"""Model-substrate correctness: attention masks/caches, Mamba2 SSD
train<->decode equivalence, MoE routing invariants, norms/RoPE."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MambaConfig, MoEConfig, reduced
from repro.configs.registry import ARCHS
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import apply_rope, cross_entropy, rms_norm, softcap


def _attn_cfg(**kw):
    base = dict(name="t", family="dense", source="", n_layers=2,
                d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=256, head_dim=16)
    base.update(kw)
    return ArchConfig(**base)


# ----------------------------------------------------------------- attention

def test_attention_is_causal():
    cfg = _attn_cfg()
    p = attn_mod.attn_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    pos = jnp.arange(16, dtype=jnp.int32)
    out1 = attn_mod.attn_apply(p, x, cfg, positions=pos)
    # perturbing the future must not change the past
    x2 = x.at[:, 10:].add(3.0)
    out2 = attn_mod.attn_apply(p, x2, cfg, positions=pos)
    np.testing.assert_allclose(np.asarray(out1[:, :10]),
                               np.asarray(out2[:, :10]), atol=1e-5)


def test_sliding_window_masks_far_past():
    cfg = _attn_cfg(sliding_window=4)
    p = attn_mod.attn_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    pos = jnp.arange(16, dtype=jnp.int32)
    out1 = attn_mod.attn_apply(p, x, cfg, positions=pos, window=4)
    x2 = x.at[:, 0:2].add(5.0)     # beyond the window of position 15
    out2 = attn_mod.attn_apply(p, x2, cfg, positions=pos, window=4)
    np.testing.assert_allclose(np.asarray(out1[:, -1]),
                               np.asarray(out2[:, -1]), atol=1e-5)


def test_prefill_then_decode_matches_full_forward():
    """decode(t) after prefill(0..t-1) == full attention at position t."""
    cfg = _attn_cfg()
    p = attn_mod.attn_init(jax.random.key(0), cfg)
    T = 12
    x = jax.random.normal(jax.random.key(1), (2, T, cfg.d_model))
    pos = jnp.arange(T, dtype=jnp.int32)
    full = attn_mod.attn_apply(p, x, cfg, positions=pos)

    _, cache = attn_mod.attn_prefill(p, x[:, :T - 1], cfg,
                                     positions=pos[:T - 1], kind="attn",
                                     cache_seq=T)
    cache = {k: v.astype(jnp.float32) for k, v in cache.items()}
    out, _ = attn_mod.attn_decode(p, x[:, T - 1:], cache, cfg,
                                  pos=jnp.asarray(T - 1), kind="attn")
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2)


def test_gqa_reduces_to_mha_when_equal_heads():
    cfg_gqa = _attn_cfg(n_kv_heads=4)
    p = attn_mod.attn_init(jax.random.key(0), cfg_gqa)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg_gqa.d_model))
    pos = jnp.arange(8, dtype=jnp.int32)
    out = attn_mod.attn_apply(p, x, cfg_gqa, positions=pos)
    assert out.shape == (1, 8, cfg_gqa.d_model)
    assert not bool(jnp.any(jnp.isnan(out)))


# --------------------------------------------------------------------- mamba

def _mamba_cfg():
    return ArchConfig(name="m", family="ssm", source="", n_layers=1,
                      d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
                      vocab_size=128,
                      mamba=MambaConfig(d_state=16, d_conv=4, expand=2,
                                        head_dim=16, chunk=8))


def test_mamba_chunked_equals_stepwise():
    """The SSD chunked scan and the O(1) decode recurrence are the same
    model: running T steps of decode must match the full forward."""
    cfg = _mamba_cfg()
    p = mamba_mod.mamba_init(jax.random.key(0), cfg)
    T = 24
    x = jax.random.normal(jax.random.key(1), (2, T, cfg.d_model)) * 0.5
    full, states = mamba_mod.mamba_forward(p, x, cfg)

    st = mamba_mod.init_mamba_state(cfg, 2)
    outs = []
    for t in range(T):
        o, st = mamba_mod.mamba_decode(p, x[:, t:t + 1], st, cfg)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=5e-3, atol=5e-3)
    # final states agree too
    np.testing.assert_allclose(np.asarray(st["ssm"]),
                               np.asarray(states["ssm"]),
                               rtol=5e-3, atol=5e-3)


def test_mamba_chunk_size_invariance():
    cfg = _mamba_cfg()
    p = mamba_mod.mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg.d_model)) * 0.5
    outs = []
    for chunk in (4, 8, 16, 32):
        c2 = dataclasses.replace(cfg, mamba=dataclasses.replace(
            cfg.mamba, chunk=chunk))
        y, _ = mamba_mod.mamba_forward(p, x, c2)
        outs.append(np.asarray(y))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-3, atol=2e-3)


def test_mamba_is_causal():
    cfg = _mamba_cfg()
    p = mamba_mod.mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    y1, _ = mamba_mod.mamba_forward(p, x, cfg)
    x2 = x.at[:, 12:].add(2.0)
    y2, _ = mamba_mod.mamba_forward(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :12]),
                               np.asarray(y2[:, :12]), atol=1e-4)


# ----------------------------------------------------------------------- moe

def _moe_cfg(E=4, K=2):
    return ArchConfig(name="e", family="moe", source="", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64,
                      moe=MoEConfig(num_experts=E, top_k=K, d_ff_expert=64,
                                    capacity_factor=2.0))


def test_moe_output_finite_and_shaped():
    cfg = _moe_cfg()
    p = moe_mod.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (3, 16, cfg.d_model))
    out, aux = moe_mod.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))
    assert float(aux) >= 0


def test_moe_aux_loss_penalizes_imbalance():
    """A router collapsed onto one expert must have a larger aux loss than
    a uniform router (Switch load-balance objective)."""
    cfg = _moe_cfg(E=4, K=1)
    p = moe_mod.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    p_collapsed = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(50.0))
    _, aux_c = moe_mod.moe_apply(p_collapsed, x, cfg)
    _, aux_u = moe_mod.moe_apply(dict(p, router=jnp.zeros_like(p["router"])),
                                 x, cfg)
    assert float(aux_c) > float(aux_u)


def test_moe_capacity_drops_dont_nan():
    cfg = dataclasses.replace(
        _moe_cfg(E=4, K=2),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      capacity_factor=0.25))    # force drops
    p = moe_mod.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    out, _ = moe_mod.moe_apply(p, x, cfg)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_moe_respects_capacity():
    cfg = _moe_cfg(E=2, K=1)
    C = moe_mod.capacity(cfg.moe, 16)
    assert 1 <= C <= 16


# -------------------------------------------------------------------- layers

def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.key(0), (4, 32)) * 10
    y = rms_norm(x, jnp.zeros((32,)))
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_softcap_bounds():
    x = jnp.linspace(-100, 100, 201)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


def test_rope_preserves_norm_and_relative_position():
    x = jax.random.normal(jax.random.key(0), (1, 8, 2, 16))
    pos = jnp.arange(8, dtype=jnp.int32)
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4)
    # dot products depend only on relative offsets
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 16))
    qs = jnp.broadcast_to(q, (1, 8, 1, 16))
    yq = apply_rope(qs, pos, 10000.0)
    d1 = float(jnp.sum(yq[0, 3, 0] * yq[0, 1, 0]))
    d2 = float(jnp.sum(yq[0, 6, 0] * yq[0, 4, 0]))
    assert abs(d1 - d2) < 1e-3


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((2, 3, 8))
    logits = logits.at[..., 6:].set(100.0)     # huge logits in padding
    labels = jnp.zeros((2, 3), jnp.int32)
    ce = cross_entropy(logits, labels, vocab_true=6)
    assert float(ce) == pytest.approx(math.log(6.0), rel=1e-4)


def test_moe_dispatch_conservation():
    """Property: with ample capacity every (token, expert) assignment is
    dispatched exactly once and the combine reconstructs a pure top-k
    mixture — checked against a dense (no-dispatch) oracle."""
    cfg = _moe_cfg(E=4, K=2)
    p = moe_mod.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out, _ = moe_mod.moe_apply(p, x, cfg)

    # dense oracle: run every token through every expert, combine by gates
    B, T, D = x.shape
    probs = jax.nn.softmax(
        x.reshape(-1, D).astype(jnp.float32) @ p["router"], axis=-1)
    gate_vals, expert_idx = moe_mod._topk_iterative(
        probs.reshape(B, T, -1), 2)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    ys = jnp.stack([
        (jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])) @ p["w_down"][e]
        for e in range(4)])                      # (E, B, T, D)
    want = jnp.zeros_like(x)
    for k in range(2):
        sel = jnp.take_along_axis(
            ys.transpose(1, 2, 0, 3),            # (B, T, E, D)
            expert_idx[..., k][..., None, None], axis=2)[..., 0, :]
        want = want + gate_vals[..., k][..., None] * sel
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_moe_topk_iterative_matches_lax_topk():
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(5), (3, 7, 16)), axis=-1)
    v1, i1 = moe_mod._topk_iterative(probs, 4)
    v2, i2 = jax.lax.top_k(probs, 4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
