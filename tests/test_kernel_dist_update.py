"""dist_update kernel vs oracle: shape sweep + boosting invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("N", [64, 300, 1024, 5000])
@pytest.mark.parametrize("alpha", [0.0, 0.3, 1.5])
def test_dist_update_matches_ref(N, alpha):
    k = jax.random.split(jax.random.key(N), 3)
    D = jax.nn.softmax(jax.random.normal(k[0], (N,)))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    h = jnp.sign(jax.random.normal(k[2], (N,)))
    got_D, got_Z = ops.dist_update(alpha, D, y, h)
    want_D, want_Z = ref.dist_update_ref(alpha, D, y, h)
    np.testing.assert_allclose(np.asarray(got_D), np.asarray(want_D),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(got_Z), float(want_Z), rtol=1e-5)


def test_dist_update_agrees_with_core_boosting():
    from repro.core.boosting import update_distribution
    k = jax.random.split(jax.random.key(0), 3)
    N = 777
    D = jax.nn.softmax(jax.random.normal(k[0], (N,)))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    h = jnp.sign(jax.random.normal(k[2], (N,)))
    got_D, got_Z = ops.dist_update(0.7, D, y, h)
    want_D, want_Z = update_distribution(D, 0.7, y, h)
    np.testing.assert_allclose(np.asarray(got_D), np.asarray(want_D),
                               rtol=1e-5, atol=1e-7)


@given(st.integers(min_value=8, max_value=2000),
       st.floats(min_value=0.0, max_value=3.0))
@settings(max_examples=25, deadline=None)
def test_dist_update_normalized_property(N, alpha):
    """Property: output always sums to 1 and stays non-negative."""
    k = jax.random.split(jax.random.key(N), 3)
    D = jax.nn.softmax(jax.random.normal(k[0], (N,)))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    h = jnp.sign(jax.random.normal(k[2], (N,)))
    got_D, _ = ops.dist_update(alpha, D, y, h)
    assert float(jnp.sum(got_D)) == pytest.approx(1.0, abs=1e-4)
    assert float(jnp.min(got_D)) >= 0.0


def test_dist_update_block_sweep():
    k = jax.random.split(jax.random.key(3), 3)
    N = 3000
    D = jax.nn.softmax(jax.random.normal(k[0], (N,)))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    h = jnp.sign(jax.random.normal(k[2], (N,)))
    want, _ = ref.dist_update_ref(1.1, D, y, h)
    for bn in (256, 512, 1024):
        got, _ = ops.dist_update(1.1, D, y, h, block_n=bn)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-7)
