"""Result-cache correctness: bit-identical hits, publish/gossip
invalidation scoped to exactly the affected tenant, and cross-tenant
isolation under interleaved traffic."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (BatchConfig, EnsembleRegistry, EnsembleServer,
                         GossipConfig, ResultCache, ShardCluster,
                         ShardedEnsembleServer, feature_hash)


def _direct_margin(snap, x):
    sp = np.asarray(snap.stump_params)
    al = np.asarray(snap.alphas)
    xv = np.asarray(x)[sp[:, 0].astype(int)]
    return float(np.dot(al, sp[:, 2] * np.sign(xv - sp[:, 1] + 1e-12)))


def _publish(target, tenant, T=4, F=6, seed=0, clock=0.0, progress=0):
    rng = np.random.RandomState(seed)
    p = np.zeros((T, 4), np.float32)
    p[:, 0] = rng.randint(0, F, size=T)
    p[:, 1] = rng.randn(T)
    p[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    a = (rng.rand(T) + 0.1).astype(np.float32)
    return target.publish_packed(tenant, jnp.asarray(p), jnp.asarray(a),
                                 clock=clock, train_progress=progress)


def _server(registry, **kw):
    return EnsembleServer(
        registry, BatchConfig(cache_capacity=kw.pop("capacity", 256)),
        service_model=lambda n: 1e-4, **kw)


def _serve_one(server, tenant, x, now):
    _, out = server.submit(tenant, x, now)
    out += server.drain()
    (resp,) = out
    return resp


def test_hit_is_bit_identical_to_cold_kernel_eval():
    reg = EnsembleRegistry()
    _publish(reg, "t", T=5, seed=3)
    warm = _server(reg)
    x = np.random.RandomState(0).randn(6).astype(np.float32)
    first = _serve_one(warm, "t", x, 0.0)       # cold: fills the cache
    assert warm.cache.stats.hits == 0 and warm.cache.stats.fills == 1
    second = _serve_one(warm, "t", x, 1.0)      # warm: served from cache
    assert warm.cache.stats.hits == 1
    assert warm.evaluator.last_eval.cached_requests == 1
    assert warm.evaluator.last_eval.kernel_requests == 0
    # a completely cold server (no cache) evaluates the same kernel path
    cold = EnsembleServer(reg, BatchConfig(), service_model=lambda n: 1e-4)
    reference = _serve_one(cold, "t", x, 0.0)
    assert second.margin == first.margin == reference.margin  # bit-identical
    assert second.label == reference.label
    assert second.snapshot_version == reference.snapshot_version


def test_hit_bit_identical_across_batch_packings():
    """The padding contract means a margin computed in a wide packed batch
    equals the single-request evaluation bit for bit — so cache fills from
    any batch composition are safe to replay."""
    reg = EnsembleRegistry()
    _publish(reg, "a", T=3, seed=1)
    _publish(reg, "b", T=9, seed=2)             # forces T/N padding for "a"
    server = _server(reg)
    rng = np.random.RandomState(4)
    xa = rng.randn(6).astype(np.float32)
    # fill from a mixed two-tenant batch (padded to the widest ensemble)
    server.submit("a", xa, 0.0)
    for i in range(3):
        server.submit("b", rng.randn(6).astype(np.float32), 0.0)
    server.drain()
    hit = _serve_one(server, "a", xa, 1.0)
    solo = _serve_one(EnsembleServer(reg, BatchConfig(),
                                     service_model=lambda n: 1e-4),
                      "a", xa, 0.0)
    assert hit.margin == solo.margin


def test_publish_invalidates_exactly_that_tenant():
    reg = EnsembleRegistry()
    _publish(reg, "a", seed=1)
    _publish(reg, "b", seed=2)
    server = _server(reg)
    rng = np.random.RandomState(0)
    xs = {t: rng.randn(6).astype(np.float32) for t in "ab"}
    for t in "ab":
        _serve_one(server, t, xs[t], 0.0)
    assert len(server.cache) == 2
    snap = _publish(reg, "a", T=6, seed=7)      # newer version for a only
    assert snap.version == 2
    keys = server.cache.keys()
    assert len(keys) == 1                       # a's entry swept...
    assert keys[0][0] == "b"                    # ...b's untouched
    assert server.cache.stats.invalidated == 1
    # serving "a" again misses (new version key) and re-fills
    resp = _serve_one(server, "a", xs["a"], 1.0)
    assert resp.snapshot_version == 2
    assert server.cache.stats.fills == 3


def test_gossip_ingest_invalidates_replica_cache():
    cluster = ShardCluster(2, GossipConfig(seed=0))
    hosts = list(cluster.hosts.values())
    _publish(cluster, "t", seed=1)
    cluster.run_until_quiescent()
    # replica host (non-owner) serves from its gossiped copy with a cache
    owner = cluster.owner("t")
    replica = next(h for h in hosts if h.host_id != owner)
    cache = ResultCache(64)
    cache.attach(replica.registry)
    server = EnsembleServer(replica.registry, BatchConfig(),
                            service_model=lambda n: 1e-4, cache=cache)
    x = np.random.RandomState(2).randn(6).astype(np.float32)
    _serve_one(server, "t", x, 0.0)
    assert len(cache) == 1
    # v2 lands on the owner, then reaches the replica via gossip ingest
    _publish(cluster, "t", T=7, seed=9, clock=1.0)
    assert len(cache) == 1                      # not yet gossiped
    cluster.run_until_quiescent(now=1.0)
    assert len(cache) == 0                      # swept on ingest
    assert cache.stats.invalidated == 1
    resp = _serve_one(server, "t", x, 2.0)
    assert resp.snapshot_version == 2


def test_cross_tenant_isolation_under_interleaved_traffic():
    cluster = ShardCluster(3, GossipConfig(seed=1))
    for i, t in enumerate(["a", "b", "c"]):
        _publish(cluster, t, T=3 + i, seed=i)
    cluster.run_until_quiescent()
    server = ShardedEnsembleServer(cluster, BatchConfig(cache_capacity=512),
                                   service_model=lambda n: 1e-4)
    rng = np.random.RandomState(5)
    pools = {t: rng.randn(4, 6).astype(np.float32) for t in "abc"}
    responses = []
    for i in range(90):
        t = "abc"[i % 3]
        _, done = server.submit(t, pools[t][i % 4], now=1e-3 * i)
        responses += done
    responses += server.drain()
    assert len(responses) == 90
    # margins never leak across tenants: every response matches a direct
    # evaluation of its own tenant's snapshot
    by_rid = {}
    for i in range(90):
        by_rid[i] = ("abc"[i % 3], pools["abc"[i % 3]][i % 4])
    for r in responses:
        tenant, x = by_rid[r.rid]
        assert r.tenant == tenant
        want = _direct_margin(cluster.latest(tenant), x)
        assert r.margin == pytest.approx(want, abs=1e-5)
    stats = server.cache_stats()
    assert stats["hits"] > 0
    # per-tenant keys stayed disjoint
    for s in server.servers.values():
        if s.cache is None:
            continue
        for key in s.cache.keys():
            assert key[0] in ("a", "b", "c")


def test_same_version_reconciliation_sweeps_loser_cache():
    """Two hosts race the same version number; after gossip replaces the
    loser's snapshot, entries the loser served from the discarded ensemble
    must not survive as hits (the invalidation bound is inclusive)."""
    cluster = ShardCluster(2, GossipConfig(seed=0, lam=0.5))
    h0, h1 = cluster.hosts.values()
    _publish(h0.registry, "t", seed=1, clock=0.0, progress=3)   # loser
    _publish(h1.registry, "t", seed=2, clock=2.0, progress=30)  # winner
    loser_cache = ResultCache(64)
    loser_cache.attach(h0.registry)
    server = EnsembleServer(h0.registry, BatchConfig(),
                            service_model=lambda n: 1e-4, cache=loser_cache)
    x = np.random.RandomState(3).randn(6).astype(np.float32)
    stale = _serve_one(server, "t", x, 0.0)
    assert len(loser_cache) == 1
    cluster.run_until_quiescent(now=2.0)
    assert len(loser_cache) == 0                # swept on replace_latest
    fresh = _serve_one(server, "t", x, 3.0)
    assert fresh.snapshot_version == stale.snapshot_version == 1
    want = _direct_margin(h1.registry.latest("t"), x)
    assert fresh.margin == pytest.approx(want, abs=1e-5)
    assert fresh.margin != stale.margin         # winner's content now serves


def test_in_batch_duplicates_deduped_to_one_kernel_slot():
    reg = EnsembleRegistry()
    _publish(reg, "t", T=4, seed=2)
    server = _server(reg)
    x = np.random.RandomState(1).randn(6).astype(np.float32)
    for _ in range(5):                          # same vector, one batch
        server.submit("t", x, 0.0)
    out = server.drain()
    assert len(out) == 5
    assert len({r.margin for r in out}) == 1
    ev = server.evaluator.last_eval
    assert ev.kernel_requests == 1              # one slot, not five
    assert ev.deduped_requests == 4
    assert server.cache.stats.fills == 1
    solo = _serve_one(EnsembleServer(reg, BatchConfig(),
                                     service_model=lambda n: 1e-4),
                      "t", x, 0.0)
    assert out[0].margin == solo.margin


# --------------------------------------- fused one-launch fingerprint path

def _requests(xs, tenant="t", rid0=0, now=0.0):
    from repro.serve.batching import Request
    return [Request(rid=rid0 + i, tenant=tenant, x=jnp.asarray(x),
                    t_submit=now) for i, x in enumerate(xs)]


def test_fused_path_fewer_launches_and_hashes_identical_predictions():
    """The ISSUE's serving acceptance: on a cached-replay batch the fused
    fingerprint path serves identical predictions with strictly fewer
    kernel launches + host hash calls than the classic hash-then-vote
    path, and counts its hits as fp_hits."""
    from repro.kernels.dispatch import KernelPolicy
    from repro.serve.engine import BatchEvaluator

    reg = EnsembleRegistry()
    _publish(reg, "t", T=5, seed=3)
    fused = BatchEvaluator(reg, policy=KernelPolicy(fused_fingerprint=True),
                           cache=ResultCache(256))
    classic = BatchEvaluator(reg, policy=KernelPolicy(),
                             cache=ResultCache(256))
    rng = np.random.RandomState(0)
    xs = [rng.randn(6).astype(np.float32) for _ in range(7)]

    fresh_f = fused.evaluate(_requests(xs))
    fresh_c = classic.evaluate(_requests(xs))
    assert fused.last_eval.fp_hits == 0         # cold: everything computed
    replay_f = fused.evaluate(_requests(xs, rid0=100, now=1.0))
    replay_c = classic.evaluate(_requests(xs, rid0=100, now=1.0))

    # identical predictions, batch for batch, bit for bit
    for got, want in ((fresh_f, fresh_c), (replay_f, replay_c)):
        assert [r.margin for r in got] == [r.margin for r in want]
        assert [r.label for r in got] == [r.label for r in want]
    # replay is served entirely from in-kernel fingerprints
    assert fused.last_eval.fp_hits == 7
    assert classic.last_eval.cached_requests == 7
    # the payoff the fused kernel exists for: strictly less host work
    assert fused.host_hash_calls == 0
    assert classic.host_hash_calls == 14        # 7 requests x 2 batches
    assert (fused.kernel_launches + fused.host_hash_calls
            < classic.kernel_launches + classic.host_hash_calls)


def test_fused_path_respects_publish_versioning():
    """Fingerprint cache keys carry the snapshot version: a republish
    makes every old entry unreachable, so no stale margin can be served."""
    from repro.kernels.dispatch import KernelPolicy
    from repro.serve.engine import BatchEvaluator

    reg = EnsembleRegistry()
    _publish(reg, "t", T=4, seed=1)
    ev = BatchEvaluator(reg, policy=KernelPolicy(fused_fingerprint=True),
                        cache=ResultCache(256))
    rng = np.random.RandomState(2)
    xs = [rng.randn(6).astype(np.float32) for _ in range(3)]
    ev.evaluate(_requests(xs))
    ev.evaluate(_requests(xs, rid0=10, now=1.0))
    assert ev.last_eval.fp_hits == 3
    _publish(reg, "t", T=6, seed=9)             # new version
    out = ev.evaluate(_requests(xs, rid0=20, now=2.0))
    assert ev.last_eval.fp_hits == 0            # old entries unreachable
    assert all(r.snapshot_version == 2 for r in out)
    snap = reg.latest("t")
    for r, x in zip(out, xs):
        assert r.margin == pytest.approx(_direct_margin(snap, x), rel=1e-5)


def test_fused_spec_round_trips_through_policy_table(tmp_path):
    from repro.serve.policy import PolicyTable, _kernel_from_spec

    pol = _kernel_from_spec({"fused_fingerprint": True})
    assert pol.fused_fingerprint is True
    table = PolicyTable()
    table.set_tenant("iot", kernel=pol)
    assert table.kernel_for("iot").fused_fingerprint is True
    assert table.kernel_for("other") is None
    p = tmp_path / "policies.json"
    table.save(p)
    loaded = PolicyTable.load(p)
    assert loaded.kernel_for("iot").fused_fingerprint is True


def test_lru_eviction_and_capacity():
    cache = ResultCache(capacity=2)
    xs = [np.full(3, float(i), np.float32) for i in range(3)]
    hs = [feature_hash(x) for x in xs]
    cache.put("t", 1, hs[0], 0.1)
    cache.put("t", 1, hs[1], 0.2)
    assert cache.lookup("t", 1, hs[0]) == 0.1   # refresh LRU order
    cache.put("t", 1, hs[2], 0.3)               # evicts hs[1]
    assert cache.lookup("t", 1, hs[1]) is None
    assert cache.lookup("t", 1, hs[0]) == 0.1
    assert cache.stats.evicted == 1
    # version mismatch is a miss even for the same bytes
    assert cache.lookup("t", 2, hs[0]) is None
