"""Delayed weight compensation (paper eq. 2)."""
import math

import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs.paper_fedboost import CompensationConfig
from repro.core.compensation import adaboost_alpha, compensate, compensated_alpha

CFG = CompensationConfig(lam=0.15, tau_cap=32)


def test_zero_delay_is_identity():
    assert float(compensate(1.3, 0, CFG)) == pytest.approx(1.3)


def test_exponential_decay_law():
    a = 0.8
    for tau in (1, 3, 7):
        assert float(compensate(a, tau, CFG)) == pytest.approx(
            a * math.exp(-CFG.lam * tau), rel=1e-5)


def test_alpha_formula():
    # alpha = 1/2 ln((1-eps)/eps)
    assert float(adaboost_alpha(0.5)) == pytest.approx(0.0, abs=1e-5)
    assert float(adaboost_alpha(0.1)) == pytest.approx(
        0.5 * math.log(9.0), rel=1e-5)
    assert float(adaboost_alpha(0.9)) < 0       # worse than chance flips


def test_tau_cap():
    assert float(compensate(1.0, 1000, CFG)) == pytest.approx(
        math.exp(-CFG.lam * CFG.tau_cap), rel=1e-5)


@given(st.floats(min_value=0.0, max_value=5.0),
       st.integers(min_value=0, max_value=100),
       st.integers(min_value=0, max_value=100))
@settings(max_examples=80, deadline=None)
def test_staler_never_heavier(a, t1, t2):
    """Property: compensation is monotone non-increasing in staleness."""
    lo, hi = sorted((t1, t2))
    assert float(compensate(a, hi, CFG)) <= float(compensate(a, lo, CFG)) + 1e-7


@given(st.floats(min_value=0.01, max_value=0.49))
@settings(max_examples=50, deadline=None)
def test_compensated_bounded_by_original(eps):
    a = float(adaboost_alpha(eps))
    for tau in (0, 1, 5):
        assert 0 <= float(compensated_alpha(eps, tau, CFG)) <= a + 1e-7
