"""Kernel-backend dispatch: resolution order, shape bucketing, the
calibration table round-trip, the deprecated interpret shim, and per-call
re-resolution in the serving evaluator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dispatch import (
    ENV_VAR, KernelPolicy, bucket_of, canonical, on_tpu, platform_default)


def _vote_case(T=9, N=33, seed=0):
    k = jax.random.split(jax.random.key(seed), 2)
    m = jnp.sign(jax.random.normal(k[0], (T, N)))
    a = jax.random.normal(k[1], (T,))
    return m, a


# -------------------------------------------------------- resolution order

def test_resolution_priority_chain(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    bucket = (8, 128)
    # platform default at the bottom
    pol = KernelPolicy()
    assert pol.resolve_name("ensemble_vote", bucket) == platform_default()
    # calibration table beats platform default
    pol.record("ensemble_vote", bucket, "xla")
    assert pol.resolve_name("ensemble_vote", bucket) == "xla"
    # env var beats the table
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert pol.resolve_name("ensemble_vote", bucket) == "interpret"
    # forced policy backend beats env
    forced = KernelPolicy(backend="xla")
    assert forced.resolve_name("ensemble_vote", bucket) == "xla"
    # explicit per-call arg beats everything
    assert forced.resolve_name("ensemble_vote", bucket,
                               explicit="interpret") == "interpret"


@pytest.mark.skipif(on_tpu(), reason="CPU-only fallback semantics")
def test_unavailable_backend_falls_through(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    pol = KernelPolicy()
    with pytest.warns(RuntimeWarning, match="unavailable"):
        name = pol.resolve_name("ensemble_vote", (8, 128),
                                explicit="mosaic")
    assert name == "interpret"
    # a mosaic-calibrated table degrades gracefully off-TPU too
    pol2 = KernelPolicy(table={("ensemble_vote", (8, 128)): "mosaic"})
    with pytest.warns(RuntimeWarning):
        assert pol2.resolve_name("ensemble_vote", (8, 128)) == "interpret"


def test_env_change_takes_effect_without_rebuild(monkeypatch):
    """The dispatch cache must never pin a stale env-driven choice."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    pol = KernelPolicy()
    m, a = _vote_case()
    bucket = bucket_of("ensemble_vote", (m, a))
    ops.ensemble_vote(m, a, policy=pol)
    assert pol.choices[("ensemble_vote", bucket)] == platform_default()
    monkeypatch.setenv(ENV_VAR, "xla")
    ops.ensemble_vote(m, a, policy=pol)
    assert pol.choices[("ensemble_vote", bucket)] == "xla"


def test_platform_change_not_masked_by_dispatch_cache(monkeypatch):
    """A TPU hot-attach re-steers cached (kernel, bucket) resolutions: the
    cache key includes the live platform, never pinning a stale choice."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    import jax as _jax
    pol = KernelPolicy()
    bucket = (8, 128)
    monkeypatch.setattr(_jax, "default_backend", lambda: "cpu")
    assert pol.resolve("ensemble_vote", bucket).name == "interpret"
    monkeypatch.setattr(_jax, "default_backend", lambda: "tpu")
    assert pol.resolve("ensemble_vote", bucket).name == "mosaic"


def test_canonical_names_and_aliases():
    assert canonical("XLA") == "xla"
    assert canonical("ref") == "xla"
    assert canonical("pallas") == "interpret"
    assert canonical("tpu") == "mosaic"
    with pytest.raises(KeyError):
        canonical("cuda")


# --------------------------------------------------------------- bucketing

def test_ragged_shapes_share_buckets_and_dispatch_cache(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    # both round up to the same padded kernel shape
    b1 = bucket_of("ensemble_vote", _vote_case(T=5, N=90))
    b2 = bucket_of("ensemble_vote", _vote_case(T=7, N=100))
    assert b1 == b2
    assert bucket_of("ensemble_vote", _vote_case(T=9, N=300)) != b1
    pol = KernelPolicy()
    ops.ensemble_vote(*_vote_case(T=5, N=90), policy=pol)
    hits0 = pol.cache_hits
    ops.ensemble_vote(*_vote_case(T=7, N=100), policy=pol)
    assert pol.cache_hits == hits0 + 1


def test_batched_bucket_tracks_padded_dims():
    m = jnp.zeros((3, 37, 100))
    a = jnp.zeros((3, 37))
    assert bucket_of("ensemble_vote_batched", (m, a)) == (4, 64, 128)


# ------------------------------------------------------------- calibration

def test_calibration_roundtrip(tmp_path, monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    pol = KernelPolicy()
    m, a = _vote_case(T=6, N=50)
    bucket, samples = pol.calibrate_call("ensemble_vote", m, a, reps=2)
    assert bucket == bucket_of("ensemble_vote", (m, a))
    assert set(samples) and all(len(ts) == 2 for ts in samples.values())
    winner = pol.table[("ensemble_vote", bucket)]
    assert winner in samples
    path = pol.save(str(tmp_path / "cal.json"))
    loaded = KernelPolicy.load(path)
    assert loaded.table == pol.table
    assert loaded.resolve_name("ensemble_vote", bucket) == winner
    # an uncalibrated bucket still falls back to the platform default
    assert loaded.resolve_name("ensemble_vote", (1024, 4096)) == \
        platform_default()


# ------------------------------------------------------- deprecated shims

def test_ops_interpret_shim_warns_and_matches():
    m, a = _vote_case()
    with pytest.warns(DeprecationWarning, match="interpret"):
        got = ops.ensemble_vote(m, a, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.ensemble_vote_ref(m, a)),
                               rtol=1e-5, atol=1e-5)


def test_server_interpret_shim_warns():
    from repro.serve import BatchConfig, EnsembleRegistry, EnsembleServer
    from repro.serve.engine import BatchEvaluator
    reg = EnsembleRegistry()
    with pytest.warns(DeprecationWarning):
        srv = EnsembleServer(reg, BatchConfig(), interpret=True)
    assert srv.policy.backend == "interpret"
    with pytest.warns(DeprecationWarning):
        ev = BatchEvaluator(reg, interpret=True)
    assert ev._backend_override == "interpret"


def test_server_interpret_shim_outranks_policy():
    """Like the explicit arg it replaces, the deprecated bool pins the
    backend even when a (e.g. calibration) policy is passed alongside —
    the policy's table survives, its resolution is overridden."""
    from repro.serve import BatchConfig, EnsembleRegistry, EnsembleServer
    reg = EnsembleRegistry()
    cal = KernelPolicy(table={("ensemble_vote", (8, 128)): "xla"})
    with pytest.warns(DeprecationWarning):
        srv = EnsembleServer(reg, BatchConfig(), policy=cal, interpret=True)
    assert srv.policy.backend == "interpret"
    assert srv.policy.table == cal.table


# -------------------------------------- serving evaluator re-resolution fix

def test_evaluator_reresolves_backend_per_call(monkeypatch):
    """A policy/env change after construction must steer the very next
    evaluate() — nothing about the backend is captured at build time."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    from repro.serve import EnsembleRegistry
    from repro.serve.batching import Request
    from repro.serve.engine import BatchEvaluator
    rng = np.random.RandomState(0)
    reg = EnsembleRegistry()
    params = np.zeros((4, 4), np.float32)
    params[:, 0] = rng.randint(0, 6, size=4)
    params[:, 1] = rng.randn(4)
    params[:, 2] = 1.0
    reg.publish_packed("t", jnp.asarray(params),
                       jnp.ones((4,), jnp.float32), clock=0.0)
    pol = KernelPolicy()
    ev = BatchEvaluator(reg, policy=pol)
    batch = [Request(rid=0, tenant="t", x=rng.randn(6).astype(np.float32),
                     t_submit=0.0)]
    r1 = ev.evaluate(batch)
    (bucket,) = [b for (k, b) in pol.choices if k == "stump_vote_batched"]
    assert pol.choices[("stump_vote_batched", bucket)] == platform_default()
    monkeypatch.setenv(ENV_VAR, "xla")
    r2 = ev.evaluate(batch)
    assert pol.choices[("stump_vote_batched", bucket)] == "xla"
    # and the two backends served identical margins
    assert r1[0].margin == pytest.approx(r2[0].margin, abs=1e-5)
