"""Kernel-backend dispatch: resolution order, layout-canonical shape
bucketing, the v2 calibration-table round-trip (backend + block layout),
layout-kwarg injection, the deprecated interpret shim, and per-call
re-resolution in the serving evaluator."""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dispatch import (
    BACKENDS, DEFAULT_LAYOUTS, ENV_VAR, CalEntry, KernelPolicy, bucket_of,
    canonical, layout_key, on_tpu, platform_default)


def _vote_case(T=9, N=33, seed=0):
    k = jax.random.split(jax.random.key(seed), 2)
    m = jnp.sign(jax.random.normal(k[0], (T, N)))
    a = jax.random.normal(k[1], (T,))
    return m, a


# -------------------------------------------------------- resolution order

def test_resolution_priority_chain(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    bucket = (8, 128)
    # platform default at the bottom
    pol = KernelPolicy()
    assert pol.resolve_name("ensemble_vote", bucket) == platform_default()
    # calibration table beats platform default
    pol.record("ensemble_vote", bucket, "xla")
    assert pol.resolve_name("ensemble_vote", bucket) == "xla"
    # env var beats the table
    monkeypatch.setenv(ENV_VAR, "interpret")
    assert pol.resolve_name("ensemble_vote", bucket) == "interpret"
    # forced policy backend beats env
    forced = KernelPolicy(backend="xla")
    assert forced.resolve_name("ensemble_vote", bucket) == "xla"
    # explicit per-call arg beats everything
    assert forced.resolve_name("ensemble_vote", bucket,
                               explicit="interpret") == "interpret"


@pytest.mark.skipif(on_tpu(), reason="CPU-only fallback semantics")
def test_unavailable_backend_falls_through(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    pol = KernelPolicy()
    with pytest.warns(RuntimeWarning, match="unavailable"):
        name = pol.resolve_name("ensemble_vote", (8, 128),
                                explicit="mosaic")
    assert name == "interpret"
    # a mosaic-calibrated table degrades gracefully off-TPU too
    pol2 = KernelPolicy(table={("ensemble_vote", (8, 128)): "mosaic"})
    with pytest.warns(RuntimeWarning):
        assert pol2.resolve_name("ensemble_vote", (8, 128)) == "interpret"


def test_env_change_takes_effect_without_rebuild(monkeypatch):
    """The dispatch cache must never pin a stale env-driven choice."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    pol = KernelPolicy()
    m, a = _vote_case()
    bucket = bucket_of("ensemble_vote", (m, a))
    ops.ensemble_vote(m, a, policy=pol)
    assert pol.choices[("ensemble_vote", bucket)] == platform_default()
    monkeypatch.setenv(ENV_VAR, "xla")
    ops.ensemble_vote(m, a, policy=pol)
    assert pol.choices[("ensemble_vote", bucket)] == "xla"


def test_platform_change_not_masked_by_dispatch_cache(monkeypatch):
    """A TPU hot-attach re-steers cached (kernel, bucket) resolutions: the
    cache key includes the live platform, never pinning a stale choice."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    import jax as _jax
    pol = KernelPolicy()
    bucket = (8, 128)
    monkeypatch.setattr(_jax, "default_backend", lambda: "cpu")
    assert pol.resolve("ensemble_vote", bucket).name == "interpret"
    monkeypatch.setattr(_jax, "default_backend", lambda: "tpu")
    assert pol.resolve("ensemble_vote", bucket).name == "mosaic"


def test_canonical_names_and_aliases():
    assert canonical("XLA") == "xla"
    assert canonical("ref") == "xla"
    assert canonical("pallas") == "interpret"
    assert canonical("tpu") == "mosaic"
    with pytest.raises(KeyError):
        canonical("cuda")


# --------------------------------------------------------------- bucketing

def test_ragged_shapes_share_buckets_and_dispatch_cache(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    # both round up to the same padded kernel shape
    b1 = bucket_of("ensemble_vote", _vote_case(T=5, N=90))
    b2 = bucket_of("ensemble_vote", _vote_case(T=7, N=100))
    assert b1 == b2
    assert bucket_of("ensemble_vote", _vote_case(T=9, N=300)) != b1
    pol = KernelPolicy()
    ops.ensemble_vote(*_vote_case(T=5, N=90), policy=pol)
    hits0 = pol.cache_hits
    ops.ensemble_vote(*_vote_case(T=7, N=100), policy=pol)
    assert pol.cache_hits == hits0 + 1


def test_batched_bucket_tracks_padded_dims():
    m = jnp.zeros((3, 37, 100))
    a = jnp.zeros((3, 37))
    assert bucket_of("ensemble_vote_batched", (m, a)) == (4, 64, 128)


def test_bucketing_is_layout_canonical():
    """Every candidate layout of one call maps to the same bucket — buckets
    come from the reference layout, never the layout under test, so a
    sweep's candidates share a single calibration entry."""
    m, a = _vote_case(T=6, N=50)
    base = bucket_of("ensemble_vote", (m, a))
    for layout in ({"block_t": 64, "block_n": 256},
                   {"block_t": 256, "block_n": 2048},
                   {"block_t": None, "block_n": None}):
        assert bucket_of("ensemble_vote", (m, a), layout) == base
    x = jnp.zeros((100, 5))
    args = (x, jnp.ones(100), jnp.ones(100), jnp.zeros((5, 6)))
    assert (bucket_of("stump_scan", args, {"block_n": 1024})
            == bucket_of("stump_scan", args))
    q = jnp.zeros((1, 2, 192, 64))
    assert (bucket_of("flash_attention", (q, q, q), {"block_q": 64,
                                                     "block_k": 64})
            == bucket_of("flash_attention", (q, q, q)))


# ------------------------------------------------------------- calibration

def test_calibration_roundtrip(tmp_path, monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    pol = KernelPolicy()
    m, a = _vote_case(T=6, N=50)
    bucket, samples = pol.calibrate_call("ensemble_vote", m, a, reps=2)
    assert bucket == bucket_of("ensemble_vote", (m, a))
    # sample keys are (backend, layout_key): xla measured once with the
    # empty layout, pallas backends swept over the kernel's grid
    assert set(samples) and all(len(ts) == 2 for ts in samples.values())
    assert all(isinstance(k, tuple) and len(k) == 2 for k in samples)
    assert ("xla", ()) in samples
    assert sum(1 for b, _ in samples if b == "interpret") > 1
    winner = pol.table[("ensemble_vote", bucket)]
    assert isinstance(winner, CalEntry)
    assert (winner.backend, winner.layout) in samples
    path = pol.save(str(tmp_path / "cal.json"))
    assert json.loads((tmp_path / "cal.json").read_text())["version"] == 2
    loaded = KernelPolicy.load(path)
    assert loaded.table == pol.table
    assert loaded.resolve_name("ensemble_vote", bucket) == winner.backend
    # an uncalibrated bucket still falls back to the platform default
    assert loaded.resolve_name("ensemble_vote", (1024, 4096)) == \
        platform_default()


def test_v1_table_loads_transparently(tmp_path):
    """Backend-only v1 tables (no version field, no layout key) load as
    layout-less entries — the reference layout then applies at dispatch."""
    p = tmp_path / "cal_v1.json"
    p.write_text(json.dumps({
        "env_var": ENV_VAR, "backend": None,
        "table": [{"kernel": "ensemble_vote", "bucket": [8, 128],
                   "backend": "xla"}]}))
    loaded = KernelPolicy.load(str(p))
    assert loaded.table[("ensemble_vote", (8, 128))] == CalEntry("xla", ())
    assert loaded.resolve_name("ensemble_vote", (8, 128)) == "xla"
    # and a v2 re-save of the v1 load is a valid v2 table
    loaded.save(str(tmp_path / "cal_v2.json"))
    again = KernelPolicy.load(str(tmp_path / "cal_v2.json"))
    assert again.table == loaded.table


def test_future_schema_version_rejected(tmp_path):
    p = tmp_path / "cal_v99.json"
    p.write_text(json.dumps({"version": 99, "table": []}))
    with pytest.raises(ValueError, match="schema v99"):
        KernelPolicy.load(str(p))


def test_save_records_measuring_platform(tmp_path):
    pol = KernelPolicy()
    pol.record("ensemble_vote", (8, 128), "xla")
    path = pol.save(str(tmp_path / "cal.json"))
    data = json.loads((tmp_path / "cal.json").read_text())
    assert data["measured_on"] == jax.default_backend()
    loaded = KernelPolicy.load(path)
    assert loaded.measured_on == jax.default_backend()
    # explicit override for tables assembled off-process
    pol.save(str(tmp_path / "cal_tpu.json"), measured_on="tpu")
    assert json.loads(
        (tmp_path / "cal_tpu.json").read_text())["measured_on"] == "tpu"


def test_cross_platform_table_warns_exactly_once(tmp_path):
    from repro.kernels import dispatch
    here = jax.default_backend()
    other = "tpu" if here != "tpu" else "gpu"
    p = tmp_path / "cal_other.json"
    p.write_text(json.dumps({
        "version": 2, "backend": None, "measured_on": other,
        "table": [{"kernel": "ensemble_vote", "bucket": [8, 128],
                   "backend": "xla", "layout": {}}]}))
    dispatch._PLATFORM_WARNED.discard((other, here))
    with pytest.warns(RuntimeWarning, match=f"measured on '{other}'"):
        loaded = KernelPolicy.load(str(p))
    assert loaded.measured_on == other
    assert loaded.resolve_name("ensemble_vote", (8, 128)) == "xla"
    # one-shot per (measured_on, platform) pair: a reload stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        KernelPolicy.load(str(p))
    dispatch._PLATFORM_WARNED.discard((other, here))


def test_same_platform_and_empty_tables_load_silently(tmp_path):
    here = jax.default_backend()
    same = tmp_path / "cal_same.json"
    same.write_text(json.dumps({
        "version": 2, "backend": None, "measured_on": here,
        "table": [{"kernel": "ensemble_vote", "bucket": [8, 128],
                   "backend": "xla", "layout": {}}]}))
    empty = tmp_path / "cal_empty.json"
    empty.write_text(json.dumps({
        "version": 2, "backend": None, "measured_on": "tpu", "table": []}))
    v1 = tmp_path / "cal_v1.json"          # pre-measured_on tables: silent
    v1.write_text(json.dumps({
        "table": [{"kernel": "ensemble_vote", "bucket": [8, 128],
                   "backend": "xla"}]}))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert KernelPolicy.load(str(same)).measured_on == here
        KernelPolicy.load(str(empty))      # nothing tuned -> nothing to warn
        assert KernelPolicy.load(str(v1)).measured_on is None


# -------------------------------------------------------- layout injection

def _spy_backend(monkeypatch, name, captured):
    be = BACKENDS[name]
    orig = type(be).run

    def run(kernel, *args, **kwargs):
        captured.append(dict(kwargs))
        return orig(be, kernel, *args, **kwargs)

    monkeypatch.setattr(be, "run", run)


def test_tuned_layout_injected_on_matching_backend(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    m, a = _vote_case(T=6, N=50)
    bucket = bucket_of("ensemble_vote", (m, a))
    pol = KernelPolicy(table={
        ("ensemble_vote", bucket):
            ("interpret", {"block_t": 64, "block_n": 256})})
    captured = []
    _spy_backend(monkeypatch, "interpret", captured)
    ops.ensemble_vote(m, a, policy=pol)
    assert captured[-1] == {"block_t": 64, "block_n": 256}
    assert pol.layout_choices[("ensemble_vote", bucket)] == \
        {"block_t": 64, "block_n": 256}
    # explicit caller kwarg outranks the tuned layout
    ops.ensemble_vote(m, a, policy=pol, block_t=128)
    assert captured[-1] == {"block_t": 128, "block_n": 256}


def test_tuned_layout_not_leaked_to_other_backend(monkeypatch):
    """A layout measured for one substrate says nothing about another: a
    call resolving to a different backend gets the reference layout."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    m, a = _vote_case(T=6, N=50)
    bucket = bucket_of("ensemble_vote", (m, a))
    pol = KernelPolicy(table={
        ("ensemble_vote", bucket):
            ("interpret", {"block_t": 64, "block_n": 256})})
    ops.ensemble_vote(m, a, policy=pol, backend="xla")
    assert pol.layout_choices[("ensemble_vote", bucket)] == \
        DEFAULT_LAYOUTS["ensemble_vote"]


def test_none_layout_kwargs_resolve_to_reference_layout(monkeypatch):
    """ops wrappers pass block kwargs as None ("table decides"); with no
    tuned entry the reference DEFAULT_LAYOUTS reach the backend."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    m, a = _vote_case(T=6, N=50)
    captured = []
    _spy_backend(monkeypatch, "interpret", captured)
    ops.ensemble_vote(m, a, policy=KernelPolicy(), backend="interpret")
    assert captured[-1] == DEFAULT_LAYOUTS["ensemble_vote"]


def test_table_accepts_legacy_string_values():
    pol = KernelPolicy(table={("ensemble_vote", (8, 128)): "xla"})
    assert pol.table[("ensemble_vote", (8, 128))] == CalEntry("xla", ())
    assert layout_key({"block_n": 256, "block_t": 64}) == \
        (("block_n", 256), ("block_t", 64))


# ------------------------------------------------------- deprecated shims

def test_ops_interpret_shim_warns_and_matches():
    m, a = _vote_case()
    with pytest.warns(DeprecationWarning, match="interpret"):
        got = ops.ensemble_vote(m, a, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.ensemble_vote_ref(m, a)),
                               rtol=1e-5, atol=1e-5)


def test_server_interpret_shim_warns():
    from repro.serve import BatchConfig, EnsembleRegistry, EnsembleServer
    from repro.serve.engine import BatchEvaluator
    reg = EnsembleRegistry()
    with pytest.warns(DeprecationWarning):
        srv = EnsembleServer(reg, BatchConfig(), interpret=True)
    assert srv.policy.backend == "interpret"
    with pytest.warns(DeprecationWarning):
        ev = BatchEvaluator(reg, interpret=True)
    assert ev._backend_override == "interpret"


def test_server_interpret_shim_outranks_policy():
    """Like the explicit arg it replaces, the deprecated bool pins the
    backend even when a (e.g. calibration) policy is passed alongside —
    the policy's table survives, its resolution is overridden."""
    from repro.serve import BatchConfig, EnsembleRegistry, EnsembleServer
    reg = EnsembleRegistry()
    cal = KernelPolicy(table={("ensemble_vote", (8, 128)): "xla"})
    with pytest.warns(DeprecationWarning):
        srv = EnsembleServer(reg, BatchConfig(), policy=cal, interpret=True)
    assert srv.policy.backend == "interpret"
    assert srv.policy.table == cal.table


# -------------------------------------- serving evaluator re-resolution fix

def test_evaluator_reresolves_backend_per_call(monkeypatch):
    """A policy/env change after construction must steer the very next
    evaluate() — nothing about the backend is captured at build time."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    from repro.serve import EnsembleRegistry
    from repro.serve.batching import Request
    from repro.serve.engine import BatchEvaluator
    rng = np.random.RandomState(0)
    reg = EnsembleRegistry()
    params = np.zeros((4, 4), np.float32)
    params[:, 0] = rng.randint(0, 6, size=4)
    params[:, 1] = rng.randn(4)
    params[:, 2] = 1.0
    reg.publish_packed("t", jnp.asarray(params),
                       jnp.ones((4,), jnp.float32), clock=0.0)
    pol = KernelPolicy()
    ev = BatchEvaluator(reg, policy=pol)
    batch = [Request(rid=0, tenant="t", x=rng.randn(6).astype(np.float32),
                     t_submit=0.0)]
    r1 = ev.evaluate(batch)
    (bucket,) = [b for (k, b) in pol.choices if k == "stump_vote_batched"]
    assert pol.choices[("stump_vote_batched", bucket)] == platform_default()
    monkeypatch.setenv(ENV_VAR, "xla")
    r2 = ev.evaluate(batch)
    assert pol.choices[("stump_vote_batched", bucket)] == "xla"
    # and the two backends served identical margins
    assert r1[0].margin == pytest.approx(r2[0].margin, abs=1e-5)
