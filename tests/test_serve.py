"""repro.serve: registry snapshots, publish hooks, adaptive micro-batching,
admission control, end-to-end served-prediction correctness."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_fedboost import FedBoostConfig, SchedulerConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.core.scheduling import HostScheduler, init_state
from repro.data import make_domain_data
from repro.serve import (
    AdaptiveWindow, BatchConfig, EnsembleRegistry, EnsembleServer,
    MicroBatchQueue, pack_stumps)


def _small_data(name="edge_vision", n=600, k=4, seed=0):
    dom = dataclasses.replace(DOMAINS[name], n_samples=n, n_clients=k)
    return make_domain_data(dom, seed=seed)


def _stump_snapshot(registry, tenant="t", T=5, F=8, seed=0, clock=0.0):
    rng = np.random.RandomState(seed)
    params = np.zeros((T, 4), np.float32)
    params[:, 0] = rng.randint(0, F, size=T)
    params[:, 1] = rng.randn(T)
    params[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    alphas = rng.rand(T).astype(np.float32) + 0.1
    return registry.publish_packed(tenant, jnp.asarray(params),
                                   jnp.asarray(alphas), clock=clock)


def _direct_margin(snap, x):
    sp = np.asarray(snap.stump_params)
    al = np.asarray(snap.alphas)
    xv = np.asarray(x)[sp[:, 0].astype(int)]
    return float(np.dot(al, sp[:, 2] * np.sign(xv - sp[:, 1] + 1e-12)))


# ------------------------------------------------------------------ registry

def test_registry_versioning_and_reads():
    reg = EnsembleRegistry(history=2)
    s1 = _stump_snapshot(reg, T=3, seed=1)
    s2 = _stump_snapshot(reg, T=5, seed=2)
    s3 = _stump_snapshot(reg, T=7, seed=3)
    assert (s1.version, s2.version, s3.version) == (1, 2, 3)
    assert reg.latest("t").version == 3
    assert reg.latest("t").n_learners == 7
    assert reg.version_count("t") == 3
    assert reg.get("t", 2).n_learners == 5      # within history window
    assert reg.get("t", 1) is None              # evicted (history=2)
    assert reg.latest("missing") is None
    with pytest.raises(dataclasses.FrozenInstanceError):
        reg.latest("t").version = 99            # snapshots are immutable


def test_registry_staleness_and_rebase():
    reg = EnsembleRegistry()
    _stump_snapshot(reg, clock=10.0)
    assert reg.staleness("t", 12.5) == pytest.approx(2.5)
    assert math.isinf(reg.staleness("nope", 0.0))
    reg.rebase_clock(0.0)
    assert reg.staleness("t", 1.0) == pytest.approx(1.0)
    assert reg.latest("t").version == 1         # rebase keeps the version


def test_pack_stumps_roundtrip():
    learners = [{"feature": jnp.asarray(3, jnp.int32),
                 "threshold": jnp.asarray(0.25),
                 "polarity": jnp.asarray(-1.0)}]
    packed = pack_stumps(learners)
    assert packed.shape == (1, 4)
    np.testing.assert_allclose(np.asarray(packed[0, :3]), [3.0, 0.25, -1.0])
    assert pack_stumps([]).shape == (0, 4)


# -------------------------------------------------------------- publish hook

def test_engine_publishes_snapshots_mid_training():
    reg = EnsembleRegistry()
    data = _small_data()
    eng = FederatedBoostEngine(FedBoostConfig(n_clients=4, n_rounds=5,
                                              seed=0), data, "enhanced")
    eng.attach_registry(reg, "edge_vision")
    eng.run()
    n_versions = reg.version_count("edge_vision")
    assert n_versions >= 2                      # published more than once
    snap = reg.latest("edge_vision")
    assert snap.weak_name == "stump"
    assert snap.n_learners == len(eng.ensemble.learners)
    assert snap.train_progress == eng.metrics.learners_merged
    # snapshot margins agree with the live ensemble on a test row
    x = np.asarray(data["test"][0][0])
    from repro.models.weak import get_weak_learner
    weak = get_weak_learner("stump")
    live = float(sum(a * float(weak.predict(p, jnp.asarray(x)[None])[0])
                     for p, a in zip(eng.ensemble.learners,
                                     eng.ensemble.alphas)))
    assert _direct_margin(snap, x) == pytest.approx(live, abs=1e-4)


def test_fed_mesh_publish_snapshot_slices_live_ensemble():
    from repro.core import fed_mesh
    reg = EnsembleRegistry()
    state = fed_mesh.init_state(FedBoostConfig(n_clients=2), 2, 16, 8,
                                buffer_cap=4, ens_cap=32,
                                key=jax.random.key(0))
    params = jnp.zeros((32, 4)).at[0].set(jnp.asarray([1.0, 0.5, 1.0, 0.0]))
    state = state._replace(ens_params=params,
                           ens_alpha=jnp.zeros((32,)).at[0].set(0.8),
                           ens_count=jnp.asarray(1, jnp.int32),
                           counter=jnp.asarray(7, jnp.int32))
    snap = fed_mesh.publish_snapshot(state, reg, "mesh", clock=3.0)
    assert snap.n_learners == 1                 # only the valid prefix
    assert snap.train_progress == 7
    np.testing.assert_allclose(np.asarray(snap.stump_params),
                               [[1.0, 0.5, 1.0, 0.0]])
    assert reg.latest("mesh").version == 1


# ------------------------------------------------- scheduler construction fix

def test_scheduler_i_init_clipped_at_construction():
    cfg = SchedulerConfig(i_min=2, i_max=8, i_init=50)
    host = HostScheduler(cfg)
    assert host.interval == 8.0                 # clipped before first observe
    assert float(init_state(cfg).interval) == 8.0
    low = SchedulerConfig(i_min=2, i_max=8, i_init=0)
    assert HostScheduler(low).interval == 2.0
    assert float(init_state(low).interval) == 2.0
    # fed_mesh state construction stays in lockstep
    from repro.core import fed_mesh
    fb = FedBoostConfig(scheduler=cfg)
    st = fed_mesh.init_state(fb, 2, 8, 4, buffer_cap=2, ens_cap=8,
                             key=jax.random.key(0))
    assert float(st.interval) == 8.0


# --------------------------------------------------------- adaptive batching

def test_window_grows_when_latency_regresses_and_shrinks_when_stable():
    cfg = BatchConfig()
    w = AdaptiveWindow(cfg)
    w.observe_p99(0.010)                        # first obs: records baseline
    start = w.units
    w.observe_p99(0.020)                        # +40% of target -> grow
    assert w.units > start
    grown = w.units
    w.observe_p99(0.020)                        # stable -> drift back down
    assert w.units < grown
    # stays within the eq.-1 clip bounds under any observation stream
    for p99 in (1.0, 1.0, 0.0, 0.0, 5.0, 5.0, 5.0):
        w.observe_p99(p99)
        assert cfg.scheduler.i_min <= w.units <= cfg.scheduler.i_max


def test_fixed_window_ignores_observations():
    w = AdaptiveWindow(BatchConfig(adaptive=False, fixed_window_units=6))
    w.observe_p99(9.9)
    w.observe_p99(0.0)
    assert w.units == 6
    assert w.window_s == pytest.approx(6e-3)


def test_admission_control_backpressure():
    q = MicroBatchQueue(BatchConfig(queue_budget=3))
    assert all(q.submit("t", [0.0], 0.0) is not None for _ in range(3))
    assert q.submit("t", [0.0], 0.0) is None    # over budget: rejected
    assert q.rejected == 1
    assert q.depth == 3
    # the rejection reaches the server's caller as accepted=False
    reg = EnsembleRegistry()
    _stump_snapshot(reg, T=2, F=3)
    server = EnsembleServer(
        reg, BatchConfig(queue_budget=2, max_batch=8, adaptive=False,
                         fixed_window_units=1000),
        service_model=lambda n: 1e-4)
    assert server.submit("t", np.zeros(3), now=0.0)[0] is True
    assert server.submit("t", np.zeros(3), now=0.0)[0] is True
    accepted, out = server.submit("t", np.zeros(3), now=0.0)
    assert accepted is False and out == []
    assert server.metrics.rejected == 1


def test_batch_dispatch_timing_and_size_cap():
    reg = EnsembleRegistry()
    snap = _stump_snapshot(reg, T=4, F=3)
    cfg = BatchConfig(adaptive=False, fixed_window_units=4,
                      base_window_s=1e-3, max_batch=2)
    server = EnsembleServer(reg, cfg, service_model=lambda n: 1e-4)
    rng = np.random.RandomState(0)
    accepted, out = server.submit("t", rng.randn(3), now=0.0)
    assert accepted and out == []
    assert server.advance(0.003) == []          # window (4ms) not expired
    out = server.advance(0.0041)                # expired -> dispatched
    assert len(out) == 1
    # size cap: the submit that fills max_batch dispatches immediately
    _, out = server.submit("t", rng.randn(3), now=0.01)
    assert out == []
    _, out = server.submit("t", rng.randn(3), now=0.01001)
    assert len(out) == 2
    assert server.metrics.batch_size_hist[2] == 1


def test_served_predictions_match_direct_eval_multi_tenant():
    reg = EnsembleRegistry()
    snaps = {name: _stump_snapshot(reg, tenant=name, T=3 + i, F=6,
                                   seed=i)
             for i, name in enumerate(["a", "b", "c"])}
    server = EnsembleServer(reg, BatchConfig(max_batch=32),
                            service_model=lambda n: 1e-4)
    rng = np.random.RandomState(7)
    xs, responses = [], []
    for i in range(30):
        tenant = "abc"[i % 3]
        x = rng.randn(6).astype(np.float32)
        xs.append((tenant, x))
        accepted, done = server.submit(tenant, x, now=1e-4 * i)
        assert accepted
        responses += done
    responses += server.drain()
    assert len(responses) == 30
    for r in responses:
        tenant, x = xs[r.rid]
        want = _direct_margin(snaps[tenant], x)
        assert r.margin == pytest.approx(want, abs=1e-5)
        assert r.label == (1.0 if want > 0 else -1.0)
        assert r.snapshot_version == snaps[tenant].version


def test_generic_weak_learner_path():
    reg = EnsembleRegistry()
    rng = np.random.RandomState(3)
    learners = tuple({"w": jnp.asarray(rng.randn(4), jnp.float32),
                      "b": jnp.asarray(rng.randn(), jnp.float32)}
                     for _ in range(3))
    alphas = [0.5, 0.3, 0.9]
    reg.publish(n := "log", learners, alphas, weak_name="logistic")
    server = EnsembleServer(reg, BatchConfig(), service_model=lambda n: 1e-4)
    x = rng.randn(4).astype(np.float32)
    server.submit(n, x, now=0.0)
    (resp,) = server.drain()
    want = sum(a * float(np.tanh(x @ np.asarray(p["w"]) + float(p["b"])))
               for p, a in zip(learners, alphas))
    assert resp.margin == pytest.approx(want, abs=1e-5)


def test_percentile_is_ceil_based_nearest_rank():
    """Pin the quantile rule: rank = ceil(q/100 * n), 1-based, clamped.
    The old int(round(...)) form used banker's rounding and drifted off
    the nearest rank on even-length lists (e.g. q=50 over 4 samples)."""
    from repro.serve.metrics import percentile
    table = [
        ([4.0], 50.0, 4.0),                   # singleton: any q
        ([1.0, 2.0], 50.0, 1.0),              # ceil(1.0) = rank 1
        ([1.0, 2.0], 75.0, 2.0),              # ceil(1.5) = rank 2
        ([1.0, 2.0, 3.0, 4.0], 25.0, 1.0),    # ceil(1.0) = rank 1
        ([1.0, 2.0, 3.0, 4.0], 50.0, 2.0),    # round() landed on 3 here
        ([1.0, 2.0, 3.0, 4.0], 75.0, 3.0),
        ([1.0, 2.0, 3.0, 4.0], 100.0, 4.0),
        ([1.0, 2.0, 3.0], 50.0, 2.0),         # odd length: true median
        ([float(v) for v in range(1, 101)], 99.0, 99.0),
        ([float(v) for v in range(1, 101)], 0.0, 1.0),   # rank clamps to 1
        ([], 99.0, 0.0),                      # empty: defined as 0
    ]
    for values, q, want in table:
        assert percentile(values, q) == want, (values, q)
    assert percentile([3.0, 1.0, 2.0], 50.0) == 2.0      # unsorted input


def test_cold_tenant_abstains_and_metrics_report():
    reg = EnsembleRegistry()
    server = EnsembleServer(reg, BatchConfig(), service_model=lambda n: 1e-4)
    server.submit("unknown", np.zeros(4, np.float32), now=0.0)
    (resp,) = server.drain()
    assert resp.margin == 0.0 and resp.snapshot_version == 0
    rep = server.metrics.report()
    assert rep["completed"] == 1
    assert rep["tenants"]["unknown"]["p99_ms"] >= 0.0
