"""Per-kernel allclose vs the pure-jnp oracles (ref.py), swept over shapes
and dtypes, kernels executed in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------- stump_scan

@pytest.mark.parametrize("N,F,T", [(64, 4, 3), (300, 20, 9), (513, 33, 16),
                                   (1024, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stump_scan_matches_ref(N, F, T, dtype):
    k = jax.random.split(jax.random.key(N * F + T), 4)
    x = jax.random.normal(k[0], (N, F), jnp.float32).astype(dtype)
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    w = jax.nn.softmax(jax.random.normal(k[2], (N,)))
    thr = jnp.sort(jax.random.normal(k[3], (F, T)), axis=1)
    got = ops.stump_scan(x.astype(jnp.float32), y, w, thr)
    want = ref.stump_scan_ref(x.astype(jnp.float32), y, w, thr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_stump_scan_block_sweep():
    k = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(k[0], (700, 24))
    y = jnp.sign(jax.random.normal(k[1], (700,)))
    w = jax.nn.softmax(jax.random.normal(k[2], (700,)))
    thr = jnp.sort(jax.random.normal(k[3], (24, 8)), axis=1)
    want = ref.stump_scan_ref(x, y, w, thr)
    for bn in (128, 256, 512):
        got = ops.stump_scan(x, y, w, thr, block_n=bn)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- ensemble_vote

@pytest.mark.parametrize("T,N", [(1, 16), (37, 1000), (128, 512),
                                 (200, 4096)])
def test_ensemble_vote_matches_ref(T, N):
    k = jax.random.split(jax.random.key(T * N), 2)
    m = jnp.sign(jax.random.normal(k[0], (T, N)))
    a = jax.random.normal(k[1], (T,))
    got = ops.ensemble_vote(m, a)
    want = ref.ensemble_vote_ref(m, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(min_value=1, max_value=50),
       st.integers(min_value=1, max_value=300))
@settings(max_examples=20, deadline=None)
def test_ensemble_vote_property(T, N):
    k = jax.random.split(jax.random.key(T * 1000 + N), 2)
    m = jnp.sign(jax.random.normal(k[0], (T, N)))
    a = jax.random.normal(k[1], (T,))
    got = ops.ensemble_vote(m, a)
    want = ref.ensemble_vote_ref(m, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- flash_attention

@pytest.mark.parametrize("B,H,T,d", [(1, 1, 128, 64), (2, 3, 256, 64),
                                     (1, 2, 512, 128), (2, 1, 384, 80)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, T, d, causal):
    k = jax.random.split(jax.random.key(B * H * T + d), 3)
    q = jax.random.normal(k[0], (B, H, T, d))
    kk = jax.random.normal(k[1], (B, H, T, d))
    v = jax.random.normal(k[2], (B, H, T, d))
    got = ops.flash_attention(q, kk, v, causal=causal)
    want = ref.flash_attention_ref(q, kk, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    k = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(k[0], (1, 2, 256, 64)).astype(dtype)
    kk = jax.random.normal(k[1], (1, 2, 256, 64)).astype(dtype)
    v = jax.random.normal(k[2], (1, 2, 256, 64)).astype(dtype)
    got = ops.flash_attention(q, kk, v)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   kk.astype(jnp.float32),
                                   v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)


def test_flash_attention_block_sweep():
    k = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(k[0], (1, 1, 512, 64))
    kk = jax.random.normal(k[1], (1, 1, 512, 64))
    v = jax.random.normal(k[2], (1, 1, 512, 64))
    want = ref.flash_attention_ref(q, kk, v)
    for bq, bk in [(64, 64), (128, 256), (256, 128), (512, 512)]:
        got = ops.flash_attention(q, kk, v, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
