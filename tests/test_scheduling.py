"""Adaptive communication scheduling (paper eq. 1): unit + property tests."""
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs.paper_fedboost import SchedulerConfig
from repro.core.scheduling import (
    HostScheduler, SchedulerState, adapt_interval, init_state)

CFG = SchedulerConfig(alpha=1.0, beta=2.0, theta1=0.001, theta2=0.01,
                      i_min=1, i_max=8, i_init=1)


def test_improving_error_widens_interval():
    s = HostScheduler(CFG)
    s.observe(0.5)
    s.observe(0.4)          # de = -0.1 < theta1 -> widen
    assert s.interval == 2.0


def test_regressing_error_shrinks_interval():
    s = HostScheduler(CFG)
    s.interval = 5.0
    s.observe(0.3)
    s.observe(0.5)          # de = +0.2 > theta2 -> shrink by beta
    assert s.interval == 3.0


def test_stable_error_widens():
    # a plateau (|de| < theta1) must widen -- that's when syncs stop paying
    s = HostScheduler(CFG)
    s.observe(0.3)
    s.observe(0.3)
    assert s.interval == 2.0


def test_dead_zone_keeps_interval():
    s = HostScheduler(CFG)
    s.observe(0.3)
    s.observe(0.305)        # theta1 < de < theta2 -> unchanged
    assert s.interval == 1.0


def test_bounded_interval():
    s = HostScheduler(CFG)
    s.observe(0.9)
    for _ in range(50):
        s.observe(0.1)      # keeps improving/stable
    assert s.interval == CFG.i_max
    for _ in range(50):
        s.observe(1.0)      # worst possible regressions
        s.prev_error = 0.0  # force de large positive every time
    assert s.interval >= CFG.i_min


def test_jax_and_host_equivalence():
    # error values chosen away from the theta thresholds: the host runs
    # float64, the jax path float32, and a delta landing exactly on theta2
    # (e.g. 0.31-0.30) classifies differently across precisions
    host = HostScheduler(CFG)
    state = init_state(CFG)
    errs = [0.5, 0.45, 0.45, 0.47, 0.3, 0.325, 0.29, 0.5, 0.1]
    for e in errs:
        host.observe(e)
        state = adapt_interval(state, e, CFG)
        assert abs(float(state.interval) - host.interval) < 1e-6


@given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1,
                max_size=60))
@settings(max_examples=60, deadline=None)
def test_interval_always_in_bounds(errors):
    """Property: under any error sequence the interval stays in
    [i_min, i_max] (paper's bounded-interval constraint)."""
    s = HostScheduler(CFG)
    for e in errors:
        s.observe(e)
        assert CFG.i_min <= s.interval <= CFG.i_max


@given(st.floats(min_value=0.0, max_value=1.0),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=60, deadline=None)
def test_monotone_response(e0, e1):
    """Property: a bigger error increase never yields a bigger interval."""
    s1, s2 = HostScheduler(CFG), HostScheduler(CFG)
    s1.interval = s2.interval = 4.0
    s1.observe(e0)
    s2.observe(e0)
    s1.observe(e1)
    s2.observe(min(e1 + 0.1, 1.0))
    assert s2.interval <= s1.interval
