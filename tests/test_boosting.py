"""AdaBoost core: distribution update, error bound, ensemble behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.boosting import (
    Ensemble, accuracy, ensemble_margin, fit_adaboost, update_distribution,
    weighted_error)
from repro.models.weak import get_weak_learner


def _toy(seed=0, n=400, f=8):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, f).astype(np.float32)
    y = np.where(x[:, 0] + 0.5 * x[:, 1] - 0.2 * x[:, 2] > 0, 1.0, -1.0)
    flip = rng.rand(n) < 0.05
    y[flip] *= -1
    return jnp.asarray(x), jnp.asarray(y.astype(np.float32))


def test_distribution_stays_normalized():
    x, y = _toy()
    D = jnp.full((x.shape[0],), 1.0 / x.shape[0])
    h = jnp.sign(x[:, 0])
    D2, Z = update_distribution(D, 0.7, y, h)
    assert float(jnp.sum(D2)) == pytest.approx(1.0, abs=1e-5)
    assert float(jnp.min(D2)) >= 0.0


def test_update_upweights_mistakes():
    x, y = _toy()
    D = jnp.full((x.shape[0],), 1.0 / x.shape[0])
    h = jnp.sign(x[:, 0])
    D2, _ = update_distribution(D, 0.7, y, h)
    miss = jnp.sign(h) != y
    assert float(jnp.mean(D2[miss])) > float(jnp.mean(D2[~miss]))


def test_training_error_bound():
    """AdaBoost guarantee: training error <= prod_t Z_t."""
    x, y = _toy()
    weak = get_weak_learner("stump")
    ens, zs = fit_adaboost(x, y, 12, weak)
    bound = float(np.prod(zs))
    train_err = ens.error(weak.predict, x, y)
    assert train_err <= bound + 1e-6
    assert bound < 1.0


def test_ensemble_beats_single_stump():
    x, y = _toy()
    weak = get_weak_learner("stump")
    ens1, _ = fit_adaboost(x, y, 1, weak)
    ens20, _ = fit_adaboost(x, y, 20, weak)
    assert ens20.error(weak.predict, x, y) < ens1.error(weak.predict, x, y)


def test_error_decreases_with_rounds():
    x, y = _toy(seed=3)
    weak = get_weak_learner("stump")
    errs = [fit_adaboost(x, y, t, weak)[0].error(weak.predict, x, y)
            for t in (2, 8, 24)]
    assert errs[2] <= errs[0]


@pytest.mark.parametrize("name", ["stump", "logistic", "mlp"])
def test_weak_learners_better_than_chance(name):
    x, y = _toy(seed=1)
    weak = get_weak_learner(name)
    D = jnp.full((x.shape[0],), 1.0 / x.shape[0])
    params = weak.fit(x, y, D, jax.random.key(0))
    h = weak.predict(params, x)
    assert float(weighted_error(D, y, h)) < 0.5
    assert weak.param_bytes(params) > 0


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=30, deadline=None)
def test_weighted_error_in_unit_interval(seed):
    rng = np.random.RandomState(seed % 2**31)
    n = 50
    D = rng.dirichlet(np.ones(n)).astype(np.float32)
    y = np.where(rng.rand(n) > 0.5, 1.0, -1.0).astype(np.float32)
    h = np.where(rng.rand(n) > 0.5, 1.0, -1.0).astype(np.float32)
    e = float(weighted_error(jnp.asarray(D), jnp.asarray(y), jnp.asarray(h)))
    assert -1e-6 <= e <= 1.0 + 1e-6


def test_ensemble_margin_linearity():
    m = jnp.asarray(np.random.RandomState(0).randn(5, 30), jnp.float32)
    a = jnp.asarray([0.5, 0.2, 0.9, 0.1, 0.3])
    lhs = ensemble_margin(m, a)
    rhs = sum(float(a[i]) * m[i] for i in range(5))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5)
