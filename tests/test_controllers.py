"""Beyond-paper controllers satisfy the same safety properties as eq. 1."""
import pytest
pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs.paper_fedboost import SchedulerConfig
from repro.core.controllers import BudgetScheduler, TrendScheduler

CFG = SchedulerConfig()


@pytest.mark.parametrize("make", [TrendScheduler, BudgetScheduler])
@given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1,
                max_size=60))
@settings(max_examples=40, deadline=None)
def test_bounded_interval(make, errors):
    s = make(CFG)
    for e in errors:
        s.observe(e)
        assert CFG.i_min <= s.interval <= CFG.i_max


def test_trend_widens_on_improvement_holds_on_plateau():
    s = TrendScheduler(CFG)
    s.observe(0.5)
    for e in (0.45, 0.4, 0.35, 0.3):   # sustained improvement -> widen
        s.observe(e)
    assert s.interval > 1.0
    level = s.interval
    for _ in range(5):                  # plateau -> hold (by design;
        s.observe(0.3)                  # drift-up variant measured worse)
    assert s.interval == pytest.approx(level, abs=1.0)


def test_budget_shrinks_on_regression():
    s = BudgetScheduler(CFG)
    s.interval = 8.0
    s.observe(0.2)
    for e in (0.3, 0.4, 0.5):
        s.observe(e)
    assert s.interval < 8.0
