"""Backend parity: every public kernel must produce identical
(atol-bounded) outputs on every *available* dispatch backend, asserted
against the kernels/ref.py oracles — including the padded/ragged shapes
exercised by test_vote_padding.py and, for the Pallas backends, every
block layout in the autotune sweep grid (LAYOUT_GRIDS): a layout the
calibrator may pick must never change the answer.  On CPU this covers
'interpret' and 'xla'; on TPU 'mosaic' joins the matrix automatically.

Deliberately hypothesis-free: this coverage must run even in containers
without the property-testing extras."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dispatch import LAYOUT_GRIDS, available_backends

BACKENDS = available_backends()
# layouts only reshape the Pallas grids; the xla oracle ignores them
PALLAS = [b for b in BACKENDS if b != "xla"]

def _lid(layout):
    return ",".join(f"{k.replace('block_', '')}{v}"
                    for k, v in sorted(layout.items()))


def _assert_close(got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=atol)


# ------------------------------------------------------------- stump_scan

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("N,F,T", [(50, 5, 6), (256, 8, 8), (300, 17, 9)])
def test_stump_scan_parity(backend, N, F, T):
    k = jax.random.split(jax.random.key(N + F + T), 4)
    x = jax.random.normal(k[0], (N, F))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    w = jax.nn.softmax(jax.random.normal(k[2], (N,)))
    thr = jnp.sort(jax.random.normal(k[3], (F, T)), axis=1)
    got = ops.stump_scan(x, y, w, thr, backend=backend)
    _assert_close(got, ref.stump_scan_ref(x, y, w, thr))


# ------------------------------------------------------- vote family (2-D)

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("T,N", [(1, 1), (7, 100), (130, 513)])
def test_ensemble_vote_parity(backend, T, N):
    k = jax.random.split(jax.random.key(T * N), 2)
    m = jnp.sign(jax.random.normal(k[0], (T, N)))
    a = jax.random.normal(k[1], (T,))
    got = ops.ensemble_vote(m, a, backend=backend)
    _assert_close(got, ref.ensemble_vote_ref(m, a))


# --------------------------------------------------- batched serving votes

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B,T,N", [(1, 1, 1), (2, 37, 100), (4, 129, 513)])
def test_ensemble_vote_batched_parity(backend, B, T, N):
    k = jax.random.split(jax.random.key(B * T * N), 2)
    m = jnp.sign(jax.random.normal(k[0], (B, T, N)))
    a = jax.random.normal(k[1], (B, T))
    got = ops.ensemble_vote_batched(m, a, backend=backend)
    assert got.shape == (B, N)
    _assert_close(got, ref.ensemble_vote_batched_ref(m, a))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B,T,N", [(1, 5, 40), (2, 77, 333)])
def test_stump_vote_batched_parity(backend, B, T, N):
    k = jax.random.split(jax.random.key(B + T + N), 4)
    xsel = jax.random.normal(k[0], (B, T, N))
    thr = jax.random.normal(k[1], (B, T))
    pol = jnp.sign(jax.random.normal(k[2], (B, T)) + 0.1)
    a = jax.random.normal(k[3], (B, T))
    got = ops.stump_vote_batched(xsel, thr, pol, a, backend=backend)
    assert got.shape == (B, N)
    _assert_close(got, ref.stump_vote_batched_ref(xsel, thr, pol, a))


# --------------------------------------------------------- flash_attention

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("T,d,causal", [(64, 32, True), (128, 128, False)])
def test_flash_attention_parity(backend, T, d, causal):
    k = jax.random.split(jax.random.key(T + d), 3)
    q = jax.random.normal(k[0], (1, 2, T, d), jnp.float32)
    kk = jax.random.normal(k[1], (1, 2, T, d), jnp.float32)
    v = jax.random.normal(k[2], (1, 2, T, d), jnp.float32)
    got = ops.flash_attention(q, kk, v, causal=causal, backend=backend)
    _assert_close(got, ref.flash_attention_ref(q, kk, v, causal=causal),
                  atol=2e-4)


# ------------------------------------------------------------- dist_update

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("N", [100, 1024, 1500])
def test_dist_update_parity(backend, N):
    k = jax.random.split(jax.random.key(N), 3)
    D = jax.nn.softmax(jax.random.normal(k[0], (N,)))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    h = jnp.sign(jax.random.normal(k[2], (N,)))
    got_D, got_Z = ops.dist_update(0.7, D, y, h, backend=backend)
    want_D, want_Z = ref.dist_update_ref(0.7, D, y, h)
    _assert_close(got_D, want_D, atol=1e-6)
    assert float(got_Z) == pytest.approx(float(want_Z), rel=1e-5)
    assert float(jnp.sum(got_D)) == pytest.approx(1.0, abs=1e-5)


# ------------------------------------------ fused vote + fingerprint kernel

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B,T,N", [(1, 1, 1), (2, 37, 100), (3, 77, 333)])
def test_stump_vote_fp_batched_parity(backend, B, T, N):
    k = jax.random.split(jax.random.key(B * 7 + T + N), 4)
    xsel = jax.random.normal(k[0], (B, T, N))
    thr = jax.random.normal(k[1], (B, T))
    pol = jnp.sign(jax.random.normal(k[2], (B, T)) + 0.1)
    a = jax.random.normal(k[3], (B, T))
    got_m, got_f0, got_f1 = ops.stump_vote_fp_batched(
        xsel, thr, pol, a, backend=backend)
    want_m, want_f0, want_f1 = ref.stump_vote_fp_batched_ref(
        xsel, thr, pol, a)
    assert got_m.shape == (B, N)
    _assert_close(got_m, want_m)
    # fingerprints are integer lanes: bit-exact across every backend and
    # layout or they are useless as cache keys
    assert np.array_equal(np.asarray(got_f0), np.asarray(want_f0))
    assert np.array_equal(np.asarray(got_f1), np.asarray(want_f1))
    assert got_f0.dtype == jnp.uint32 and got_f1.dtype == jnp.uint32


def test_stump_vote_fp_margin_matches_plain_vote():
    """The fused kernel's margin lane is the same number the two-kernel
    path produces — fusing the fingerprint must not perturb predictions."""
    B, T, N = 2, 41, 207
    k = jax.random.split(jax.random.key(11), 4)
    xsel = jax.random.normal(k[0], (B, T, N))
    thr = jax.random.normal(k[1], (B, T))
    pol = jnp.sign(jax.random.normal(k[2], (B, T)) + 0.1)
    a = jax.random.normal(k[3], (B, T))
    for be in BACKENDS:
        m_fused, _, _ = ops.stump_vote_fp_batched(xsel, thr, pol, a,
                                                  backend=be)
        m_plain = ops.stump_vote_batched(xsel, thr, pol, a, backend=be)
        _assert_close(m_fused, m_plain)


# ----------------------------------------------- layout sweep x ragged shape

@pytest.mark.parametrize("backend", PALLAS)
@pytest.mark.parametrize("layout", LAYOUT_GRIDS["stump_scan"], ids=_lid)
def test_stump_scan_layout_sweep_parity(backend, layout):
    k = jax.random.split(jax.random.key(3), 4)
    x = jax.random.normal(k[0], (300, 7))
    y = jnp.sign(jax.random.normal(k[1], (300,)))
    w = jax.nn.softmax(jax.random.normal(k[2], (300,)))
    thr = jnp.sort(jax.random.normal(k[3], (7, 9)), axis=1)
    got = ops.stump_scan(x, y, w, thr, backend=backend, **layout)
    _assert_close(got, ref.stump_scan_ref(x, y, w, thr))


@pytest.mark.parametrize("backend", PALLAS)
@pytest.mark.parametrize("layout", LAYOUT_GRIDS["stump_vote_batched"],
                         ids=_lid)
def test_stump_vote_layout_sweep_parity(backend, layout):
    B, T, N = 2, 77, 333
    k = jax.random.split(jax.random.key(5), 4)
    xsel = jax.random.normal(k[0], (B, T, N))
    thr = jax.random.normal(k[1], (B, T))
    pol = jnp.sign(jax.random.normal(k[2], (B, T)) + 0.1)
    a = jax.random.normal(k[3], (B, T))
    got = ops.stump_vote_batched(xsel, thr, pol, a, backend=backend,
                                 **layout)
    _assert_close(got, ref.stump_vote_batched_ref(xsel, thr, pol, a))


@pytest.mark.parametrize("backend", PALLAS)
@pytest.mark.parametrize("layout", LAYOUT_GRIDS["stump_vote_fp_batched"],
                         ids=_lid)
def test_stump_vote_fp_layout_sweep_parity(backend, layout):
    """Fingerprint lanes must be bit-identical under every swept layout:
    the xor-fold is associative and zero-alpha padding rows are the XOR
    identity, so block shape cannot leak into the digest."""
    B, T, N = 2, 41, 207
    k = jax.random.split(jax.random.key(9), 4)
    xsel = jax.random.normal(k[0], (B, T, N))
    thr = jax.random.normal(k[1], (B, T))
    pol = jnp.sign(jax.random.normal(k[2], (B, T)) + 0.1)
    a = jax.random.normal(k[3], (B, T))
    got_m, got_f0, got_f1 = ops.stump_vote_fp_batched(
        xsel, thr, pol, a, backend=backend, **layout)
    want_m, want_f0, want_f1 = ref.stump_vote_fp_batched_ref(
        xsel, thr, pol, a)
    _assert_close(got_m, want_m)
    assert np.array_equal(np.asarray(got_f0), np.asarray(want_f0))
    assert np.array_equal(np.asarray(got_f1), np.asarray(want_f1))


@pytest.mark.parametrize("backend", PALLAS)
@pytest.mark.parametrize("layout", LAYOUT_GRIDS["ensemble_vote"], ids=_lid)
def test_ensemble_vote_layout_sweep_parity(backend, layout):
    k = jax.random.split(jax.random.key(13), 2)
    m = jnp.sign(jax.random.normal(k[0], (130, 513)))
    a = jax.random.normal(k[1], (130,))
    got = ops.ensemble_vote(m, a, backend=backend, **layout)
    _assert_close(got, ref.ensemble_vote_ref(m, a))


@pytest.mark.parametrize("backend", PALLAS)
@pytest.mark.parametrize("layout", LAYOUT_GRIDS["dist_update"], ids=_lid)
def test_dist_update_layout_sweep_parity(backend, layout):
    N = 1500
    k = jax.random.split(jax.random.key(N), 3)
    D = jax.nn.softmax(jax.random.normal(k[0], (N,)))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    h = jnp.sign(jax.random.normal(k[2], (N,)))
    got_D, got_Z = ops.dist_update(0.7, D, y, h, backend=backend, **layout)
    want_D, want_Z = ref.dist_update_ref(0.7, D, y, h)
    _assert_close(got_D, want_D, atol=1e-6)
    assert float(got_Z) == pytest.approx(float(want_Z), rel=1e-5)


@pytest.mark.parametrize("backend", PALLAS)
@pytest.mark.parametrize("T", [96, 192, 320])
@pytest.mark.parametrize("layout", LAYOUT_GRIDS["flash_attention"],
                         ids=_lid)
def test_flash_layout_sweep_parity_non_divisible_T(backend, T, layout):
    """T values where the swept block sizes do NOT divide the sequence:
    _flash_blocks must clamp to the largest divisor <= requested, never
    crash or mis-tile (satellite: largest-divisor fallback)."""
    k = jax.random.split(jax.random.key(T), 3)
    q = jax.random.normal(k[0], (1, 2, T, 32), jnp.float32)
    kk = jax.random.normal(k[1], (1, 2, T, 32), jnp.float32)
    v = jax.random.normal(k[2], (1, 2, T, 32), jnp.float32)
    got = ops.flash_attention(q, kk, v, causal=True, backend=backend,
                              **layout)
    _assert_close(got, ref.flash_attention_ref(q, kk, v, causal=True),
                  atol=2e-4)


# ------------------------------------------- cross-backend agreement (all)

def test_all_backends_agree_on_ragged_vote():
    """Pairwise agreement (not just vs ref) on a ragged batched case."""
    B, T, N = 3, 41, 207
    k = jax.random.split(jax.random.key(7), 2)
    m = jnp.sign(jax.random.normal(k[0], (B, T, N)))
    a = jax.random.normal(k[1], (B, T))
    outs = {be: np.asarray(ops.ensemble_vote_batched(m, a, backend=be))
            for be in BACKENDS}
    base = outs[BACKENDS[0]]
    for be, out in outs.items():
        np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{be} vs {BACKENDS[0]}")
