"""Backend parity: every public kernel must produce identical
(atol-bounded) outputs on every *available* dispatch backend, asserted
against the kernels/ref.py oracles — including the padded/ragged shapes
exercised by test_vote_padding.py.  On CPU this covers 'interpret' and
'xla'; on TPU 'mosaic' joins the matrix automatically.

Deliberately hypothesis-free: this coverage must run even in containers
without the property-testing extras."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dispatch import available_backends

BACKENDS = available_backends()


def _assert_close(got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=atol)


# ------------------------------------------------------------- stump_scan

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("N,F,T", [(50, 5, 6), (256, 8, 8), (300, 17, 9)])
def test_stump_scan_parity(backend, N, F, T):
    k = jax.random.split(jax.random.key(N + F + T), 4)
    x = jax.random.normal(k[0], (N, F))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    w = jax.nn.softmax(jax.random.normal(k[2], (N,)))
    thr = jnp.sort(jax.random.normal(k[3], (F, T)), axis=1)
    got = ops.stump_scan(x, y, w, thr, backend=backend)
    _assert_close(got, ref.stump_scan_ref(x, y, w, thr))


# ------------------------------------------------------- vote family (2-D)

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("T,N", [(1, 1), (7, 100), (130, 513)])
def test_ensemble_vote_parity(backend, T, N):
    k = jax.random.split(jax.random.key(T * N), 2)
    m = jnp.sign(jax.random.normal(k[0], (T, N)))
    a = jax.random.normal(k[1], (T,))
    got = ops.ensemble_vote(m, a, backend=backend)
    _assert_close(got, ref.ensemble_vote_ref(m, a))


# --------------------------------------------------- batched serving votes

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B,T,N", [(1, 1, 1), (2, 37, 100), (4, 129, 513)])
def test_ensemble_vote_batched_parity(backend, B, T, N):
    k = jax.random.split(jax.random.key(B * T * N), 2)
    m = jnp.sign(jax.random.normal(k[0], (B, T, N)))
    a = jax.random.normal(k[1], (B, T))
    got = ops.ensemble_vote_batched(m, a, backend=backend)
    assert got.shape == (B, N)
    _assert_close(got, ref.ensemble_vote_batched_ref(m, a))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B,T,N", [(1, 5, 40), (2, 77, 333)])
def test_stump_vote_batched_parity(backend, B, T, N):
    k = jax.random.split(jax.random.key(B + T + N), 4)
    xsel = jax.random.normal(k[0], (B, T, N))
    thr = jax.random.normal(k[1], (B, T))
    pol = jnp.sign(jax.random.normal(k[2], (B, T)) + 0.1)
    a = jax.random.normal(k[3], (B, T))
    got = ops.stump_vote_batched(xsel, thr, pol, a, backend=backend)
    assert got.shape == (B, N)
    _assert_close(got, ref.stump_vote_batched_ref(xsel, thr, pol, a))


# --------------------------------------------------------- flash_attention

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("T,d,causal", [(64, 32, True), (128, 128, False)])
def test_flash_attention_parity(backend, T, d, causal):
    k = jax.random.split(jax.random.key(T + d), 3)
    q = jax.random.normal(k[0], (1, 2, T, d), jnp.float32)
    kk = jax.random.normal(k[1], (1, 2, T, d), jnp.float32)
    v = jax.random.normal(k[2], (1, 2, T, d), jnp.float32)
    got = ops.flash_attention(q, kk, v, causal=causal, backend=backend)
    _assert_close(got, ref.flash_attention_ref(q, kk, v, causal=causal),
                  atol=2e-4)


# ------------------------------------------------------------- dist_update

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("N", [100, 1024, 1500])
def test_dist_update_parity(backend, N):
    k = jax.random.split(jax.random.key(N), 3)
    D = jax.nn.softmax(jax.random.normal(k[0], (N,)))
    y = jnp.sign(jax.random.normal(k[1], (N,)))
    h = jnp.sign(jax.random.normal(k[2], (N,)))
    got_D, got_Z = ops.dist_update(0.7, D, y, h, backend=backend)
    want_D, want_Z = ref.dist_update_ref(0.7, D, y, h)
    _assert_close(got_D, want_D, atol=1e-6)
    assert float(got_Z) == pytest.approx(float(want_Z), rel=1e-5)
    assert float(jnp.sum(got_D)) == pytest.approx(1.0, abs=1e-5)


# ------------------------------------------- cross-backend agreement (all)

def test_all_backends_agree_on_ragged_vote():
    """Pairwise agreement (not just vs ref) on a ragged batched case."""
    B, T, N = 3, 41, 207
    k = jax.random.split(jax.random.key(7), 2)
    m = jnp.sign(jax.random.normal(k[0], (B, T, N)))
    a = jax.random.normal(k[1], (B, T))
    outs = {be: np.asarray(ops.ensemble_vote_batched(m, a, backend=be))
            for be in BACKENDS}
    base = outs[BACKENDS[0]]
    for be, out in outs.items():
        np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{be} vs {BACKENDS[0]}")
