"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers, d_model<=512, <=4 experts) runs one forward/train step and one
prefill+decode step on CPU; output shapes asserted, no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation) — see launch/dryrun.py and tests/test_dryrun_subprocess.py.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig, reduced
from repro.configs.registry import ARCHS
from repro.models import Model, concrete_inputs

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def smoke_models():
    out = {}
    for name in ARCH_NAMES:
        cfg = reduced(ARCHS[name])
        m = Model(cfg)
        out[name] = (cfg, m, m.init(jax.random.key(0)))
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_is_reduced(name):
    cfg = reduced(ARCHS[name])
    assert cfg.n_layers <= 2 or cfg.n_encoder_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step(smoke_models, name):
    cfg, model, params = smoke_models[name]
    batch = concrete_inputs(cfg, ShapeConfig("t", 32, 2, "train"),
                            jax.random.key(1), batch_override=2,
                            seq_override=32)
    loss, mets = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert float(mets["ce"]) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_and_decode(smoke_models, name):
    cfg, model, params = smoke_models[name]
    B, T = 2, 32
    pb = concrete_inputs(cfg, ShapeConfig("p", T, B, "prefill"),
                         jax.random.key(2), batch_override=B,
                         seq_override=T)
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_seq=2 * T))(params, pb)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    tok = jnp.zeros((B, 1), jnp.int32)
    dlogits, new_caches = jax.jit(model.decode_step)(
        params, tok, caches, jnp.asarray(T, jnp.int32))
    assert dlogits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(dlogits)))
    # cache structure unchanged
    assert (jax.tree_util.tree_structure(caches)
            == jax.tree_util.tree_structure(new_caches))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_gradients_flow(smoke_models, name):
    cfg, model, params = smoke_models[name]
    batch = concrete_inputs(cfg, ShapeConfig("t", 16, 2, "train"),
                            jax.random.key(3), batch_override=2,
                            seq_override=16)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads)]
    assert all(not jnp.isnan(n) for n in norms)
    assert sum(norms) > 0            # something learns
