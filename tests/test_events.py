"""The event-queue virtual clock (repro.core.events) and the staleness-
decay family it feeds (repro.core.compensation): pinned pop order for tied
events, monotone time, and the FedAsync decay functions."""
import heapq
import math

import numpy as np
import pytest

from repro.configs.paper_fedboost import CompensationConfig
from repro.core import events
from repro.core.compensation import (DECAYS, compensate, staleness_scale)


# ------------------------------------------------------------ VirtualClock
def test_pop_orders_by_time_first():
    vc = events.VirtualClock()
    vc.push(3.0, events.ARRIVAL, cid=0)
    vc.push(1.0, events.BARRIER, cid=9)
    vc.push(2.0, events.ROUND, cid=5)
    assert [vc.pop().t for _ in range(3)] == [1.0, 2.0, 3.0]


def test_tied_time_pops_in_kind_order():
    """At equal t, arrivals must drain before the barrier that closes over
    them, and trace markers (round/stall) come first."""
    vc = events.VirtualClock()
    vc.push(1.0, events.BARRIER)
    vc.push(1.0, events.ROUND, cid=2)
    vc.push(1.0, events.ARRIVAL, cid=1)
    vc.push(1.0, events.STALL, cid=3)
    vc.push(1.0, events.TRIGGER, cid=4)
    kinds = [vc.pop().kind for _ in range(5)]
    assert kinds == [events.ROUND, events.STALL, events.TRIGGER,
                     events.ARRIVAL, events.BARRIER]


def test_tied_sync_events_pop_in_client_order():
    """Two sync messages landing at the same instant merge in client
    order — the legacy engine's (arrival, cid) heap order, pinned."""
    vc = events.VirtualClock()
    for cid in (7, 2, 5, 0):
        vc.push(4.25, events.ARRIVAL, cid=cid, payload=f"msg{cid}")
    assert [vc.pop().cid for _ in range(4)] == [0, 2, 5, 7]


def test_tied_time_kind_cid_falls_back_to_push_order():
    vc = events.VirtualClock()
    a = vc.push(1.0, events.ARRIVAL, cid=3, payload="first")
    b = vc.push(1.0, events.ARRIVAL, cid=3, payload="second")
    assert a.seq < b.seq
    assert [vc.pop().payload for _ in range(2)] == ["first", "second"]


def test_matches_legacy_heap_order():
    """The legacy enhanced loop ordered sync messages by (arrival, cid);
    the clock's (t, kind, cid, seq) key must reproduce that order exactly
    for arrival-only workloads."""
    rng = np.random.RandomState(0)
    ts = rng.uniform(0, 5, size=40).round(1)   # force plenty of ties
    cids = rng.randint(0, 6, size=40)
    legacy = []
    vc = events.VirtualClock()
    for t, cid in zip(ts, cids):
        heapq.heappush(legacy, (float(t), int(cid)))
        vc.push(float(t), events.ARRIVAL, cid=int(cid))
    for _ in range(40):
        lt, lcid = heapq.heappop(legacy)
        ev = vc.pop()
        assert (ev.t, ev.cid) == (lt, lcid)


def test_now_is_monotone_and_counts():
    vc = events.VirtualClock()
    vc.push(2.0, events.ROUND)
    vc.push(1.0, events.ROUND)
    assert len(vc) == 2 and vc.n_pushed == 2
    vc.pop()
    assert vc.now == 1.0
    vc.push(0.5, events.ROUND)   # scheduled in the past: now must not regress
    vc.pop()
    assert vc.now == 1.0
    vc.pop()
    assert vc.now == 2.0 and vc.n_popped == 3 and not vc


def test_payloads_never_compared():
    """Unorderable payloads must be fine even on full key ties minus seq."""
    vc = events.VirtualClock()
    vc.push(1.0, events.ARRIVAL, cid=1, payload={"a": 1})
    vc.push(1.0, events.ARRIVAL, cid=1, payload=object())
    vc.pop(), vc.pop()


def test_peek_does_not_pop():
    vc = events.VirtualClock()
    vc.push(1.0, events.TRIGGER, payload="x")
    assert vc.peek().payload == "x"
    assert len(vc) == 1
    assert vc.pop().payload == "x"
    assert vc.peek() is None


def test_kind_names():
    assert events.Event(0.0, events.BARRIER, -1, 0).kind_name == "barrier"


# ------------------------------------------------------ staleness decays
CFG = CompensationConfig()


def test_exp_decay_matches_eq2():
    for tau in (0, 1, 5, 31):
        assert staleness_scale(tau, CFG) == pytest.approx(
            math.exp(-CFG.lam * tau))


def test_constant_decay_is_one():
    cfg = CompensationConfig(decay="constant")
    for tau in (0, 3, 100):
        assert staleness_scale(tau, cfg) == 1.0


def test_hinge_decay_boundary():
    cfg = CompensationConfig(decay="hinge", hinge_a=10.0, hinge_b=6.0)
    assert staleness_scale(0, cfg) == 1.0
    assert staleness_scale(6, cfg) == 1.0                 # grace boundary
    assert staleness_scale(8, cfg) == pytest.approx(1.0 / (10.0 * 2.0))


def test_poly_decay():
    cfg = CompensationConfig(decay="poly", poly_a=0.5)
    assert staleness_scale(0, cfg) == 1.0
    assert staleness_scale(3, cfg) == pytest.approx(4.0 ** -0.5)


def test_tau_cap_applies_to_every_family():
    for decay in DECAYS:
        cfg = CompensationConfig(decay=decay, tau_cap=10)
        assert staleness_scale(50, cfg) == staleness_scale(10, cfg)
        assert staleness_scale(-3, cfg) == staleness_scale(0, cfg)


def test_compensate_agrees_with_scalar_path():
    """The jnp compensate and the python-scalar staleness_scale must agree
    for every family (the fleet profile uses the scalar path)."""
    for decay in DECAYS:
        cfg = CompensationConfig(decay=decay)
        for tau in (0, 1, 6, 7, 40):
            want = 0.7 * staleness_scale(tau, cfg)
            got = float(compensate(0.7, tau, cfg))
            assert got == pytest.approx(want, rel=1e-5), (decay, tau)


def test_unknown_decay_raises():
    cfg = CompensationConfig(decay="bogus")
    with pytest.raises(KeyError):
        staleness_scale(1, cfg)
    with pytest.raises(KeyError):
        compensate(1.0, 1, cfg)
