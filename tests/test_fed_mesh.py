"""Mesh-integrated federated boosting (shard_map) — run in a subprocess with
8 placeholder devices so the main pytest process keeps its 1-device view."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs.paper_fedboost import FedBoostConfig
    from repro.sim.scenarios import DOMAINS
    from repro.core import fed_mesh
    from repro.data import make_domain_data
    from repro.models.weak import stump_thresholds

    K = 8
    dom = dataclasses.replace(DOMAINS['edge_vision'], n_clients=K)
    data = make_domain_data(dom, seed=0)
    n_local = min(c[0].shape[0] for c in data['clients'])
    x = jnp.stack([c[0][:n_local] for c in data['clients']])
    y = jnp.stack([c[1][:n_local] for c in data['clients']])
    xv_full, yv_full = data['val']
    nvl = xv_full.shape[0] // K
    xv = xv_full[:K*nvl].reshape(K, nvl, -1)
    yv = yv_full[:K*nvl].reshape(K, nvl)

    mesh = jax.make_mesh((K,), ("clients",))
    cfg = FedBoostConfig(n_clients=K)
    thr = stump_thresholds(x.reshape(-1, x.shape[-1]))
    step = fed_mesh.make_fed_boost_step(cfg, mesh, "clients", thr)
    state = fed_mesh.init_state(cfg, K, n_local, nvl, buffer_cap=8,
                                ens_cap=1024, key=jax.random.key(0))
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      fed_mesh.state_shardings(mesh, "clients"),
                      is_leaf=lambda v: isinstance(v, P))
    dsh = NamedSharding(mesh, P("clients"))
    state = jax.device_put(state, sh)
    x, y, xv, yv = (jax.device_put(a, dsh) for a in (x, y, xv, yv))
    jstep = jax.jit(step, donate_argnums=0)
    intervals = []
    for r in range(40):
        state = jstep(state, x, y, xv, yv)
        intervals.append(float(state.interval))
    print(json.dumps({
        "ens_count": int(state.ens_count),
        "syncs": int(state.sync_count),
        "interval_first": intervals[0],
        "interval_last": intervals[-1],
        "val_err": float(state.prev_err),
        "counter": int(state.counter),
    }))
""")


@pytest.fixture(scope="module")
def fed_mesh_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_fed_mesh_learns(fed_mesh_result):
    # well below chance (0.5) and the majority-class floor (~0.39 for this
    # dataset); the mesh mode holds up to i_max*cap learners unflushed at
    # the horizon, so it trails the event-driven engine slightly
    assert fed_mesh_result["val_err"] < 0.38


def test_fed_mesh_adaptive_interval_grows(fed_mesh_result):
    # on a converging problem the plateau must widen the interval
    assert fed_mesh_result["interval_last"] > fed_mesh_result["interval_first"]


def test_fed_mesh_syncs_fewer_than_rounds(fed_mesh_result):
    # scheduled skipping: far fewer collectives than boosting rounds
    assert fed_mesh_result["syncs"] < fed_mesh_result["counter"]
    assert fed_mesh_result["ens_count"] > 0
