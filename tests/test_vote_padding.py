"""kernels/ops.py padding contract for the ensemble-vote family, incl. the
batched serving variants: padded zero-alpha learner rows and padded sample
columns must not perturb the result vs the kernels/ref.py oracles.

Deliberately hypothesis-free: this coverage must run even in containers
without the property-testing extras."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _case(key, B, T, N):
    k = jax.random.split(key, 4)
    m = jnp.sign(jax.random.normal(k[0], (B, T, N)))
    a = jax.random.normal(k[1], (B, T))
    xsel = jax.random.normal(k[2], (B, T, N))
    thr = jax.random.normal(k[3], (B, T))
    pol = jnp.sign(jax.random.normal(k[0], (B, T)) + 0.1)
    return m, a, xsel, thr, pol


# --------------------------------------------------- 2-D wrapper (existing)

@pytest.mark.parametrize("T,N", [(1, 1), (7, 100), (128, 512), (130, 513),
                                 (200, 4096)])
def test_ensemble_vote_padding_vs_ref(T, N):
    m, a, *_ = _case(jax.random.key(T * N + 1), 1, T, N)
    got = ops.ensemble_vote(m[0], a[0])
    want = ref.ensemble_vote_ref(m[0], a[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ensemble_vote_explicit_zero_padding_invariance():
    """Manually appending zero-alpha rows and dummy columns must reproduce
    the unpadded result on the original region."""
    m, a, *_ = _case(jax.random.key(0), 1, 37, 210)
    m, a = m[0], a[0]
    base = np.asarray(ops.ensemble_vote(m, a))
    mp = jnp.pad(m, ((0, 11), (0, 46)), constant_values=7.7)  # junk columns
    ap = jnp.pad(a, (0, 11))                                  # zero alphas
    padded = np.asarray(ops.ensemble_vote(mp, ap))
    np.testing.assert_allclose(padded[:210], base, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        padded[:210], np.asarray(ref.ensemble_vote_ref(m, a)),
        rtol=1e-5, atol=1e-5)


# ------------------------------------------------- batched serving variants

@pytest.mark.parametrize("B,T,N", [(1, 1, 1), (2, 37, 100), (3, 128, 512),
                                   (4, 129, 513), (2, 200, 1500)])
def test_ensemble_vote_batched_matches_ref(B, T, N):
    m, a, *_ = _case(jax.random.key(B * T * N), B, T, N)
    got = ops.ensemble_vote_batched(m, a)
    want = ref.ensemble_vote_batched_ref(m, a)
    assert got.shape == (B, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,T,N", [(1, 5, 40), (3, 64, 640), (2, 77, 333)])
def test_stump_vote_batched_matches_ref(B, T, N):
    _, a, xsel, thr, pol = _case(jax.random.key(B + T + N), B, T, N)
    got = ops.stump_vote_batched(xsel, thr, pol, a)
    want = ref.stump_vote_batched_ref(xsel, thr, pol, a)
    assert got.shape == (B, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_batched_explicit_zero_padding_invariance():
    B, T, N = 2, 23, 77
    m, a, xsel, thr, pol = _case(jax.random.key(9), B, T, N)
    base_vote = np.asarray(ops.ensemble_vote_batched(m, a))
    base_stump = np.asarray(ops.stump_vote_batched(xsel, thr, pol, a))
    # zero-alpha learner rows with junk margins/thresholds + junk columns
    mp = jnp.pad(m, ((0, 0), (0, 9), (0, 51)), constant_values=-3.3)
    ap = jnp.pad(a, ((0, 0), (0, 9)))
    xp = jnp.pad(xsel, ((0, 0), (0, 9), (0, 51)), constant_values=5.5)
    tp = jnp.pad(thr, ((0, 0), (0, 9)), constant_values=-2.0)
    pp = jnp.pad(pol, ((0, 0), (0, 9)), constant_values=-1.0)
    np.testing.assert_allclose(
        np.asarray(ops.ensemble_vote_batched(mp, ap))[:, :N], base_vote,
        rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ops.stump_vote_batched(xp, tp, pp, ap))[:, :N],
        base_stump, rtol=1e-6, atol=1e-6)


def test_batched_agrees_with_2d_per_slot():
    """Each slot of the batched vote equals the 2-D kernel on that slot."""
    B, T, N = 3, 50, 300
    m, a, *_ = _case(jax.random.key(4), B, T, N)
    batched = np.asarray(ops.ensemble_vote_batched(m, a))
    for b in range(B):
        np.testing.assert_allclose(
            batched[b], np.asarray(ops.ensemble_vote(m[b], a[b])),
            rtol=1e-5, atol=1e-5)


def test_stump_vote_matches_training_predictor():
    """The fused kernel reproduces models.weak.predict_stump margins."""
    from repro.models.weak import predict_stump
    key = jax.random.key(11)
    x = jax.random.normal(key, (60, 12))
    params = [{"feature": jnp.asarray(f % 12, jnp.int32),
               "threshold": jnp.asarray(0.1 * f - 0.4),
               "polarity": jnp.asarray(1.0 if f % 2 else -1.0)}
              for f in range(7)]
    a = jnp.linspace(0.2, 1.4, 7)
    want = sum(float(a[i]) * np.asarray(predict_stump(p, x))
               for i, p in enumerate(params))
    feat = jnp.asarray([int(p["feature"]) for p in params], jnp.int32)
    xsel = x[:, feat].T[None]                       # (1, 7, 60)
    thr = jnp.asarray([[float(p["threshold"]) for p in params]])
    pol = jnp.asarray([[float(p["polarity"]) for p in params]])
    got = np.asarray(ops.stump_vote_batched(xsel, thr, pol, a[None]))[0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
