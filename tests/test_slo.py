"""SLO error budgets + multi-window burn-rate alerting (repro.obs.slo)."""
import pytest

import repro.obs as obs
from repro.obs.slo import (AlertLog, BurnRateRule, ErrorBudget, SLObjective,
                           SLOMonitor, default_rules)


def _obj(**kw):
    base = dict(tenant="t0", latency_threshold_s=0.02, target=0.95,
                window_s=1.0)
    base.update(kw)
    return SLObjective(**base)


# ----------------------------------------------------------------- objective
def test_budget_fraction_is_target_complement():
    assert _obj(target=0.99).budget_fraction == pytest.approx(0.01)
    assert _obj(target=0.95).budget_fraction == pytest.approx(0.05)
    # a 100% target still yields a positive (tiny) budget, never div-by-zero
    assert _obj(target=1.0).budget_fraction > 0.0


def test_default_rules_scale_with_window():
    page, ticket = default_rules(_obj(window_s=2.0))
    assert (page.long_s, page.short_s, page.factor) == (0.5, 0.125, 8.0)
    assert (ticket.long_s, ticket.short_s, ticket.factor) == (2.0, 0.5, 2.0)
    # page is the faster, higher-threshold rule
    assert page.short_s < ticket.short_s and page.factor > ticket.factor


# -------------------------------------------------------------- error budget
def test_error_budget_exact_totals_and_windowed_counts():
    b = ErrorBudget(_obj(), horizon_s=1.0)
    for i in range(10):
        b.record(0.1 * i, good=(i % 2 == 0))
    assert (b.good_total, b.bad_total, b.total) == (5, 5, 10)
    # window (0.4, 0.9]: events at t=0.5..0.9
    good, bad = b.window_counts(0.9, 0.5)
    assert good + bad == 5
    # totals survive trimming even when the window forgets everything
    b.record(100.0, good=True)
    assert b.window_counts(100.0, 0.5) == (1, 0)
    assert (b.good_total, b.bad_total) == (6, 5)


def test_burn_rate_in_budget_units():
    # 5% budget; 10% observed bad over the window -> burn 2.0
    b = ErrorBudget(_obj(target=0.95), horizon_s=10.0)
    for i in range(100):
        b.record(0.01 * (i + 1), good=(i % 10 != 0))
    assert b.bad_fraction(1.0, 1.0) == pytest.approx(0.10)
    assert b.burn_rate(1.0, 1.0) == pytest.approx(2.0)
    # remaining is clipped to [0, 1]
    assert b.remaining(1.0) == 0.0
    empty = ErrorBudget(_obj(), horizon_s=1.0)
    assert empty.burn_rate(5.0, 1.0) == 0.0
    assert empty.remaining(5.0) == 1.0


# ---------------------------------------------------------------- alert log
def test_alert_log_fire_resolve_active_bookkeeping():
    from repro.obs.slo import AlertEvent
    log = AlertLog()
    f = AlertEvent(1.0, "t0", "page", "fire", 10.0, 9.0)
    log.fire(f)
    assert log.is_active("t0", "page") and log.active() == [f]
    log.resolve(AlertEvent(2.0, "t0", "page", "resolve", 0.5, 4.0))
    assert not log.is_active("t0", "page") and log.active() == []
    assert [e["kind"] for e in log.timeline()] == ["fire", "resolve"]


# ------------------------------------------------------------------ monitor
def test_monitor_fires_during_burst_and_resolves_after():
    mon = SLOMonitor([_obj(window_s=1.0)])
    t = 0.0
    # healthy traffic: everything within threshold
    while t < 2.0:
        mon.record("t0", t, latency_s=0.005)
        assert mon.check(t) == []
        t += 0.01
    # incident: every request blows the threshold
    fired = []
    while t < 2.5:
        mon.record("t0", t, latency_s=0.5)
        fired += mon.check(t)
        t += 0.01
    assert any(e.kind == "fire" for e in fired)
    assert mon.alerts.active()
    # recovery: healthy again; short windows drain and everything resolves
    resolved = []
    while t < 4.5:
        mon.record("t0", t, latency_s=0.005)
        resolved += mon.check(t)
        t += 0.01
    assert any(e.kind == "resolve" for e in resolved)
    assert not mon.alerts.active()
    # fire/resolve pair up per (tenant, rule)
    events = mon.alerts.timeline()
    fires = sum(e["kind"] == "fire" for e in events)
    assert fires == sum(e["kind"] == "resolve" for e in events)


def test_monitor_rejections_burn_budget_and_journal_is_exact():
    journal = []
    mon = SLOMonitor([_obj()], journal=journal)
    assert mon.record("t0", 0.0, latency_s=0.001) is True
    assert mon.record("t0", 0.1, latency_s=0.5) is False      # too slow
    assert mon.record("t0", 0.2, rejected=True) is False      # shed
    assert mon.record("t0", 0.3) is False                     # no latency
    b = mon.budgets["t0"]
    assert (b.good_total, b.bad_total) == (1, 3)
    assert len(journal) == 4
    assert [e["good"] for e in journal] == [True, False, False, False]
    assert journal[2]["rejected"] is True
    # unknown tenants are ignored, not crashed on
    assert mon.record("nobody", 0.4, latency_s=9.9) is True
    assert len(journal) == 4


def test_monitor_requires_objectives_and_burn_pressure_crosses_one():
    with pytest.raises(ValueError):
        SLOMonitor([])
    mon = SLOMonitor([_obj(window_s=1.0)])
    assert mon.burn_pressure(0.0) == 0.0
    for i in range(50):
        mon.record("t0", 0.01 * i, latency_s=0.5)   # all bad
    # burn_short/factor >= 1.0 exactly when some rule is ready to fire
    assert mon.burn_pressure(0.5) >= 1.0
    assert mon.budget_remaining("t0", 0.5) == 0.0


def test_monitor_emits_slo_counters_and_alert_points():
    with obs.tracing() as tracer:
        mon = SLOMonitor([_obj(window_s=1.0)])
        for i in range(50):
            mon.record("t0", 0.01 * i, latency_s=0.5)
            mon.check(0.01 * i)
        snap = obs.get_registry().snapshot()
        assert snap["counters"]["slo.bad{tenant=t0}"] == 50.0
        assert snap["counters"]["alert.fires{rule=page,tenant=t0}"] >= 1.0
        assert any(g.startswith("slo.burn_rate{") for g in snap["gauges"])
        names = [s["name"] for s in tracer.finished()]
    assert "alert.fire" in names


def test_report_shape():
    mon = SLOMonitor([_obj()])
    mon.record("t0", 0.0, latency_s=0.001)
    rep = mon.report(0.5)
    assert rep["tenants"]["t0"]["good"] == 1
    assert rep["tenants"]["t0"]["bad"] == 0
    assert rep["alerts"] == [] and rep["active_alerts"] == []


def test_custom_rules_override_defaults():
    rule = BurnRateRule("only", long_s=0.5, short_s=0.1, factor=4.0)
    mon = SLOMonitor([_obj()], rules=[rule])
    assert mon.rules_for("t0") == (rule,)
