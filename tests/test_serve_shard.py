"""Sharded registry topology: rendezvous routing and its minimal-disruption
property, gossip pull-on-miss and reconciliation, the registry facade the
training engines publish through, and failover serving from replicas."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (BatchConfig, GossipConfig, ShardCluster,
                         ShardedEnsembleServer, rendezvous_owner,
                         rendezvous_rank, staleness_weight)


def _publish(target, tenant, T=4, F=6, seed=0, clock=0.0, progress=0):
    rng = np.random.RandomState(seed)
    p = np.zeros((T, 4), np.float32)
    p[:, 0] = rng.randint(0, F, size=T)
    p[:, 1] = rng.randn(T)
    p[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    a = (rng.rand(T) + 0.1).astype(np.float32)
    return target.publish_packed(tenant, jnp.asarray(p), jnp.asarray(a),
                                 clock=clock, train_progress=progress)


# ---------------------------------------------------------------- routing
def test_rendezvous_deterministic_and_minimally_disruptive():
    hosts = [f"h{i}" for i in range(5)]
    tenants = [f"tenant-{i}" for i in range(40)]
    owners = {t: rendezvous_owner(t, hosts) for t in tenants}
    assert owners == {t: rendezvous_owner(t, hosts) for t in tenants}
    assert len(set(owners.values())) > 1        # spreads over hosts
    # removing one host only moves that host's tenants
    dead = "h2"
    survivors = [h for h in hosts if h != dead]
    for t in tenants:
        new = rendezvous_owner(t, survivors)
        if owners[t] != dead:
            assert new == owners[t]
        else:
            assert new != dead
    # rank order: owner first, all hosts present exactly once
    rank = rendezvous_rank(tenants[0], hosts)
    assert rank[0] == owners[tenants[0]]
    assert sorted(rank) == sorted(hosts)


def test_publish_routes_to_owner_and_facade_reads():
    cluster = ShardCluster(3, GossipConfig(seed=0))
    snap = _publish(cluster, "t", clock=2.0)
    owner = cluster.owner("t")
    assert cluster.hosts[owner].registry.latest("t") is snap
    for hid, host in cluster.hosts.items():
        if hid != owner:                        # not replicated until gossip
            assert host.registry.latest("t") is None
    assert cluster.latest("t") is snap
    assert cluster.get("t", 1) is snap
    assert cluster.version_count("t") == 1
    assert cluster.staleness("t", 3.5) == pytest.approx(1.5)
    assert cluster.tenants() == ["t"]


def test_engine_publish_notifies_owning_shard():
    """The async engine's publish hook, pointed at a cluster, must land
    snapshots on the tenant's owning shard (and count them)."""
    import dataclasses
    from repro.configs.paper_fedboost import FedBoostConfig
    from repro.sim.scenarios import DOMAINS
    from repro.core import FederatedBoostEngine
    from repro.data import make_domain_data
    dom = dataclasses.replace(DOMAINS["edge_vision"], n_samples=400,
                              n_clients=3)
    data = make_domain_data(dom, seed=0)
    cluster = ShardCluster(3, GossipConfig(seed=0))
    eng = FederatedBoostEngine(FedBoostConfig(n_clients=3, n_rounds=4,
                                              seed=0), data, "enhanced")
    eng.attach_registry(cluster, "edge_vision")
    eng.run()
    assert eng.metrics.snapshots_published >= 1
    owner = cluster.owner("edge_vision")
    assert (cluster.hosts[owner].registry.version_count("edge_vision")
            == eng.metrics.snapshots_published)
    for hid, host in cluster.hosts.items():
        if hid != owner:
            assert host.registry.latest("edge_vision") is None


# ----------------------------------------------------------------- gossip
def test_gossip_pull_on_miss_replicates_history_window():
    cluster = ShardCluster(3, GossipConfig(seed=3, history=3))
    for v in range(5):
        _publish(cluster, "t", T=3 + v, seed=v, clock=float(v))
    cluster.run_until_quiescent(now=5.0)
    assert cluster.converged()
    for host in cluster.hosts.values():
        hist = host.registry.history("t")
        assert [s.version for s in hist] == [3, 4, 5]  # bounded window
        assert host.registry.latest("t").n_learners == 7
        # cross-host get() by version works inside the window
        assert host.registry.get("t", 4).n_learners == 6


def test_staleness_weight_monotone():
    assert staleness_weight(0.0, 0.5) == 1.0
    assert (staleness_weight(1.0, 0.5) > staleness_weight(2.0, 0.5)
            > staleness_weight(5.0, 0.5) > 0.0)
    assert staleness_weight(-3.0, 0.5) == 1.0   # clock skew clamps to 0


def test_concurrent_version_tiebreak_prefers_fresher_more_trained():
    cluster = ShardCluster(2, GossipConfig(seed=0, lam=0.5))
    h0, h1 = cluster.hosts.values()
    _publish(h0.registry, "t", seed=1, clock=0.0, progress=5)
    stale = h0.registry.latest("t")
    _publish(h1.registry, "t", seed=2, clock=3.0, progress=30)
    fresh = h1.registry.latest("t")
    assert stale.version == fresh.version == 1  # a genuine race
    cluster.run_until_quiescent(now=3.0)
    for host in cluster.hosts.values():
        assert host.registry.latest("t").fingerprint == fresh.fingerprint
    assert cluster.stats.reconciled >= 1


# --------------------------------------------------------------- failover
def test_failover_serves_from_gossiped_replica_and_recovers():
    cluster = ShardCluster(3, GossipConfig(seed=0))
    snap = _publish(cluster, "t", seed=4)
    cluster.run_until_quiescent()
    server = ShardedEnsembleServer(cluster, BatchConfig(cache_capacity=64),
                                   service_model=lambda n: 1e-4)
    x = np.random.RandomState(1).randn(6).astype(np.float32)

    def roundtrip(now):
        _, out = server.submit("t", x, now)
        out += server.drain()
        (resp,) = out
        return resp

    before = roundtrip(0.0)
    owner = cluster.owner("t")
    cluster.mark_down(owner)
    backup = cluster.route("t").host_id
    assert backup != owner
    after = roundtrip(1.0)
    assert after.margin == before.margin        # same snapshot, same answer
    assert after.snapshot_version == snap.version
    # publishes during the outage route to the acting owner; on recovery
    # the old owner pulls the missed version back via gossip
    v2 = _publish(cluster, "t", T=6, seed=9, clock=2.0)
    assert v2.version == 2
    cluster.mark_up(owner)
    cluster.run_until_quiescent(now=2.0)
    assert cluster.hosts[owner].registry.latest("t").version == 2
    assert cluster.converged()


def test_all_hosts_down_sheds_load():
    cluster = ShardCluster(2, GossipConfig(seed=0))
    _publish(cluster, "t")
    server = ShardedEnsembleServer(cluster, BatchConfig(),
                                   service_model=lambda n: 1e-4)
    for hid in list(cluster.hosts):
        cluster.mark_down(hid)
    accepted, out = server.submit("t", np.zeros(6, np.float32), 0.0)
    assert accepted is False and out == []
    with pytest.raises(RuntimeError):
        cluster.owner("t")
    # the shed load is charged to the fleet report (per tenant), even
    # though no per-host server ever saw the request
    server.submit("other", np.zeros(6, np.float32), 0.0)
    rep = server.report()
    assert rep["rejected"] == 2
    assert rep["tenants"]["t"]["rejected"] == 1
    assert rep["tenants"]["other"]["rejected"] == 1
    assert rep["completed"] == 0


def test_report_merges_mixed_up_down_fleet():
    """Fleet report merging under partial outage: per-tenant reservoirs
    concatenate, last_version merges by max, cache counters aggregate, and
    per-host rows carry their liveness status."""
    cluster = ShardCluster(3, GossipConfig(seed=0))
    tenants = ["a", "b", "c", "d"]
    for i, t in enumerate(tenants):
        _publish(cluster, t, seed=i)
    _publish(cluster, "a", T=6, seed=9)           # a is at version 2
    cluster.run_until_quiescent()
    server = ShardedEnsembleServer(
        cluster, BatchConfig(cache_capacity=64, adaptive=False,
                             fixed_window_units=1),
        service_model=lambda n: 1e-4)
    rng = np.random.RandomState(0)
    pools = {t: rng.randn(4, 6).astype(np.float32) for t in tenants}
    accepted = 0
    for i in range(24):
        t = tenants[i % 4]
        accepted += server.submit(t, pools[t][i % 4], now=1e-3 * i)[0]
    victim = cluster.owner("a")
    cluster.mark_down(victim)                     # mixed fleet from here on
    for i in range(24, 48):
        t = tenants[i % 4]
        accepted += server.submit(t, pools[t][i % 4], now=1e-3 * i)[0]
    server.drain()

    rep = server.report()
    assert accepted == 48
    assert rep["completed"] == 48
    per_host = rep["per_host"]
    assert rep["completed"] == sum(h["completed"] for h in per_host.values())
    assert rep["n_batches"] == sum(h["n_batches"] for h in per_host.values())
    statuses = {hid: h["status"] for hid, h in per_host.items()}
    assert statuses[victim] == "down"
    assert sorted(statuses.values()) == ["down", "up", "up"]
    # the downed owner served 'a' before the outage, the failover host
    # after it: the merged tenant row must still carry the max version
    assert rep["tenants"]["a"]["snapshot_version"] == 2
    assert rep["tenants"]["a"]["completed"] == 12
    # per-tenant latencies concatenate across hosts
    assert sum(t["completed"] for t in rep["tenants"].values()) == 48
    # cache counters aggregate over every host's cache (the same four
    # vectors per tenant recur: hits must have accrued somewhere)
    cache = rep["cache"]
    assert cache["hits"] + cache["misses"] > 0
    assert cache["hits"] == sum(
        s.cache.stats.hits for s in server.servers.values())
    assert cache["hit_rate"] == pytest.approx(
        cache["hits"] / (cache["hits"] + cache["misses"]))


def test_fleet_rids_unique_across_hosts():
    cluster = ShardCluster(3, GossipConfig(seed=0))
    for i, t in enumerate(["a", "b", "c", "d"]):
        _publish(cluster, t, seed=i)
    assert len({cluster.owner(t) for t in "abcd"}) > 1  # multi-host spread
    server = ShardedEnsembleServer(cluster, BatchConfig(),
                                   service_model=lambda n: 1e-4)
    rng = np.random.RandomState(0)
    responses = []
    for i in range(40):
        t = "abcd"[i % 4]
        _, done = server.submit(t, rng.randn(6).astype(np.float32),
                                now=1e-3 * i)
        responses += done
    responses += server.drain()
    rids = [r.rid for r in responses]
    assert len(rids) == 40 and len(set(rids)) == 40
