"""repro.chain: the chain of record (hash-linked blocks over the
BlockchainLedger slot model), the ChainRegistry EnsembleRegistry quack,
the ChainCluster serving fleet, ledger slot pruning, and the pinned
bit-for-bit parity of the centralized path against pre-chain goldens."""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.chain import Block, Chain, ChainCluster, ChainCommit, ChainRegistry
from repro.chain.registry import _owner_runs
from repro.serve import GossipConfig, ShardCluster
from repro.serve.registry import EnsembleRegistry
from repro.sim.behavior import BlockchainLedger
from repro.sim.harness import run_scenario, train_pair
from repro.sim.scenarios import get_scenario

GOLDEN = Path(__file__).parent / "golden" / "blockchain_centralized.json"


def _commit(seq, tenant="t", cid=0, alphas=(1.0,), rounds=None):
    rows = tuple((float(seq), 0.5, 1.0, 0.0) for _ in alphas)
    return ChainCommit(tenant=tenant, cid=cid, seq=seq,
                       rounds=rounds or (0,) * len(alphas),
                       alphas=tuple(alphas), stump_rows=rows)


def _packed(T, seed=0):
    rng = np.random.RandomState(seed)
    rows = np.zeros((T, 4), np.float32)
    rows[:, 0] = rng.randint(0, 6, size=T)
    rows[:, 1] = rng.randn(T)
    rows[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    return rows, (rng.rand(T) + 0.1).astype(np.float32)


# ------------------------------------------------------------- chain core
def test_chain_mints_in_confirmation_order():
    chain = Chain(seed=1)
    waits = [chain.submit(_commit(chain.next_seq()), t=float(i))
             for i in range(5)]
    assert all(w > 0 for w in waits)
    assert chain.height == 0                       # nothing due yet
    minted = chain.advance(1e9)
    assert len(minted) == 5 and chain.height == 5
    assert chain.verify()
    # blocks appear in confirmation-time order and times are recorded
    times = [b.mined_at for b in chain.blocks[1:]]
    assert times == sorted(times)
    seqs = [c.seq for b in chain.blocks[1:] for c in b.commits]
    assert sorted(seqs) == list(range(1, 6))       # nothing lost


def test_finalize_drains_pending_and_confirms_tip():
    chain = Chain(seed=2, reorg_prob=0.4)
    for i in range(6):
        chain.submit(_commit(chain.next_seq()), t=float(i))
    chain.advance(2.0)
    partial = chain.confirmed_hashes()
    assert chain.tail_depth == 1                   # tip unconfirmed
    chain.finalize()
    assert chain.tail_depth == 0                   # whole chain confirmed
    full = chain.confirmed_hashes()
    assert full[:len(partial)] == partial          # prefix only extended
    total = sum(len(b.commits) for b in chain.blocks)
    assert total == 6                              # reorgs lose nothing
    assert chain.verify()


def test_verify_detects_tampered_block():
    chain = Chain(seed=3)
    for i in range(3):
        chain.submit(_commit(chain.next_seq()), t=float(i))
    chain.advance(1e9)
    assert chain.verify()
    b = chain.blocks[2]
    chain.blocks[2] = Block(b.height, b.prev_hash, b.mined_at + 1.0,
                            b.commits)             # mutate mined time
    assert not chain.verify()                      # descendant link breaks


def test_replay_hashes_match_live_chain():
    chain = Chain(seed=4)
    for i in range(4):
        chain.submit(_commit(chain.next_seq(), cid=i), t=float(i))
    chain.advance(1e9)
    assert chain.replay_hashes() == [b.hash for b in chain.blocks[1:]]


def test_committee_rotates_when_leader_leaves():
    chain = Chain(seed=5, committee_size=2)
    for n in ("a", "b", "c", "d"):
        chain.join(n)
    com = chain.committee()
    assert len(com) == 2
    leader = chain.leader()
    chain.leave(leader)
    assert chain.leader() != leader                # rotated past the dead
    assert leader not in chain.committee()
    # the miner stamp is metadata only: block hashes are leader-free
    chain.submit(_commit(chain.next_seq()), t=0.0)
    chain.advance(1e9)
    assert chain.replay_hashes() == [b.hash for b in chain.blocks[1:]]


# ------------------------------------------------------------ ledger prune
def test_ledger_pruning_never_changes_waits():
    """Satellite regression: a pruning ledger returns bit-identical waits
    to an unpruned clone over per-cursor-monotone commit sequences, while
    keeping the live slot set bounded."""
    a = BlockchainLedger(np.random.RandomState(0), prune_every=8)
    b = BlockchainLedger(np.random.RandomState(0), prune_every=10**9)
    cur_a = [a.register() for _ in range(3)]
    cur_b = [b.register() for _ in range(3)]
    rng = np.random.RandomState(42)
    clocks = [0.0, 0.0, 0.0]
    for _ in range(400):
        i = rng.randint(3)
        clocks[i] += float(rng.rand())             # per-cursor monotone
        t = clocks[i]
        assert a.commit(t, cursor=cur_a[i]) == b.commit(t, cursor=cur_b[i])
    assert a.pruned_slots > 0
    assert a.live_slots < b.live_slots
    assert a.live_slots + a.pruned_slots == b.live_slots


def test_ledger_cursorless_commit_disables_pruning():
    led = BlockchainLedger(np.random.RandomState(1), prune_every=4)
    cur = led.register()
    led.commit(0.0)                                # untracked commit
    for i in range(1, 40):
        led.commit(float(i), cursor=cur)
    assert led.pruned_slots == 0                   # conservative: no floor
    assert led.live_slots == 40


# ---------------------------------------------------------- chain registry
def test_owner_runs_split():
    assert _owner_runs(None, 0, 3) == [(0, 3)]
    assert _owner_runs([7, 7, 2, 2, 7], 0, 5) == [(0, 2), (2, 4), (4, 5)]
    assert _owner_runs([7, 7, 2], 2, 3) == [(2, 3)]  # delta only
    assert _owner_runs([1, 2], 2, 2) == []


def test_publish_packed_folds_versions_and_provenance():
    reg = ChainRegistry(node_id="n0", history=8)
    rows, alphas = _packed(3)
    assert reg.publish_packed("t", rows, alphas, clock=0.0,
                              owners=[5, 5, 9], rounds=[1, 2, 1]) is None
    snap = None
    t = 0.0
    while snap is None:                            # wait out confirmation
        t += 1.0
        reg.sync(t)
        snap = reg.latest("t")
    # two owner runs -> two commits; confirmed in order, content intact
    np.testing.assert_array_equal(np.asarray(snap.stump_params), rows)
    np.testing.assert_allclose(np.asarray(snap.alphas), alphas, rtol=1e-6)
    prov = reg.provenance("t")
    assert [(c, r) for c, r, _ in prov] == [(5, 1), (5, 2), (9, 1)]
    hashes = {h for _, _, h in prov}
    assert hashes <= set(reg.chain.confirmed_hashes())
    # versioned lineage: version 1 covers a prefix of the latest
    v1 = reg.provenance("t", 1)
    assert prov[:len(v1)] == v1
    with pytest.raises(KeyError):
        reg.provenance("t", 99)
    assert reg.provenance("ghost") == ()


def test_publish_commits_delta_only_and_refuses_shrink():
    reg = ChainRegistry(node_id="n0")
    r1, a1 = _packed(2, seed=1)
    reg.publish_packed("t", r1, a1, clock=0.0)
    r2, a2 = _packed(5, seed=1)
    reg.publish_packed("t", r2, a2, clock=1.0)
    reg.sync(1e9)
    reg.chain.finalize()
    # entries on chain == 5 (2 + the 3-entry delta), not 7
    n = sum(c.n_entries for b in reg.chain.blocks for c in b.commits)
    assert n == 5
    with pytest.raises(ValueError, match="shrank"):
        reg.publish_packed("t", r1, a1, clock=2.0)
    with pytest.raises(ValueError, match="mismatched"):
        reg.publish("t", [{}] * 2, [1.0, 2.0, 3.0], clock=2.0)


def test_every_node_folds_identical_snapshots():
    """The serverless core claim: nodes (including one born after the
    publisher died) rebuild bit-identical snapshots from the chain."""
    chain = Chain(seed=6)
    pub = ChainRegistry(chain, node_id="pub")
    other = ChainRegistry(chain, node_id="other")
    for step in range(3):
        rows, alphas = _packed(2 + 2 * step, seed=step)
        pub.publish_packed("t", rows, alphas, clock=float(step))
    chain.finalize()
    a, b = pub.latest("t"), other.latest("t")
    assert a.version == b.version and a.fingerprint == b.fingerprint
    pub.close()                                    # publisher dies
    late = ChainRegistry(chain, node_id="late")    # born afterwards
    c = late.latest("t")
    assert (c.version, c.fingerprint) == (a.version, a.fingerprint)
    assert late.provenance("t") == other.provenance("t")
    assert late.digest() == other.digest()


def test_generic_learner_family_round_trips():
    chain = Chain(seed=7)
    reg = ChainRegistry(chain, node_id="n0")
    learners = [{"w": np.arange(3, dtype=np.float32)},
                {"w": np.ones(3, np.float32)}]
    reg.publish("t", learners, [0.5, 0.25], clock=0.0,
                weak_name="logistic")
    chain.finalize()
    snap = reg.latest("t")
    assert snap.weak_name == "logistic" and snap.stump_params is None
    np.testing.assert_array_equal(snap.learners[0]["w"],
                                  learners[0]["w"])


# ----------------------------------------------------------- chain cluster
def test_chain_cluster_kill_any_host_and_warm_from_chain():
    cl = ChainCluster(3, GossipConfig(seed=0))
    rows, alphas = _packed(4)
    cl.publish_packed("t", rows, alphas, clock=0.0, owners=[1, 1, 2, 2],
                      rounds=[0, 1, 0, 1])
    cl.run_until_quiescent()
    fps = {h.registry.latest("t").fingerprint for h in cl.hosts.values()}
    assert len(fps) == 1                           # all views identical
    leader = cl.leader()
    assert leader in cl.hosts
    cl.kill(leader)                                # committee leader dies
    assert cl.leader() != leader
    rows2, alphas2 = _packed(6)
    cl.publish_packed("t", rows2, alphas2, clock=1.0)
    cl.run_until_quiescent(now=1.0)
    snap = cl.latest("t")
    assert snap is not None and snap.stump_params.shape[0] == 6
    assert cl.provenance("t")                      # lineage still answerable
    # scale-out warms purely from chain history
    fresh = cl.add_host("host-9", now=2.0)
    assert fresh.registry.latest("t").fingerprint == snap.fingerprint
    # total loss: every host leaves; a newborn still rebuilds everything
    for hid in list(cl.hosts):
        cl.remove_host(hid)
    reborn = cl.add_host("host-99", now=3.0)
    assert reborn.registry.latest("t").fingerprint == snap.fingerprint


def test_train_pair_through_chain_cluster():
    sc = get_scenario("blockchain")
    sc = dataclasses.replace(
        sc, domain=dataclasses.replace(sc.domain, n_samples=500,
                                       n_clients=4))
    cluster = ChainCluster(2, GossipConfig(seed=0))
    _, runs = train_pair(sc, "block_delay", seed=0, n_rounds=4,
                         cluster=cluster)
    assert runs["enhanced"].snapshots_published > 0
    cluster.run_until_quiescent()
    snap = cluster.latest(sc.name)
    assert snap is not None and snap.version > 0
    prov = cluster.provenance(sc.name)
    assert len(prov) == snap.n_learners           # one triple per learner
    assert {c for c, _, _ in prov} <= set(range(-1, 4))


def test_flchain_scenario_registered():
    sc = get_scenario("blockchain_flchain")
    assert sc.chain and sc.variant_of == "blockchain"
    assert set(sc.traces) >= {"legacy", "block_delay"}
    assert not get_scenario("blockchain").chain    # centralized default


def test_flchain_harness_kills_leader_and_serves_lossless():
    """The harness chain leg: mid-replay the committee leader is killed;
    the zero-loss invariant (asserted inside replay_serve) must survive
    and the fleet keeps serving confirmed chain state."""
    sc = get_scenario("blockchain_flchain")
    sc = dataclasses.replace(
        sc, domain=dataclasses.replace(sc.domain, n_samples=500,
                                       n_clients=4))
    rep = run_scenario(sc, trace="block_delay", seed=0, n_rounds=4,
                       serve=True, serve_duration_s=0.5)
    s = rep.serve
    assert s is not None and s["completed"] > 0
    assert s["killed_host"]                        # the kill leg ran
    assert s["snapshot_version"] > 0


# -------------------------------------------------------- centralized pin
def test_centralized_path_bitwise_parity_with_golden():
    """The chain refactor must leave the default centralized path
    bit-for-bit unchanged: these goldens were captured immediately before
    src/repro/chain existed (same seeds, same ShardCluster publish path).
    Counters are exact; float accumulators and snapshot fingerprints are
    pinned exactly too — any drift means the refactor leaked into the
    centralized code path."""
    golden = json.loads(GOLDEN.read_text())
    sc = get_scenario("blockchain")
    for trace in ("legacy", "block_delay"):
        cluster = ShardCluster(2, GossipConfig(seed=0))
        _, runs = train_pair(sc, trace, seed=0, n_rounds=10,
                             cluster=cluster)
        for mode, m in runs.items():
            g = golden[f"{trace}/{mode}"]
            assert m.uplink_bytes == g["uplink_bytes"]
            assert m.downlink_bytes == g["downlink_bytes"]
            assert m.n_messages == g["n_messages"]
            assert m.n_syncs == g["n_syncs"]
            assert m.learners_merged == g["learners_merged"]
            assert m.snapshots_published == g["snapshots_published"]
            assert m.rounds_unavailable == g["rounds_unavailable"]
            assert m.sim_time_s == g["sim_time_s"]
            assert m.final_val_error == g["final_val_error"]
            assert m.final_test_error == g["final_test_error"]
            tail = [list(p) for p in m.val_error_curve[-3:]]
            assert tail == g["val_error_curve_tail"]
        snap = cluster.latest(sc.name)
        gs = golden[f"{trace}/snapshot"]
        assert snap.version == gs["version"]
        assert snap.fingerprint == gs["fingerprint"]
        assert snap.n_learners == gs["n_learners"]
