"""Vocabulary-drift lint: every span/point/counter/gauge/histogram name
emitted under ``src/repro/`` must be documented in the vocabulary tables of
``src/repro/obs/README.md`` — and every documented name must still be
emitted somewhere.  Rename an instrument without updating the README (or
vice versa) and this test names the drift."""
import re
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src" / "repro"
README = SRC / "obs" / "README.md"

# an emission call: the instrument-factory token, an open paren, then the
# first argument on the same line (dotted-name literals are always inline)
_CALL = re.compile(
    r"\b(?:span|point|count|observe|counter|gauge|histogram)\(\s*([^\n]*)")
# dotted instrument/span name inside a (possibly f-) string literal
_LITERAL = re.compile(r'f?"([a-z_][a-z0-9_]*(?:\.[a-z0-9_{}]+)+)"')
# a documented name: lowercase dotted, optional {labels} suffix / <op> hole
_DOC_NAME = re.compile(
    r"^[a-z_][a-z0-9_]*(?:\.[a-z0-9_<>]+)+(?:\{[^}]*\})?$")


def emitted_names():
    """Every dotted name passed to an emission call under src/repro."""
    names = {}
    for path in sorted(SRC.rglob("*.py")):
        for m in _CALL.finditer(path.read_text()):
            # all string literals in the first-argument region: catches
            # conditional names like ("slo.good" if good else "slo.bad")
            head = m.group(1).split(" #")[0]
            for lit in _LITERAL.findall(head):
                name = re.sub(r"\{[^}]*\}", "<op>", lit)
                names.setdefault(name, f"{path.relative_to(SRC)}")
    return names


def documented_names():
    """Names from the README vocabulary tables: span-table column 1 and
    metrics-table column 2 (other columns carry prose and attr names)."""
    names = {}
    section = None
    for line in README.read_text().splitlines():
        if line.startswith("#"):
            heading = line.strip("# ").lower()
            if "span vocabulary" in heading:
                section = ("span", 0)       # column 1: the span name
            elif "metrics registry" in heading:
                section = ("metric", 1)     # column 2: the instruments
            else:
                section = None
            continue
        if section is None or not line.startswith("|"):
            continue
        cols = [c.strip() for c in line.strip("|").split("|")]
        kind, col = section
        if len(cols) <= col or set(cols[col]) <= {"-", " "}:
            continue
        for tok in re.findall(r"`([^`]+)`", cols[col]):
            if _DOC_NAME.match(tok):
                names.setdefault(re.sub(r"\{[^}]*\}", "", tok), kind)
    return names


def test_every_emitted_name_is_documented():
    emitted = emitted_names()
    documented = documented_names()
    undocumented = {n: src for n, src in emitted.items()
                    if n not in documented}
    assert not undocumented, (
        "names emitted in src/repro but missing from the obs/README.md "
        f"vocabulary tables: {undocumented}")


def test_every_documented_name_is_emitted():
    emitted = emitted_names()
    documented = documented_names()
    stale = sorted(n for n in documented if n not in emitted)
    assert not stale, (
        "names documented in obs/README.md vocabulary tables but no "
        f"longer emitted anywhere under src/repro: {stale}")


def test_lint_extractors_see_the_core_vocabulary():
    """Self-check that the scanners actually work (an empty intersection
    would make the two drift tests pass vacuously)."""
    emitted = emitted_names()
    documented = documented_names()
    for name in ("serve.request", "serve.submit", "train.sync",
                 "kernel.<op>", "alert.fire", "audit.update_magnitude",
                 "slo.good", "slo.bad", "chain.mint", "gossip.exchange"):
        assert name in emitted, f"scanner lost emitted name {name}"
        assert name in documented, f"README parse lost {name}"
    assert len(emitted) > 30 and len(documented) > 30
