"""Property-based suite for the chain of record: hash-link integrity
under arbitrary commit sequences and tampering, deterministic replay from
genesis, and confirmed-prefix monotonicity under reorgs and committee
rotation."""
import pytest

pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt

from hypothesis import given, settings, strategies as st

from repro.chain import Block, Chain, ChainCommit

NODES = ("n0", "n1", "n2", "n3", "n4")


def _commit(seq, tenant, cid, k):
    return ChainCommit(
        tenant=tenant, cid=cid, seq=seq, rounds=tuple(range(k)),
        alphas=tuple(0.5 + 0.25 * i for i in range(k)),
        stump_rows=tuple((float(seq), float(i), 1.0, 0.0)
                         for i in range(k)))


submissions = st.lists(
    st.tuples(st.sampled_from(("alpha", "beta")),   # tenant
              st.integers(0, 9),                    # committing client
              st.integers(1, 3),                    # entries in the delta
              st.floats(0.0, 4.0)),                 # inter-submit gap (s)
    min_size=1, max_size=16)


def _feed(chain, events):
    t = 0.0
    for tenant, cid, k, gap in events:
        t += gap                                    # publisher-monotone
        chain.submit(_commit(chain.next_seq(), tenant, cid, k), t)
    return t


# ------------------------------------------------------ hash-link integrity
@given(events=submissions, seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_hash_links_verify_and_tamper_breaks_them(events, seed):
    chain = Chain(seed=seed)
    _feed(chain, events)
    chain.finalize()
    assert chain.verify()
    assert len(chain.blocks) == len(events) + 1     # genesis + 1 per commit
    # tamper with any non-tip block: the descendant's prev_hash no longer
    # matches, so the whole chain fails verification
    for i in range(1, len(chain.blocks) - 1):
        good = chain.blocks[i]
        chain.blocks[i] = Block(good.height, good.prev_hash,
                                good.mined_at + 0.5, good.commits)
        assert not chain.verify()
        chain.blocks[i] = good
    # the tip has no descendant: break its own parent link instead
    tip = chain.blocks[-1]
    chain.blocks[-1] = Block(tip.height, "f" * 24, tip.mined_at,
                             tip.commits)
    assert not chain.verify()
    chain.blocks[-1] = tip
    assert chain.verify()


# ---------------------------------------------------- deterministic replay
@given(events=submissions, seed=st.integers(0, 99),
       reorg=st.floats(0.0, 0.5))
@settings(max_examples=40, deadline=None)
def test_replay_from_genesis_reproduces_hashes(events, seed, reorg):
    a = Chain(seed=seed, reorg_prob=reorg)
    b = Chain(seed=seed, reorg_prob=reorg)
    # committee membership differs between the two chains: the miner
    # stamp is metadata, so the hash chains must still agree
    a.join("only-on-a")
    for n in NODES:
        b.join(n)
    _feed(a, events)
    _feed(b, events)
    a.finalize()
    b.finalize()
    live = [blk.hash for blk in a.blocks[1:]]
    assert a.replay_hashes() == live
    assert [blk.hash for blk in b.blocks[1:]] == live


# ----------------------------------------- confirmed-prefix monotonicity
@given(events=submissions, seed=st.integers(0, 99),
       churn=st.lists(st.sampled_from(NODES), max_size=6),
       reorg=st.floats(0.0, 0.6))
@settings(max_examples=40, deadline=None)
def test_confirmed_prefix_only_extends(events, seed, churn, reorg):
    chain = Chain(seed=seed, reorg_prob=reorg, committee_size=2)
    for n in NODES:
        chain.join(n)
    t_end = _feed(chain, events)
    confirmed = []
    t = 0.0
    for i, node in enumerate(churn or [NODES[0]]):
        # committee rotation mid-run: leave on odd steps, rejoin on even
        (chain.leave if i % 2 else chain.join)(node)
        t += t_end / 4 + 0.5
        chain.advance(t)
        now = chain.confirmed_hashes()
        assert now[:len(confirmed)] == confirmed    # prefix preserved
        confirmed = now
    chain.finalize()
    final = chain.confirmed_hashes()
    assert final[:len(confirmed)] == confirmed
    assert chain.verify()
    # no commit is ever lost to a reorg
    seqs = sorted(c.seq for b in chain.blocks for c in b.commits)
    assert seqs == list(range(1, len(events) + 1))
