"""repro.sim: behavior models, JSON trace replay, the scenario registry,
the engine's behavior_for hook (legacy shim bit-for-bit), deprecation
shims, and the train->serve harness."""
import dataclasses

import numpy as np
import pytest

from repro.core import FederatedBoostEngine
from repro.sim.behavior import (
    BlockDelayBehavior, ClientBehavior, DiurnalBehavior, GilbertLinkBehavior,
    LegacyBehavior, Link, SiteBehavior, SiteOutageProcess, TraceSchedule,
    legacy_behaviors)
from repro.sim.harness import result_row, run_scenario, train_pair
from repro.sim.scenarios import (
    DOMAINS, PaperBand, SCENARIOS, base_scenarios, get_scenario,
    variant_scenarios)


def _small_scenario(name="edge_vision", n_samples=400, n_clients=4):
    sc = get_scenario(name)
    return dataclasses.replace(
        sc, domain=dataclasses.replace(sc.domain, n_samples=n_samples,
                                       n_clients=n_clients))


# ------------------------------------------------------- legacy shim parity
def test_legacy_shim_bitwise_equal_to_default():
    """An engine with the explicit LegacyBehavior shim must reproduce the
    default (no behavior_for) engine bit-for-bit — same RNG draws in the
    same order, same float expressions.  The default path is itself the
    pre-behavior engine's code path, so this pins the acceptance criterion
    that the shim reproduces pre-PR results at equal seeds."""
    sc = _small_scenario()
    data = sc.make_data(seed=3)
    cfg = sc.fedboost_config(seed=3, n_rounds=5)
    for mode in ("baseline", "enhanced"):
        a = FederatedBoostEngine(cfg, data, mode).run()
        shims = legacy_behaviors(cfg, len(data["clients"]),
                                 np.random.RandomState(cfg.seed),
                                 latency_s=FederatedBoostEngine.LATENCY_S)
        b = FederatedBoostEngine(cfg, data, mode,
                                 behavior_for=lambda c: shims[c]).run()
        assert a.total_bytes == b.total_bytes
        assert a.sim_time_s == b.sim_time_s
        assert a.final_val_error == b.final_val_error
        assert a.n_syncs == b.n_syncs
        assert a.rounds_unavailable == b.rounds_unavailable


def test_legacy_trace_factory_returns_none():
    # None tells the engine to install its own shim from the same RNG
    # stream — the only way to stay bit-for-bit with the pre-PR engine
    assert get_scenario("mobile").behavior_for("legacy", 0) is None


# ------------------------------------------------------------ engine hook
def test_custom_behavior_drives_sim_time():
    class Slow(ClientBehavior):
        def compute_time(self, work, t=0.0):
            return 50.0 * work

    sc = _small_scenario()
    data = sc.make_data(seed=0)
    cfg = sc.fedboost_config(seed=0, n_rounds=3)
    fast = FederatedBoostEngine(cfg, data, "enhanced").run()
    slow = FederatedBoostEngine(cfg, data, "enhanced",
                                behavior_for=lambda c: Slow()).run()
    assert slow.sim_time_s > fast.sim_time_s * 5


def test_unavailable_rounds_counted():
    class Offline(ClientBehavior):
        def availability(self, t):
            return False

    sc = _small_scenario()
    data = sc.make_data(seed=0)
    cfg = sc.fedboost_config(seed=0, n_rounds=3)
    m = FederatedBoostEngine(cfg, data, "enhanced",
                             behavior_for=lambda c: Offline()).run()
    assert m.rounds_unavailable == len(data["clients"]) * 3
    # nothing is lost: buffered learners still sync after the stalls
    assert m.learners_merged == len(data["clients"]) * 3


# --------------------------------------------------------- behavior models
def test_diurnal_day_night_cycle():
    b = DiurnalBehavior(speed=2.0, period_s=24.0, phase_s=0.0,
                        rng=np.random.RandomState(0), peak=1.0, trough=0.0,
                        night_slowdown=1.0, link_mbps=10.0)
    noon, midnight = 6.0, 18.0           # sin peak / trough for phase 0
    assert b.daylight(noon) == pytest.approx(1.0)
    assert b.daylight(midnight) == pytest.approx(0.0, abs=1e-9)
    assert b.availability(noon) is True          # p = peak = 1
    assert b.availability(midnight) is False     # p = trough = 0
    assert b.compute_time(1.0, midnight) == pytest.approx(4.0)  # 2x slower
    assert b.compute_time(1.0, noon) == pytest.approx(2.0)
    assert b.link(noon).bandwidth_mbps > b.link(midnight).bandwidth_mbps


def test_gilbert_link_bursts_and_degrades():
    good, bad = Link(0.05, 1.0), Link(0.5, 0.05)
    b = GilbertLinkBehavior(1.0, np.random.RandomState(1), mean_good_s=2.0,
                            mean_bad_s=1.0, good=good, bad=bad,
                            drop_in_bad=1.0, drop_in_good=0.0)
    states = [b.in_good_state(t) for t in np.linspace(0, 60, 600)]
    assert any(states) and not all(states)       # both states visited
    # state runs are bursty: consecutive samples mostly agree
    agree = np.mean([a == c for a, c in zip(states, states[1:])])
    assert agree > 0.8
    t_bad = next(t for t, s in zip(np.linspace(0, 60, 600), states) if not s)
    assert b.link(60.0) in (good, bad)
    bb = GilbertLinkBehavior(1.0, np.random.RandomState(1), mean_good_s=2.0,
                             mean_bad_s=1.0, good=good, bad=bad,
                             drop_in_bad=1.0, drop_in_good=0.0)
    assert bb.link(t_bad) is bad                 # degraded while fading
    assert bb.availability(t_bad) is False       # dropped in the deep fade


def test_site_outages_are_correlated_and_waited_out():
    site = SiteOutageProcess(np.random.RandomState(2), mean_up_s=5.0,
                             mean_down_s=2.0)
    a = SiteBehavior(site, speed=1.0)
    b = SiteBehavior(site, speed=3.0)
    ts = np.linspace(0.0, 100.0, 1000)
    avail_a = [a.availability(t) for t in ts]
    down_t = [t for t, up in zip(ts, avail_a) if not up]
    assert down_t and len(down_t) < len(ts)      # outages happen, end
    # correlation: the second client on the site sees identical windows
    assert [b.availability(t) for t in ts] == avail_a
    t0 = down_t[0]
    assert site.remaining(t0) > 0.0
    # an unavailable round stalls until the outage clears, not one round
    assert a.stall_time(1.0, t0) >= site.remaining(t0)


def test_block_delay_latency_floor():
    b = BlockDelayBehavior(1.0, np.random.RandomState(3),
                           block_interval_s=0.5, confirmations=3,
                           congestion_prob=0.0, latency_s=0.05)
    for t in (0.0, 1.0, 2.0):
        # at least (confirmations-1) full block intervals on every message
        assert b.link(t).latency_s >= 0.05 + 2 * 0.5


def test_blockchain_ledger_serializes_commit_bursts():
    # K simultaneous commits queue on block capacity: slots are pairwise
    # >= one block gap apart, so the burst spans >= (K-1) gaps — the cost
    # a synchronous round pays and a sparse async sync does not
    from repro.sim.behavior import BlockchainLedger
    ledger = BlockchainLedger(np.random.RandomState(0),
                              block_interval_s=0.5, commits_per_block=1)
    waits = [ledger.commit(0.0) for _ in range(8)]
    slots = sorted(waits)
    assert all(b - a >= 0.5 - 1e-9 for a, b in zip(slots, slots[1:]))
    assert slots[-1] >= slots[0] + 7 * 0.5
    # a lone commit long after the backlog clears waits ~one block again
    assert ledger.commit(1000.0) < 0.5 * 8


def test_blockchain_ledger_is_call_order_independent():
    # an early-simulated-time commit issued *late* (the enhanced engine
    # advances clients one at a time) must not queue behind later-time
    # slots it precedes on chain
    from repro.sim.behavior import BlockchainLedger
    ledger = BlockchainLedger(np.random.RandomState(1),
                              block_interval_s=0.5)
    ledger.commit(100.0)                         # client 0, far future
    wait = ledger.commit(1.0)                    # client 1, early clock
    assert wait < 50.0                           # not pushed past t=100


# ------------------------------------------------------------ trace replay
def test_trace_schedule_segments_loop_and_json_roundtrip():
    trace = TraceSchedule(
        [{"t": 0.0, "speed": 1.0},
         {"t": 4.0, "speed": 3.0, "bandwidth_mbps": 1.0},
         {"t": 8.0, "available": False}],
        base=None, loop_s=10.0)
    assert trace.compute_time(1.0, 1.0) == pytest.approx(1.0)
    assert trace.compute_time(1.0, 5.0) == pytest.approx(3.0)
    assert trace.link(5.0).bandwidth_mbps == pytest.approx(1.0)
    assert trace.availability(9.0) is False
    assert trace.availability(11.0) is True      # looped back to segment 0
    assert trace.compute_time(1.0, 15.0) == pytest.approx(3.0)
    clone = TraceSchedule.from_json(trace.to_json())
    for t in np.linspace(0, 25, 50):
        assert clone.availability(t) == trace.availability(t)
        assert clone.compute_time(1.0, t) == trace.compute_time(1.0, t)


def test_trace_schedule_phase_rotates_cycle_and_roundtrips():
    segs = [{"t": 0.0, "available": True}, {"t": 6.0, "available": False}]
    base = TraceSchedule(segs, loop_s=8.0)
    shifted = TraceSchedule(segs, loop_s=8.0, phase_s=3.0)
    for t in np.linspace(0.0, 40.0, 200):
        assert shifted.availability(t) == base.availability(t + 3.0)
    # a staggered client still sleeps its recorded fraction of the cycle
    ts = np.linspace(0.0, 80.0, 4000)
    off = np.mean([not shifted.availability(t) for t in ts])
    assert off == pytest.approx(0.25, abs=0.02)
    # phase survives the JSON round-trip
    clone = TraceSchedule.from_json(shifted.to_json())
    assert clone.phase_s == shifted.phase_s
    for t in np.linspace(0.0, 20.0, 100):
        assert clone.availability(t) == shifted.availability(t)
    # before the first start a looped cycle continues its last segment
    late_start = TraceSchedule([{"t": 2.0, "available": True},
                                {"t": 6.0, "available": False}], loop_s=8.0)
    assert late_start.availability(1.0) is False   # mid "off" from t=6
    one_shot = TraceSchedule([{"t": 2.0, "available": False}])
    assert one_shot.availability(1.0) is False     # clamps to first


def test_trace_schedule_layers_over_base():
    class Base(ClientBehavior):
        def compute_time(self, work, t=0.0):
            return 2.0 * work

        def link(self, t):
            return Link(0.1, 8.0)

    trace = TraceSchedule([{"t": 0.0, "speed": 2.0, "latency_s": 0.3}],
                          base=Base())
    assert trace.compute_time(1.0, 0.0) == pytest.approx(4.0)  # 2 x 2
    link = trace.link(0.0)
    assert link.latency_s == pytest.approx(0.3)  # trace overrides latency
    assert link.bandwidth_mbps == pytest.approx(8.0)  # base bandwidth kept


def test_trace_schedule_rejects_unknown_fields():
    with pytest.raises(ValueError):
        TraceSchedule([{"t": 0.0, "spede": 1.0}])
    with pytest.raises(ValueError):
        TraceSchedule([])


def test_trace_schedule_from_file(tmp_path):
    import json
    path = tmp_path / "trace.json"
    path.write_text(json.dumps({"loop_s": 4.0,
                                "segments": [{"t": 0.0, "available": True},
                                             {"t": 2.0, "available": False}]}))
    trace = TraceSchedule.from_file(path)
    assert trace.availability(1.0) is True
    assert trace.availability(3.0) is False


# -------------------------------------------------------- scenario registry
def test_registry_has_five_domains_with_nontrivial_traces():
    assert base_scenarios() == ["edge_vision", "blockchain", "mobile",
                                "iot", "healthcare"]
    for name in base_scenarios():
        sc = get_scenario(name)
        assert "legacy" in sc.traces
        assert len(sc.nontrivial_traces) >= 2, name
        # factories build one fresh behavior per client
        for trace in sc.nontrivial_traces:
            bf = sc.behavior_for(trace, seed=0)
            behaviors = [bf(c) for c in range(sc.domain.n_clients)]
            assert all(isinstance(b, ClientBehavior) for b in behaviors)
    assert set(variant_scenarios()) == {"mobile_x4", "edge_vision_churn",
                                        "blockchain_flchain",
                                        "iot_coldstart", "mobile_100k"}


def test_registry_unknown_names_raise():
    with pytest.raises(KeyError):
        get_scenario("nope")
    with pytest.raises(KeyError):
        get_scenario("mobile").behavior_for("nope")


def test_band_check_flags_below_floor():
    band = PaperBand((15, 35), (20, 40), (15, 25), (0.0, 2.0),
                     tol_time=5.0, tol_comm=5.0, tol_acc=1.0)
    ok = {"time_down": 20.0, "comm_down": 30.0, "acc_delta_pp": 1.0}
    assert band.check(ok) == []
    bad = {"time_down": 2.0, "comm_down": 5.0, "acc_delta_pp": -3.0}
    assert len(band.check(bad)) == 3


def test_domains_shim_warns_and_matches_registry():
    import repro.configs.paper_fedboost as pf
    with pytest.warns(DeprecationWarning):
        shim = pf.DOMAINS
    assert shim == DOMAINS
    assert sorted(shim) == sorted(base_scenarios())
    with pytest.raises(AttributeError):
        pf.NOPE


def test_paper_bands_shim_warns():
    import benchmarks.domains as bd
    from repro.sim.scenarios import PAPER_BANDS
    with pytest.warns(DeprecationWarning):
        shim = bd.PAPER_BANDS
    assert shim == PAPER_BANDS
    # midpoints preserved from the old ad-hoc table
    assert shim["edge_vision"] == pytest.approx((25.0, 30.0, 20.0, 1.0))


# ----------------------------------------------------------------- harness
def test_train_serve_harness_end_to_end():
    sc = _small_scenario("edge_vision", n_samples=500, n_clients=4)
    rep = run_scenario(sc, trace="rack_outage", seed=0, n_rounds=4,
                       serve=True, serve_duration_s=0.5)
    assert rep.scenario == "edge_vision" and rep.trace == "rack_outage"
    assert rep.enhanced.snapshots_published > 0
    assert rep.enhanced.learners_merged > 0
    assert set(rep.row) >= {"time_down", "comm_down", "conv_down",
                            "acc_delta_pp"}
    s = rep.serve
    assert s is not None and s["completed"] > 0
    assert s["snapshot_version"] > 0             # served a trained snapshot
    assert s["hosts_final"] >= 2
    # band check ran (pass or fail — the matrix asserts compliance on the
    # full-size domains, not this shrunken smoke)
    assert isinstance(rep.band_failures, list)


def test_harness_trace_changes_training_profile():
    sc = _small_scenario("iot", n_samples=400, n_clients=4)
    _, legacy = train_pair(sc, "legacy", seed=0, n_rounds=4)
    _, gilbert = train_pair(sc, "gilbert", seed=0, n_rounds=4)
    # different behavior models => different simulated cost profile
    assert (gilbert["enhanced"].sim_time_s != legacy["enhanced"].sim_time_s
            or gilbert["enhanced"].total_bytes
            != legacy["enhanced"].total_bytes)
    row = result_row(gilbert)
    assert np.isfinite(row["comm_down"])


# ------------------------------------------------------- recorded traces
def test_mobile_diurnal_artifact_matches_derivation():
    """The checked-in recording is exactly what the seeded derivation
    produces — `python -m repro.sim.traces` regenerates it bit for bit."""
    from repro.sim.traces import (available_traces, derive_diurnal_trace,
                                  load_trace)
    assert "mobile_diurnal" in available_traces()
    trace = load_trace("mobile_diurnal")
    assert trace == derive_diurnal_trace()
    assert trace["loop_s"] == 24.0 and len(trace["segments"]) == 48
    # the recording is a valid TraceSchedule and behaves like a day:
    # some off segments, night slowdown above 1x
    sched = TraceSchedule.from_json(trace)
    avail = [sched.availability(s["t"]) for s in trace["segments"]]
    assert any(avail) and not all(avail)
    speeds = [s["speed"] for s in trace["segments"]]
    assert max(speeds) > 1.0 and min(speeds) >= 1.0


def test_missing_trace_lists_available():
    from repro.sim.traces import load_trace
    with pytest.raises(FileNotFoundError, match="mobile_diurnal"):
        load_trace("no_such_recording")


def test_mobile_scenario_replays_recorded_trace():
    sc = get_scenario("mobile")
    assert "diurnal_trace" in sc.traces
    behavior_for = sc.behavior_for("diurnal_trace", seed=0)
    b0, b1 = behavior_for(0), behavior_for(1)
    assert isinstance(b0, TraceSchedule)
    assert b0.loop_s == 24.0
    # per-client stagger: same recording, shifted phase
    assert b1.phase_s != b0.phase_s
    samples = [(b0.availability(t), b0.compute_time(1.0, t))
               for t in np.linspace(0.0, 24.0, 20)]
    assert any(a for a, _ in samples) and not all(a for a, _ in samples)
