"""Golden parity of the event-queue engine core against the legacy loops,
the async-accounting bugfix regressions, and the fleet-profile consistency
checks (the PR-7 sweep)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.paper_fedboost import (CompensationConfig, DomainConfig,
                                          FedBoostConfig, SchedulerConfig)
from repro.core import FederatedBoostEngine
from repro.core.async_engine import _Client
from repro.core.buffers import (BufferEntry, ClientBuffer,
                                ENTRY_OVERHEAD_BYTES, entry_wire_bytes)
from repro.data import make_domain_data
from repro.sim.scenarios import get_scenario

INT_FIELDS = ("uplink_bytes", "downlink_bytes", "n_messages", "n_syncs",
              "learners_merged", "rounds_unavailable")


def _dom(n_clients=8, dropout=0.2, **kw):
    base = dict(name="mobile", n_samples=1200, n_features=12,
                n_clients=n_clients, noniid_alpha=0.5, label_imbalance=0.5,
                noise=0.15, straggler_factor=4.0, dropout_prob=dropout,
                link_mbps=5.0)
    base.update(kw)
    return DomainConfig(**base)


def _cfg(dom, n_rounds=6, seed=3, **kw):
    return FedBoostConfig(n_clients=dom.n_clients, n_rounds=n_rounds,
                          straggler_factor=dom.straggler_factor,
                          dropout_prob=dom.dropout_prob,
                          link_mbps=dom.link_mbps, seed=seed, **kw)


def _run(cfg, data, mode, *, engine="events", fleet=None, behavior_for=None):
    return FederatedBoostEngine(cfg, data, mode, engine=engine, fleet=fleet,
                                behavior_for=behavior_for).run()


def assert_bitwise_equal(a, b):
    """Every metric — including the float curve — must match exactly."""
    for f in INT_FIELDS:
        assert getattr(a, f) == getattr(b, f), f
    assert a.sim_time_s == b.sim_time_s
    assert a.final_val_error == b.final_val_error
    assert a.final_test_error == b.final_test_error
    assert a.final_test_recall == b.final_test_recall
    assert a.val_error_curve == b.val_error_curve


# --------------------------------------------- golden events-vs-loop parity
@pytest.mark.parametrize("mode", ["baseline", "enhanced"])
def test_events_engine_bit_parity_legacy_trace(mode):
    dom = _dom()
    data = make_domain_data(dom, seed=0, partitioner="iid")
    cfg = _cfg(dom)
    assert_bitwise_equal(_run(cfg, data, mode, engine="loop"),
                         _run(cfg, data, mode, engine="events"))


@pytest.mark.parametrize("mode", ["baseline", "enhanced"])
@pytest.mark.parametrize("scenario,trace", [("iot", "gilbert"),
                                            ("mobile", "diurnal")])
def test_events_engine_bit_parity_nontrivial_traces(mode, scenario, trace):
    """Parity must hold through stateful behavior models too (and on more
    than one scenario)."""
    sc = get_scenario(scenario)
    dom = dataclasses.replace(sc.domain, n_samples=900, n_clients=6)
    data = make_domain_data(dom, seed=1, partitioner=sc.partitioner)
    cfg = _cfg(dom, n_rounds=5, seed=5)
    runs = {}
    for engine in ("loop", "events"):
        # fresh stateful behaviors per engine run
        runs[engine] = _run(cfg, data, mode, engine=engine,
                            behavior_for=sc.behavior_for(trace, 1))
    assert_bitwise_equal(runs["loop"], runs["events"])


def test_tied_sync_arrivals_merge_in_client_order():
    """Deterministic pop order for tied sync events: identical links +
    speeds make every first-round arrival tie exactly; both engines must
    process them in client order (same metrics, same curve)."""
    dom = _dom(dropout=0.0, straggler_factor=1.0)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    cfg = _cfg(dom, n_rounds=4, seed=0)
    a = _run(cfg, data, "enhanced", engine="loop")
    b = _run(cfg, data, "enhanced", engine="events")
    assert_bitwise_equal(a, b)


# ----------------------------------------------- baseline late-accounting
def _all_drop_runs(n_rounds=4, engine="events"):
    """dropout_prob=1: every learner goes the late path every round."""
    dom = _dom(n_clients=4, dropout=1.0)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    cfg = _cfg(dom, n_rounds=n_rounds, seed=0)
    return cfg, _run(cfg, data, "baseline", engine=engine)


@pytest.mark.parametrize("engine", ["loop", "events"])
def test_baseline_charges_late_uplink(engine):
    """Regression (PR 7): late learners' uplink bytes/messages were never
    charged.  With the fix every trained learner is charged exactly once —
    even when every round drops every client."""
    cfg, m = _all_drop_runs(engine=engine)
    n = cfg.n_clients * cfg.n_rounds
    per_msg = (ENTRY_OVERHEAD_BYTES + 12) + cfg.header_bytes   # stump = 12B
    # n uplink messages + the per-round downlink broadcasts
    assert m.uplink_bytes == n * per_msg
    assert m.learners_merged == n
    assert m.rounds_unavailable == n
    assert m.n_messages == n + cfg.n_clients * cfg.n_rounds


@pytest.mark.parametrize("engine", ["loop", "events"])
def test_baseline_final_round_late_learners_flushed(engine):
    """Regression (PR 7): the final round's pending_late was silently
    discarded — trained, counted unavailable, never merged or charged.
    The flush merges them (stale-by-one, full weight) after the last
    barrier and extends sim_time to the last delivery."""
    cfg, m = _all_drop_runs(engine=engine)
    assert m.learners_merged == cfg.n_clients * cfg.n_rounds
    # flush appends one extra curve record past the n_rounds barriers
    assert len(m.val_error_curve) == cfg.n_rounds + 1
    assert m.sim_time_s > cfg.n_rounds * 1.0 - 1e-9


def test_no_dropout_means_no_flush_record():
    dom = _dom(dropout=0.0)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    m = _run(_cfg(dom, n_rounds=4), data, "baseline")
    assert m.rounds_unavailable == 0
    assert len(m.val_error_curve) == 4


# ------------------------------------------------- wire-size single source
def test_entry_wire_bytes_single_source():
    e = BufferEntry({"feature": 0, "threshold": 0.0, "polarity": 1.0},
                    0.1, 0.5, 0)
    pb = lambda p: 12
    assert entry_wire_bytes(e, pb) == 12 + ENTRY_OVERHEAD_BYTES
    buf = ClientBuffer(0)
    for _ in range(3):
        buf.add(e.params, e.eps, e.alpha, e.round_stamp)
    assert buf.nbytes(pb) == 3 * entry_wire_bytes(e, pb)


def test_engine_entry_bytes_routes_through_buffers():
    dom = _dom(n_clients=2)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    eng = FederatedBoostEngine(_cfg(dom, n_rounds=1), data, "baseline")
    e = BufferEntry({"feature": 0, "threshold": 0.0, "polarity": 1.0},
                    0.1, 0.5, 0)
    assert eng._entry_bytes(e) == entry_wire_bytes(e, eng.weak.param_bytes)


def test_client_buffer_default_is_honest():
    """Regression (PR 7): _Client.buffer claimed type ClientBuffer but
    defaulted to None.  The default must build a real per-client buffer."""
    c = _Client(cid=7, x=None, y=None, D=None, behavior=None)
    assert isinstance(c.buffer, ClientBuffer)
    assert c.buffer.client_id == 7
    own = ClientBuffer(7)
    assert _Client(cid=7, x=None, y=None, D=None, behavior=None,
                   buffer=own).buffer is own


# -------------------------------------------------------- knobs + fleet
def test_catch_up_cap_wide_is_exact():
    """A cap wider than any window replays exactly what None replays —
    the reverse scan and the full scan select the same indices, so the
    whole run is bit-for-bit identical."""
    dom = _dom()
    data = make_domain_data(dom, seed=0, partitioner="iid")
    exact = _run(_cfg(dom), data, "enhanced")
    wide = _run(_cfg(dom, catch_up_cap=10_000), data, "enhanced")
    assert_bitwise_equal(exact, wide)


def test_catch_up_cap_small_still_learns():
    """A tight cap bounds replay work; it may shift learning (and thus
    scheduling), but the run must stay well-formed in both modes."""
    dom = _dom()
    data = make_domain_data(dom, seed=0, partitioner="iid")
    for mode in ("baseline", "enhanced"):
        m = _run(_cfg(dom, catch_up_cap=2), data, mode)
        assert m.learners_merged == dom.n_clients * 6
        assert 0.0 <= m.final_val_error <= 1.0


@pytest.mark.parametrize("decay", ["constant", "hinge", "poly"])
def test_decay_families_run_end_to_end(decay):
    dom = _dom(n_clients=4)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    cfg = _cfg(dom, n_rounds=4,
               compensation=CompensationConfig(decay=decay))
    m = _run(cfg, data, "enhanced")
    assert m.learners_merged == 4 * 4
    assert 0.0 <= m.final_val_error <= 1.0


@pytest.mark.parametrize("mode", ["baseline", "enhanced"])
def test_fleet_profile_matches_reference_accounting(mode):
    """The vectorized fleet profile must reproduce the reference engine's
    integer accounting and simulated clock exactly; learning results agree
    up to summation order."""
    dom = _dom(n_clients=8)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    cfg = _cfg(dom, catch_up_cap=4,
               scheduler=SchedulerConfig(i_init=2))
    ref = _run(cfg, data, mode, fleet=False)
    flt = _run(cfg, data, mode, fleet=True)
    for f in INT_FIELDS:
        assert getattr(ref, f) == getattr(flt, f), f
    assert ref.sim_time_s == flt.sim_time_s
    assert len(ref.val_error_curve) == len(flt.val_error_curve)
    assert abs(ref.final_val_error - flt.final_val_error) < 0.05
    assert abs(ref.final_test_error - flt.final_test_error) < 0.05


def test_fleet_profile_rejects_non_stump():
    dom = _dom(n_clients=4)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    cfg = _cfg(dom, n_rounds=2, weak_learner="logistic")
    with pytest.raises(ValueError, match="stump"):
        FederatedBoostEngine(cfg, data, "baseline", fleet=True).run()


def test_fleet_auto_selection_threshold():
    dom = _dom(n_clients=4)
    data = make_domain_data(dom, seed=0, partitioner="iid")
    eng = FederatedBoostEngine(_cfg(dom), data, "baseline")
    assert not eng._fleet                  # tiny fleet: reference profile
    eng = FederatedBoostEngine(_cfg(dom), data, "baseline", fleet=True)
    assert eng._fleet and eng.engine_kind == "events"


def test_scale_scenario_registered():
    sc = get_scenario("mobile_100k")
    assert sc.fleet and not sc.serve_replay
    assert sc.domain.n_clients == 100_000
    cfg = sc.fedboost_config()
    assert cfg.catch_up_cap == 16
    assert cfg.compensation.decay == "hinge"
    assert cfg.scheduler.i_init == 2
