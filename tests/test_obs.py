"""repro.obs: tracer nesting/ring/export, the disabled no-op fast path,
metrics-registry instruments, reservoir thinning under soak, weighted
fleet percentiles over thinned per-tenant reservoirs, traced serving
latency decomposition, traced engine runs, and kernel-profiling hooks."""
import dataclasses
import json
import random

import numpy as np
import pytest

from repro import obs
from repro.configs.paper_fedboost import FedBoostConfig
from repro.core import FederatedBoostEngine
from repro.data import make_domain_data
from repro.kernels.dispatch import (KernelPolicy, bucket_label,
                                    calibration_check, dispatch)
from repro.launch.obs_report import (aggregate, check_trace, folded_stacks,
                                     phase_breakdown, self_times)
from repro.obs.registry import (Histogram, MetricsRegistry, percentile,
                                weighted_percentile)
from repro.serve import BatchConfig, EnsembleRegistry, EnsembleServer
from repro.serve.metrics import ServeMetrics
from repro.sim.scenarios import DOMAINS


# ------------------------------------------------------------------ tracer

def test_span_nesting_parent_ids_and_two_clocks():
    with obs.tracing() as tr:
        with obs.span("outer", sim_t=10.0, scenario="s") as outer:
            with obs.span("inner") as inner:
                obs.point("leaf", sim_t0=10.5, sim_t1=10.5, k=1)
            outer.end(sim_t=12.0)
        spans = {d["name"]: d for d in tr.finished()}
    assert spans["inner"]["parent"] == spans["outer"]["span"]
    assert spans["leaf"]["parent"] == spans["inner"]["span"]
    assert spans["outer"]["parent"] is None
    assert spans["outer"]["sim_t0"] == 10.0
    assert spans["outer"]["sim_t1"] == 12.0
    assert spans["leaf"]["attrs"] == {"k": 1}
    # wall-clock containment: children close before their parent
    assert spans["outer"]["t0"] <= spans["inner"]["t0"]
    assert spans["inner"]["t1"] <= spans["outer"]["t1"]
    assert check_trace(list(spans.values())) == []


def test_disabled_span_is_shared_noop():
    # the hot-path guarantee: while tracing is off, every span request
    # returns the *same* object — no allocation, attrs dropped silently
    assert not obs.enabled()
    assert obs.span("x", sim_t=1.0, big="attr") is obs.NULL_SPAN
    assert obs.point("y") is obs.NULL_SPAN
    assert obs.span("x").set(a=1).end_sim(2.0) is obs.NULL_SPAN
    with obs.span("ctx") as sp:
        assert sp is obs.NULL_SPAN
    assert not obs.profiling_enabled()


def test_tracing_scope_restores_previous_state():
    assert not obs.enabled()
    reg_before = obs.get_registry()
    with obs.tracing() as tr:
        assert obs.enabled() and obs.get_tracer() is tr
        assert obs.profiling_enabled()
        assert obs.get_registry() is not reg_before   # fresh, isolated
        with pytest.raises(ValueError):
            with obs.tracing():                       # nested scope is fine
                raise ValueError("boom")
        assert obs.get_tracer() is tr                 # inner scope restored
    assert not obs.enabled() and not obs.profiling_enabled()
    assert obs.get_registry() is reg_before


def test_span_error_attr_and_abandoned_children():
    with obs.tracing() as tr:
        with pytest.raises(RuntimeError):
            with obs.span("parent"):
                obs.span("orphan")        # never ended by its owner
                raise RuntimeError("die")
        spans = {d["name"]: d for d in tr.finished()}
    assert spans["parent"]["attrs"]["error"] == "RuntimeError"
    assert "orphan" not in spans          # abandoned, not mis-parented
    # the stack recovered: a new root is a root, not a child of the orphan
    with obs.tracing() as tr:
        with obs.span("p"):
            obs.span("dangling")
        with obs.span("q"):
            pass
        spans = {d["name"]: d for d in tr.finished()}
    assert spans["q"]["parent"] is None


def test_ring_bounds_memory_and_counts_drops():
    with obs.tracing(ring=16) as tr:
        for i in range(50):
            obs.point("e", i=i)
        assert len(tr) == 16
        assert tr.dropped == 34
        assert tr.started == 50
        assert [d["attrs"]["i"] for d in tr.finished()] == list(range(34, 50))


def test_jsonl_export_roundtrip(tmp_path):
    with obs.tracing() as tr:
        with obs.span("a", sim_t=0.5, tenant="m"):
            obs.point("b", x=1.5)
        path = tr.export_jsonl(tmp_path / "trace.jsonl")
    back = obs.load_jsonl(path)
    assert back == tr.finished()
    # every line is standalone JSON (streaming consumers): one meta header
    # carrying the ring accounting, then one line per span
    lines = (tmp_path / "trace.jsonl").read_text().strip().splitlines()
    assert len(lines) == 3
    meta = json.loads(lines[0])["meta"]
    assert meta == {"schema": 2, "dropped": 0, "started": 2, "exported": 2}
    assert all(json.loads(ln)["name"] in ("a", "b") for ln in lines[1:])
    meta2, spans = obs.load_trace(path)
    assert meta2 == meta and spans == back


# ---------------------------------------------------------------- registry

def test_registry_instruments_get_or_create_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("c", tenant="a").inc()
    reg.counter("c", tenant="a").inc(2.0)
    reg.counter("c", tenant="b").inc()
    assert reg.counter("c", tenant="a").value == 3.0
    g = reg.gauge("g")
    g.set(5.0)
    g.max(3.0)          # below: no-op
    g.max(9.0)
    assert g.value == 9.0
    h = reg.histogram("h", unit="s")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.mean == 2.5 and h.p50 == 2.0
    snap = reg.snapshot()
    assert snap["counters"]["c{tenant=a}"] == 3.0
    assert snap["counters"]["c{tenant=b}"] == 1.0
    assert snap["gauges"]["g"] == 9.0
    assert snap["histograms"]["h{unit=s}"]["count"] == 4
    # label order never splits an instrument
    reg.counter("k", a="1", b="2").inc()
    reg.counter("k", b="2", a="1").inc()
    assert reg.counter("k", a="1", b="2").value == 2.0


def test_registry_save_is_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("train.fits").inc(7)
    path = reg.save(tmp_path / "m.json")
    doc = json.loads((tmp_path / "m.json").read_text())
    assert path.endswith("m.json")
    assert doc["counters"]["train.fits"] == 7.0


def test_reservoir_soak_bounded_memory_and_quantile_tolerance():
    # 100k lognormal samples through a 4096-slot reservoir: memory stays
    # bounded and the thinned quantiles track the full stream
    rng = random.Random(7)
    h = Histogram(reservoir=4096)
    full = []
    for _ in range(100_000):
        v = rng.lognormvariate(0.0, 1.0)
        full.append(v)
        h.observe(v)
    assert len(h.values) == 4096              # hard memory bound
    assert h.count == 100_000
    assert h.weight_per_sample == pytest.approx(100_000 / 4096)
    assert h.mean == pytest.approx(sum(full) / len(full))   # exact (sum/count)
    for q in (50.0, 90.0, 99.0):
        true = percentile(full, q)
        assert h.percentile(q) == pytest.approx(true, rel=0.15), q


def test_tenant_metrics_soak_bounded_memory_and_quantiles():
    # the same guarantee through the TenantMetrics view: 100k completions
    # for one tenant thin into one bounded reservoir whose quantiles
    # track the full latency stream
    rng = random.Random(11)
    m = ServeMetrics()
    full = []
    for _ in range(100_000):
        v = rng.lognormvariate(-6.0, 0.5)        # ~2.5ms lognormal latencies
        full.append(v)
        m.record_completion("t", v, staleness_s=0.0, version=1)
    t = m.tenant("t")
    assert len(t.latencies) == 4096              # bounded under the soak
    assert t.completed == 100_000
    for q, got in ((50.0, t.p50), (99.0, t.p99)):
        assert got == pytest.approx(percentile(full, q), rel=0.15), q
    assert m.fleet_percentile(50.0) == pytest.approx(
        percentile(full, 50.0), rel=0.15)


def test_histogram_extend_merges_totals_and_stays_bounded():
    a, b = Histogram(reservoir=64), Histogram(reservoir=64)
    for i in range(100):
        a.observe(1.0)
        b.observe(3.0)
    a.extend(b)
    assert a.count == 200 and a.sum == pytest.approx(400.0)
    assert len(a.values) == 64


def test_weighted_percentile_table_driven():
    cases = [
        # (pairs, q, want)
        ([(1.0, 1.0), (2.0, 1.0), (3.0, 1.0)], 50.0, 2.0),   # unit = plain
        ([(1.0, 1.0), (2.0, 1.0), (3.0, 1.0)], 100.0, 3.0),
        ([(10.0, 9.0), (99.0, 1.0)], 50.0, 10.0),
        ([(10.0, 9.0), (99.0, 1.0)], 90.0, 10.0),
        ([(10.0, 9.0), (99.0, 1.0)], 95.0, 99.0),
        ([(5.0, 0.0), (7.0, 2.0)], 50.0, 7.0),   # zero weights dropped
        ([], 99.0, 0.0),
    ]
    for pairs, q, want in cases:
        assert weighted_percentile(pairs, q) == want, (pairs, q)
    # agrees exactly with percentile() under unit weights
    rng = random.Random(3)
    vals = [rng.random() for _ in range(257)]
    for q in (1.0, 50.0, 99.0):
        assert (weighted_percentile([(v, 1.0) for v in vals], q)
                == percentile(vals, q))


def test_fleet_percentile_weights_thinned_tenant_reservoirs():
    # the bias this fixes: a hot tenant's reservoir is thinned (4096 kept
    # of 99k) while a cold tenant's 1k all fit, so naively concatenating
    # reservoirs gives the cold tenant ~20% of the merged sample instead
    # of its true 1% of traffic — and its slow requests swamp the p99
    m = ServeMetrics()
    for _ in range(99_000):
        m.record_completion("hot", 0.001, staleness_s=0.0, version=1)
    for _ in range(1_000):
        m.record_completion("cold", 0.100, staleness_s=0.0, version=1)
    naive = percentile(m.all_latencies(), 99.0)
    assert naive == pytest.approx(0.100)           # the documented bias
    assert m.fleet_percentile(99.0) == pytest.approx(0.001)   # weighted: fixed
    # true stream p99: 99k fast + 1k slow -> the 99th sits in the fast mass
    assert m.report()["p99_ms"] == pytest.approx(1.0)
    # per-tenant quantiles are unaffected either way
    assert m.tenant("cold").p99 == pytest.approx(0.100)


def test_weight_per_sample_tracks_stream_not_reservoir():
    h = Histogram(reservoir=64)
    for _ in range(64):
        h.observe(1.0)
    assert h.weight_per_sample == 1.0             # nothing thinned yet
    for _ in range(640 - 64):
        h.observe(1.0)
    assert h.weight_per_sample == pytest.approx(10.0)
    assert Histogram().weight_per_sample == 0.0   # empty: no weight


def test_latency_pairs_survive_merge_of_merges():
    """Folding already-folded per-host registries must not double-weight
    thinned reservoirs.  ``Histogram.extend`` keeps only every 8th incoming
    sample once full, so a second-level fold re-thins the first fold's
    survivors; ``latency_pairs`` taken *before* each merge carries the
    exact weights, and fleet quantiles from the concatenated pairs match
    the true stream regardless of fold depth."""
    rng = random.Random(7)
    hosts = []
    stream: list = []
    for h in range(4):
        m = ServeMetrics()
        # hosts see very different traffic volumes and latency regimes
        n = 6_000 * (h + 1)
        base = 0.001 * (h + 1)
        for _ in range(n):
            v = base * (1.0 + 0.1 * rng.random())
            m.record_completion("t", v, staleness_s=0.0, version=1)
            stream.append(v)
        hosts.append(m)
    true_p99 = percentile(stream, 99.0)
    # exact-weight pairs concatenated across hosts, pre-merge
    pairs = [p for m in hosts for p in m.latency_pairs()]
    flat = weighted_percentile(pairs, 99.0)
    assert abs(flat - true_p99) / true_p99 < 0.05
    # a two-level fold: (h0+h1) and (h2+h3), then the fold-of-folds.
    # the merged histogram's single weight_per_sample can no longer
    # distinguish the hosts, and re-thinning dropped samples unevenly
    lvl1a, lvl1b = ServeMetrics(), ServeMetrics()
    lvl1a.tenant("t").merge_from(hosts[0].tenant("t"))
    lvl1a.tenant("t").merge_from(hosts[1].tenant("t"))
    lvl1b.tenant("t").merge_from(hosts[2].tenant("t"))
    lvl1b.tenant("t").merge_from(hosts[3].tenant("t"))
    top = ServeMetrics()
    top.tenant("t").merge_from(lvl1a.tenant("t"))
    top.tenant("t").merge_from(lvl1b.tenant("t"))
    # totals stay exact through any fold depth
    assert top.completed == len(stream)
    # and the pre-merge pairs remain the trustworthy quantile source:
    # they must beat (or match) the merged reservoir's estimate
    merged_err = abs(top.fleet_percentile(99.0) - true_p99)
    assert abs(flat - true_p99) <= merged_err + 1e-12


def test_sharded_report_percentiles_come_from_premerge_pairs():
    """ShardedEnsembleServer.report folds per-host metrics; its fleet
    p50/p99 must come from the pre-merge per-host pairs, not from the
    merged (re-thinned) reservoir."""
    from repro.serve import (BatchConfig, GossipConfig, ShardCluster,
                             ShardedEnsembleServer)
    from repro.serve.metrics import weighted_percentile as wp
    cluster = ShardCluster(3, GossipConfig(seed=0))
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    for i in range(4):
        p = np.zeros((4, 4), np.float32)
        p[:, 0] = rng.randint(0, 8, size=4)
        p[:, 1] = rng.randn(4)
        p[:, 2] = 1.0
        cluster.publish_packed(f"tenant-{i}", jnp.asarray(p),
                               jnp.asarray(rng.rand(4) + 0.1))
    cluster.run_until_quiescent()
    server = ShardedEnsembleServer(cluster, BatchConfig(max_batch=8),
                                   service_model=lambda n: 1e-3 + 1e-4 * n)
    t = 0.0
    for i in range(60):
        t += rng.exponential(1.0 / 200.0)
        server.submit(f"tenant-{i % 4}", rng.randn(8).astype(np.float32), t)
    server.drain()
    rep = server.report()
    pairs = server.metrics.latency_pairs()
    for s in server.servers.values():
        pairs.extend(s.metrics.latency_pairs())
    assert rep["p50_ms"] == 1e3 * wp(pairs, 50.0)
    assert rep["p99_ms"] == 1e3 * wp(pairs, 99.0)
    assert rep["completed"] == 60


def test_tenant_metrics_view_and_merge():
    m = ServeMetrics()
    m.record_submit(0.0, depth=3)
    m.record_completion("a", 0.01, staleness_s=2.0, version=3)
    m.record_completion("a", 0.03, staleness_s=4.0, version=2)  # stale pub
    m.record_rejected("a")
    t = m.tenant("a")
    assert t.completed == 2 and t.rejected == 1
    assert t.last_version == 3                  # max, not last-write
    assert t.mean_staleness == pytest.approx(3.0)
    assert t.latencies == [0.01, 0.03]
    other = ServeMetrics()
    other.record_completion("a", 0.05, staleness_s=0.0, version=5)
    t.merge_from(other.tenant("a"))
    assert t.completed == 3 and t.last_version == 5
    assert sorted(t.latencies) == [0.01, 0.03, 0.05]


# ----------------------------------------------------- serving decomposition

def _stump_registry(T=4, F=6, seed=0):
    rng = np.random.RandomState(seed)
    params = np.zeros((T, 4), np.float32)
    params[:, 0] = rng.randint(0, F, size=T)
    params[:, 1] = rng.randn(T)
    params[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    reg = EnsembleRegistry()
    import jax.numpy as jnp
    reg.publish_packed("t", jnp.asarray(params),
                       jnp.asarray(rng.rand(T).astype(np.float32) + 0.1),
                       clock=0.0)
    return reg


def test_traced_serve_request_decomposition_sums_to_latency():
    reg = _stump_registry()
    cfg = BatchConfig(adaptive=False, fixed_window_units=2,
                      base_window_s=1e-3, max_batch=4)
    with obs.tracing() as tr:
        server = EnsembleServer(reg, cfg, service_model=lambda n: 1e-3)
        rng = np.random.RandomState(1)
        out = []
        t = 0.0
        for i in range(40):
            t += float(rng.exponential(1e-3))
            out += server.submit("t", rng.randn(6), now=t)[1]
        out += server.drain()
        spans = tr.finished()
    assert len(out) == 40
    reqs = [d for d in spans if d["name"] == "serve.request"]
    batches = [d for d in spans if d["name"] == "serve.batch"]
    kernels = [d for d in spans if d["name"] == "serve.kernel"]
    assert len(reqs) == 40 and batches and kernels
    for r in reqs:
        a = r["attrs"]
        # the exact decomposition: batching wait + queueing behind the
        # in-flight batch + the batch's own service time == latency
        assert a["batch_s"] >= 0 and a["queue_s"] >= 0 and a["kernel_s"] > 0
        assert (a["batch_s"] + a["queue_s"] + a["kernel_s"]
                == pytest.approx(a["latency_s"], abs=1e-9))
        # and the span's simulated interval is that same latency
        assert (r["sim_t1"] - r["sim_t0"]
                == pytest.approx(a["latency_s"], abs=1e-9))
    # request points nest under their dispatching batch span
    batch_ids = {d["span"] for d in batches}
    assert all(r["parent"] in batch_ids for r in reqs)
    assert check_trace(spans) == []


def test_traced_serve_metrics_and_registry_counters():
    reg = _stump_registry()
    with obs.tracing():
        server = EnsembleServer(reg, BatchConfig(max_batch=8),
                                service_model=lambda n: 1e-4)
        rng = np.random.RandomState(2)
        for i in range(10):
            server.submit("t", rng.randn(6), now=1e-3 * i)
        server.drain()
        greg = obs.get_registry()
        # the engine-side counters live on the *global* registry; the
        # server's ServeMetrics counters live on its private one
        assert server.metrics.registry is not greg
        assert server.metrics.completed == 10
    assert server.metrics.n_batches > 0


# ------------------------------------------------------------ traced engine

def _tiny_engine(mode="enhanced"):
    dom = dataclasses.replace(DOMAINS["edge_vision"], n_samples=400,
                              n_clients=4)
    data = make_domain_data(dom, seed=0)
    cfg = FedBoostConfig(n_clients=4, n_rounds=4, seed=0)
    return FederatedBoostEngine(cfg, data, mode)


@pytest.mark.parametrize("mode", ["baseline", "enhanced"])
def test_traced_engine_run_emits_train_spans(mode):
    with obs.tracing(profile_kernels=False) as tr:
        m = _tiny_engine(mode).run()
        spans = tr.finished()
        reg = obs.get_registry()
        fits = reg.counter("train.fits").value
    names = {d["name"] for d in spans}
    assert "train.fit" in names
    assert ("train.round" if mode == "baseline" else "train.sync") in names
    assert fits > 0
    assert m.final_val_error <= 0.5
    # fit spans carry the virtual clock and client id
    fit = next(d for d in spans if d["name"] == "train.fit")
    assert fit["sim_t0"] is not None and "cid" in fit["attrs"]
    sync = next(d for d in spans
                if d["name"] in ("train.round", "train.sync"))
    assert sync["sim_t1"] is not None and sync["sim_t1"] >= sync["sim_t0"]
    assert check_trace(spans) == []


def test_untraced_engine_run_leaves_no_spans():
    obs.disable()
    before = obs.get_registry().counter("train.fits").value
    _tiny_engine().run()
    # counters still accumulate (always cheap); no tracer was installed
    assert obs.get_registry().counter("train.fits").value > before
    assert obs.get_tracer() is None


# --------------------------------------------------------- kernel profiling

def test_dispatch_profiling_records_launches_and_wall_time():
    rng = np.random.RandomState(0)
    args = (rng.randn(1, 4, 8).astype(np.float32),    # xsel (B, T, N)
            rng.randn(1, 4).astype(np.float32),
            np.sign(rng.randn(1, 4)).astype(np.float32),
            rng.rand(1, 4).astype(np.float32))
    with obs.tracing() as tr:
        out = dispatch("stump_vote_batched", args, backend="xla")
        out2 = dispatch("stump_vote_batched", args, backend="xla")
        reg = obs.get_registry()
        walls = [(labels, h) for name, labels, h in reg.histograms()
                 if name == "kernel.wall_s"]
        compiles = [(labels, h) for name, labels, h in reg.histograms()
                    if name == "kernel.compile_s"]
        counters = [(labels, c) for name, labels, c in reg.counters()
                    if name == "kernel.launches"]
        spans = tr.finished()
    assert out.shape == out2.shape == (1, 8)
    # first-seen (kernel, bucket, backend) launch pays jit trace/compile
    # and lands in kernel.compile_s; the repeat is steady state -> wall_s
    assert len(walls) == 1 and len(compiles) == 1 and len(counters) == 1
    labels, h = walls[0]
    assert labels["kernel"] == "stump_vote_batched"
    assert labels["backend"] == "xla"
    assert h.count == 1 and h.sum > 0
    clabels, ch = compiles[0]
    assert clabels == labels
    assert ch.count == 1 and ch.sum > 0
    assert counters[0][1].value == 2    # launches counts both
    ksp = next(d for d in spans if d["name"].startswith("kernel."))
    assert ksp["name"] == "kernel.stump_vote_batched"
    assert ksp["attrs"]["bucket"] == labels["bucket"]


def test_first_seen_split_is_per_kernel_bucket_backend():
    """The compile_s split keys on (kernel, bucket, backend): a counting
    backend stub shows exactly one compile observation per distinct
    bucket, with repeats all landing in wall_s."""
    from repro.kernels.dispatch import BACKENDS

    class CountingBackend:
        name = "counting"
        calls = 0

        def available(self):
            return True

        def run(self, kernel, *args, **kwargs):
            CountingBackend.calls += 1
            return np.zeros((args[0].shape[0], args[0].shape[2]),
                            np.float32)

    rng = np.random.RandomState(1)

    def mk(B, T, N):
        return (rng.randn(B, T, N).astype(np.float32),
                rng.randn(B, T).astype(np.float32),
                np.sign(rng.randn(B, T)).astype(np.float32),
                rng.rand(B, T).astype(np.float32))

    BACKENDS["counting"] = CountingBackend()
    try:
        with obs.tracing():
            small, big = mk(1, 4, 8), mk(1, 4, 600)
            for _ in range(3):
                dispatch("stump_vote_batched", small, backend="counting")
            dispatch("stump_vote_batched", big, backend="counting")
            reg = obs.get_registry()
            compiles = [(labels, h) for name, labels, h
                        in reg.histograms() if name == "kernel.compile_s"]
            walls = [(labels, h) for name, labels, h in reg.histograms()
                     if name == "kernel.wall_s"]
    finally:
        BACKENDS.pop("counting")
    assert CountingBackend.calls == 4
    # two buckets -> two first-seen compile observations, one each
    assert len(compiles) == 2
    assert all(h.count == 1 for _, h in compiles)
    # only the small bucket repeated -> one wall_s series with 2 obs
    assert len(walls) == 1 and walls[0][1].count == 2


def test_dispatch_unprofiled_records_nothing():
    obs.disable()
    reg = MetricsRegistry()
    old = obs.set_registry(reg)
    try:
        rng = np.random.RandomState(0)
        args = (rng.randn(1, 4, 8).astype(np.float32),
                rng.randn(1, 4).astype(np.float32),
                np.sign(rng.randn(1, 4)).astype(np.float32),
                rng.rand(1, 4).astype(np.float32))
        dispatch("stump_vote_batched", args, backend="xla")
        assert len(reg) == 0
    finally:
        obs.set_registry(old)


def test_calibration_check_flags_stale_winner():
    reg = MetricsRegistry()
    bucket = (128, 8, 8)
    bl = bucket_label(bucket)
    for _ in range(20):
        reg.histogram("kernel.wall_s", kernel="k", bucket=bl,
                      backend="mosaic").observe(5e-3)    # calibrated winner
        reg.histogram("kernel.wall_s", kernel="k", bucket=bl,
                      backend="xla").observe(1e-3)       # actually faster
    pol = KernelPolicy(table={("k", bucket): "mosaic"}, env_var=None)
    flags = calibration_check(policy=pol, registry=reg)
    assert len(flags) == 1
    assert flags[0]["calibrated"] == "mosaic"
    assert flags[0]["observed_best"] == "xla"
    assert flags[0]["observed_best_p50_s"] < flags[0]["calibrated_p50_s"]
    # the flag carries per-backend observation counts for triage
    assert flags[0]["counts"] == {"mosaic": 20, "xla": 20}
    # agreeing observations -> no flag
    pol_ok = KernelPolicy(table={("k", bucket): "xla"}, env_var=None)
    assert calibration_check(policy=pol_ok, registry=reg) == []
    # single-backend observations are skipped, not flagged
    reg2 = MetricsRegistry()
    reg2.histogram("kernel.wall_s", kernel="k", bucket=bl,
                   backend="mosaic").observe(5e-3)
    assert calibration_check(policy=pol, registry=reg2) == []


@pytest.mark.parametrize("n_obs,min_count,expect_flag", [
    (4, 5, False),     # below the default floor -> too noisy, skipped
    (5, 5, True),      # at the floor -> counted
    (2, 2, True),      # caller-lowered floor
    (19, 20, False),   # caller-raised floor
])
def test_calibration_check_min_count_floor(n_obs, min_count, expect_flag):
    """Histograms with fewer than min_count observations per backend are
    p50-unstable and must not generate drift flags."""
    reg = MetricsRegistry()
    bucket = (128, 8, 8)
    bl = bucket_label(bucket)
    for _ in range(n_obs):
        reg.histogram("kernel.wall_s", kernel="k", bucket=bl,
                      backend="mosaic").observe(5e-3)
        reg.histogram("kernel.wall_s", kernel="k", bucket=bl,
                      backend="xla").observe(1e-3)
    pol = KernelPolicy(table={("k", bucket): "mosaic"}, env_var=None)
    flags = calibration_check(policy=pol, registry=reg,
                              min_count=min_count)
    assert (len(flags) == 1) is expect_flag
    if expect_flag:
        assert flags[0]["counts"] == {"mosaic": n_obs, "xla": n_obs}


# ----------------------------------------------------------------- reporter

def _mk(name, span, parent, t0, t1, **attrs):
    return {"name": name, "span": span, "parent": parent, "t0": t0,
            "t1": t1, "sim_t0": None, "sim_t1": None, "attrs": attrs}


def test_report_self_times_and_folded_stacks():
    spans = [
        _mk("train.round", 1, None, 0.0, 10.0),
        _mk("train.fit", 2, 1, 1.0, 4.0),
        _mk("train.fit", 3, 1, 5.0, 9.0),
        _mk("serve.batch", 4, None, 20.0, 21.0),
    ]
    self_s = self_times(spans)
    assert self_s[1] == pytest.approx(3.0)      # 10 - (3 + 4)
    assert self_s[2] == pytest.approx(3.0)
    agg = {a["name"]: a for a in aggregate(spans)}
    assert agg["train.fit"]["count"] == 2
    assert agg["train.fit"]["total_s"] == pytest.approx(7.0)
    phases = {ns: (sec, n) for ns, sec, n in phase_breakdown(spans)}
    assert phases["train"][0] == pytest.approx(10.0)
    assert phases["serve"][0] == pytest.approx(1.0)
    folded = dict(folded_stacks(spans))
    assert folded["train.round;train.fit"] == pytest.approx(7e6)
    assert folded["train.round"] == pytest.approx(3e6)
    assert folded["serve.batch"] == pytest.approx(1e6)


def test_check_trace_catches_violations():
    ok = [_mk("a", 1, None, 0.0, 2.0), _mk("b", 2, 1, 0.5, 1.5)]
    assert check_trace(ok) == []
    assert check_trace([_mk("a", 1, None, 2.0, 1.0)])          # t1 < t0
    assert check_trace([_mk("a", 1, None, 0.0, None)])         # unended
    assert check_trace([_mk("a", 1, None, 0.0, 1.0),
                        _mk("a", 1, None, 0.0, 1.0)])          # dup id
    assert check_trace([_mk("a", 1, None, 0.0, 1.0),
                        _mk("b", 2, 1, 0.5, 5.0)])             # escapes parent
    bad_req = _mk("serve.request", 2, 1, 0.5, 0.6,
                  batch_s=0.1, queue_s=0.1, kernel_s=0.1, latency_s=0.5)
    assert check_trace([_mk("serve.batch", 1, None, 0.0, 1.0), bad_req])
