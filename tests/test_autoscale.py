"""Fleet autoscaling and per-(tenant, host) policies: eq.-(1) pressure
controller bounds, gossip-warmed scale-out, loss-free scale-in drains,
PolicyTable resolution/JSON, per-tenant admission + batch caps, and
per-tenant kernel-policy partitioning."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dispatch import KernelPolicy
from repro.serve import (AutoscaleConfig, BatchConfig, EnsembleRegistry,
                         EnsembleServer, FleetAutoscaler, GossipConfig,
                         PolicyTable, ShardCluster, ShardedEnsembleServer)


def _publish(target, tenant, T=4, F=6, seed=0, clock=0.0):
    rng = np.random.RandomState(seed)
    p = np.zeros((T, 4), np.float32)
    p[:, 0] = rng.randint(0, F, size=T)
    p[:, 1] = rng.randn(T)
    p[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    a = (rng.rand(T) + 0.1).astype(np.float32)
    return target.publish_packed(tenant, jnp.asarray(p), jnp.asarray(a),
                                 clock=clock)


def _cluster(n_hosts, tenants, seed=0):
    cluster = ShardCluster(n_hosts, GossipConfig(seed=seed))
    for i, t in enumerate(tenants):
        _publish(cluster, t, seed=i)
    cluster.run_until_quiescent()
    return cluster


TENANTS = [f"t{i}" for i in range(6)]


# ------------------------------------------------------------ policy table
def test_policy_table_resolution_precedence():
    pt = PolicyTable(BatchConfig(max_batch=16, queue_budget=100))
    pt.set_host("h0", max_batch=32)
    pt.set_tenant("hot", queue_budget=400, max_batch=64)
    pt.set_pair("hot", "h0", max_batch=8)
    assert pt.batch_for().max_batch == 16               # fleet default
    assert pt.batch_for(host="h0").max_batch == 32      # host layer
    assert pt.batch_for("hot", "h1").max_batch == 64    # tenant over host
    assert pt.batch_for("hot", "h1").queue_budget == 400
    assert pt.batch_for("hot", "h0").max_batch == 8     # pair most specific
    assert pt.batch_for("hot", "h0").queue_budget == 400  # merged field-wise
    assert pt.batch_for("cold", "h9") is pt.batch_for()  # untouched scopes
    with pytest.raises(ValueError):
        pt.set_tenant("x", no_such_field=1)
    with pytest.raises(ValueError):
        pt.set_host("h0", scheduler=None)               # fleet-wide only
    with pytest.raises(ValueError):
        # host-server knobs at tenant scope would be silently ignored —
        # refused instead (only queue_budget/max_batch resolve per tenant)
        pt.set_tenant("x", fixed_window_units=1)
    with pytest.raises(ValueError):
        pt.set_pair("x", "h0", cache_capacity=64)
    pt.set_host("h0", fixed_window_units=1)             # host scope: fine


def test_policy_table_kernel_resolution():
    xla, interp = KernelPolicy(backend="xla"), KernelPolicy(
        backend="interpret")
    pt = PolicyTable()
    assert pt.kernel_for("a", "h0") is None             # caller's policy
    pt.set_host("h0", kernel=interp)
    pt.set_tenant("a", kernel=xla)
    assert pt.kernel_for("a", "h0") is xla              # tenant over host
    assert pt.kernel_for("b", "h0") is interp
    assert pt.kernel_for("b", "h1") is None


def test_policy_table_json_roundtrip(tmp_path):
    path = tmp_path / "policies.json"
    pt = PolicyTable(BatchConfig(max_batch=32),
                     default_kernel=KernelPolicy(backend="xla"))
    pt.set_tenant("hot", queue_budget=1024,
                  kernel=KernelPolicy(backend="interpret"))
    pt.set_host("h1", cache_capacity=128)
    pt.set_pair("hot", "h1", max_batch=4)
    pt.save(path)
    back = PolicyTable.load(path)
    assert back.batch_for().max_batch == 32
    assert back.default_kernel.backend == "xla"
    assert back.batch_for("hot", "h0").queue_budget == 1024
    assert back.batch_for(host="h1").cache_capacity == 128
    assert back.batch_for("hot", "h1").max_batch == 4
    assert back.kernel_for("hot", "h9").backend == "interpret"
    with pytest.raises(ValueError):
        bad = dict(json.loads(path.read_text()), pairs={"nohost": {}})
        path.write_text(json.dumps(bad))
        PolicyTable.load(path)
    # an empty kernel spec would mask broader pins as "most specific"
    path.write_text(json.dumps({"tenants": {"a": {"kernel": {}}}}))
    with pytest.raises(ValueError):
        PolicyTable.load(path)


def test_per_tenant_queue_budget_and_batch_cap():
    reg = EnsembleRegistry()
    _publish(reg, "hot", seed=1)
    _publish(reg, "cold", seed=2)
    pt = PolicyTable(BatchConfig(queue_budget=8, max_batch=16,
                                 adaptive=False, fixed_window_units=1000))
    pt.set_tenant("cold", queue_budget=2, max_batch=1)
    server = EnsembleServer(reg, policy_table=pt, host_id="h0",
                            service_model=lambda n: 1e-4)
    # cold admission stops at its own budget while the host queue has room
    assert server.submit("cold", np.zeros(6, np.float32), 0.0)[0]
    assert server.submit("cold", np.zeros(6, np.float32), 0.0)[0]
    assert not server.submit("cold", np.zeros(6, np.float32), 0.0)[0]
    assert server.metrics.tenants["cold"].rejected == 1
    # hot fills the remaining host budget (max_batch 16 > budget: no
    # size-capped dispatch fires under the 1 s window)
    for _ in range(6):
        assert server.submit("hot", np.zeros(6, np.float32), 0.0)[0]
    assert not server.submit("hot", np.zeros(6, np.float32), 0.0)[0]
    # one dispatched batch carries at most cold's max_batch of its requests
    batch = server.queue.pop_batch()
    assert len(batch) == 7
    assert sum(r.tenant == "cold" for r in batch) == 1
    assert sum(r.tenant == "hot" for r in batch) == 6
    assert [r.tenant for r in server.queue.pop_batch()] == ["cold"]


def test_per_tenant_batch_cap_preserves_fifo_of_overflow():
    from repro.serve import MicroBatchQueue
    cfg = BatchConfig(queue_budget=64, max_batch=4)
    capped = BatchConfig(queue_budget=64, max_batch=1)
    q = MicroBatchQueue(cfg, tenant_cfg=lambda t: capped if t == "c" else cfg)
    for i, t in enumerate("ccab"):
        q.submit(t, [float(i)], float(i))
    first = q.pop_batch()
    assert [r.tenant for r in first] == ["c", "a", "b"]  # 2nd c deferred
    assert [r.tenant for r in q.pop_batch()] == ["c"]    # kept FIFO slot
    assert q.depth == 0


def test_hot_tenant_raises_above_host_scope_take_effect():
    """The README's hot-tenant example must not be a silent no-op: a
    tenant's queue_budget/max_batch above the host scope really do admit
    more and batch bigger."""
    from repro.serve import MicroBatchQueue
    pt = PolicyTable(BatchConfig(queue_budget=4, max_batch=2))
    pt.set_tenant("hot", queue_budget=10, max_batch=8)
    q = MicroBatchQueue(pt.batch_for(host="h0"),
                        tenant_cfg=lambda t: pt.batch_for(t, "h0"))
    for _ in range(2):
        assert q.submit("cold", [0.0], 0.0) is not None
    # hot admits past the host budget of 4, up to its own 10 total
    for _ in range(8):
        assert q.submit("hot", [0.0], 0.0) is not None
    assert q.submit("hot", [0.0], 0.0) is None
    # cold is behind the host-budget total cap the whole time
    assert q.submit("cold", [0.0], 0.0) is None
    assert q.rejected == 2
    # hot's raised max_batch lifts the shared bound to 8; cold's share
    # rides along within its own (host-scope) cap
    batch = q.pop_batch()
    assert len(batch) == 8
    assert sum(r.tenant == "hot" for r in batch) == 6
    assert sum(r.tenant == "cold" for r in batch) == 2
    assert [r.tenant for r in q.pop_batch()] == ["hot", "hot"]


def test_cluster_remove_host_hands_window_to_down_survivor_or_refuses():
    cluster = _cluster(2, ["t0"])
    other = [h for h in cluster.hosts if h != cluster.owner("t0")][0]
    owner = cluster.owner("t0")
    v2 = _publish(cluster, "t0", T=5, seed=9)     # on owner only
    cluster.mark_down(other)                      # no up survivor left...
    cluster.remove_host(owner)
    # ...yet the window survived on the down replica
    assert cluster.hosts[other].registry.latest("t0").fingerprint \
        == v2.fingerprint
    with pytest.raises(ValueError):
        cluster.remove_host(other)                # last host: refuse


def test_value_equal_kernel_policies_share_one_launch(monkeypatch):
    """Tenants whose table entries resolve to value-identical policies
    (e.g. every tenant pinning the same backend in JSON) must share one
    packed cross-tenant launch, not one launch per tenant."""
    from repro.serve import engine as engine_mod
    reg = EnsembleRegistry()
    for i, t in enumerate("abc"):
        _publish(reg, t, seed=i)
    pt = PolicyTable()
    for t in "abc":                               # three distinct objects
        pt.set_tenant(t, kernel=KernelPolicy(backend="xla"))
    calls = []
    real = engine_mod.kops.stump_vote_batched
    monkeypatch.setattr(engine_mod.kops, "stump_vote_batched",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    server = EnsembleServer(reg, policy_table=pt, host_id="h",
                            service_model=lambda n: 1e-4)
    for t in "abc":
        server.submit(t, np.zeros(6, np.float32), 0.0)
    assert len(server.drain()) == 3
    assert len(calls) == 1                        # one packed (B,T,N) launch


def test_per_tenant_kernel_policy_partitions_launches():
    reg = EnsembleRegistry()
    snaps = {t: _publish(reg, t, seed=i) for i, t in enumerate("ab")}
    xla, interp = KernelPolicy(backend="xla"), KernelPolicy(
        backend="interpret")
    pt = PolicyTable(BatchConfig(max_batch=16))
    pt.set_tenant("a", kernel=xla)
    pt.set_tenant("b", kernel=interp)
    server = EnsembleServer(reg, policy_table=pt, host_id="h",
                            service_model=lambda n: 1e-4)
    rng = np.random.RandomState(0)
    xs = {t: rng.randn(6).astype(np.float32) for t in "ab"}
    for t in "ab":
        server.submit(t, xs[t], 0.0)
    responses = server.drain()
    assert len(responses) == 2
    # each tenant's launch went through its own policy's dispatcher
    assert {b for b in xla.choices.values()} == {"xla"}
    assert {b for b in interp.choices.values()} == {"interpret"}
    for r in responses:
        sp = np.asarray(snaps[r.tenant].stump_params)
        al = np.asarray(snaps[r.tenant].alphas)
        xv = np.asarray(xs[r.tenant])[sp[:, 0].astype(int)]
        want = float(np.dot(al, sp[:, 2] * np.sign(xv - sp[:, 1] + 1e-12)))
        assert r.margin == pytest.approx(want, abs=1e-5)


def test_explicit_cfg_composes_with_policy_table():
    """An explicit BatchConfig passed alongside a table is not discarded:
    it becomes the fleet default the table's overrides layer onto."""
    cluster = _cluster(2, TENANTS[:2])
    pt = PolicyTable(BatchConfig(queue_budget=999))
    pt.set_tenant("t0", max_batch=4)
    explicit = BatchConfig(queue_budget=5, adaptive=False,
                           fixed_window_units=7)
    server = ShardedEnsembleServer(cluster, explicit, policy_table=pt,
                                   service_model=lambda n: 1e-4)
    for s in server.servers.values():
        assert s.cfg.queue_budget == 5          # explicit beats table default
        assert s.cfg.fixed_window_units == 7
    hid = next(iter(server.servers))
    resolved = server.servers[hid].policy_table.batch_for("t0", hid)
    assert resolved.max_batch == 4              # tenant override still layers
    assert resolved.queue_budget == 5


# ------------------------------------------------------------- membership
def test_scale_out_warms_replica_before_joining():
    cluster = _cluster(2, TENANTS)
    digests = cluster.digests()
    new = cluster.add_host("h-new")
    assert new.up
    # warmed via gossip pull before entering the ring: full replica at join
    assert new.registry.digest() == next(iter(digests.values()))
    assert "h-new" in cluster.host_ids()
    with pytest.raises(ValueError):
        cluster.add_host("h-new")


def test_add_host_warms_from_down_replicas_under_total_outage():
    """Replacing a dead fleet must not put an empty cold replica into the
    ring: with zero up peers, warm-up pulls from the down replicas'
    stores, so the first routable host still holds the data."""
    cluster = _cluster(2, TENANTS)
    want = {t: cluster.latest(t).fingerprint for t in TENANTS}
    for hid in list(cluster.hosts):
        cluster.mark_down(hid)
    new = cluster.add_host("replacement")
    assert new.up
    for t in TENANTS:
        assert new.registry.latest(t).fingerprint == want[t]
    assert cluster.route(TENANTS[0]).host_id == "replacement"


def test_remove_host_hands_unpublished_window_to_survivor():
    cluster = _cluster(3, TENANTS)
    owner = cluster.owner("t0")
    v2 = _publish(cluster, "t0", T=5, seed=9)     # not yet gossiped out
    assert v2.version == 2
    cluster.remove_host(owner)
    assert owner not in cluster.hosts
    # the un-gossiped publish survived the removal on some survivor...
    assert any(h.registry.get("t0", 2) is not None
               for h in cluster.hosts.values())
    # ...and anti-entropy then spreads it fleet-wide
    cluster.run_until_quiescent()
    assert cluster.converged()
    for h in cluster.hosts.values():
        assert h.registry.latest("t0").fingerprint == v2.fingerprint


def test_scale_in_drains_without_losing_accepted_requests():
    cluster = _cluster(3, TENANTS)
    server = ShardedEnsembleServer(
        cluster, BatchConfig(adaptive=False, fixed_window_units=10_000,
                             max_batch=64, queue_budget=64),
        service_model=lambda n: 1e-4)
    rng = np.random.RandomState(0)
    accepted = []
    for i in range(30):
        ok, out = server.submit(TENANTS[i % len(TENANTS)],
                                rng.randn(6).astype(np.float32), now=1e-4 * i)
        assert ok and out == []                   # giant window: all queued
    victims = [hid for hid, s in server.servers.items() if s.queue.depth]
    victim = victims[0]
    depth = server.servers[victim].queue.depth
    responses, rerouted = server.remove_host(victim, now=0.01)
    assert rerouted == depth and responses == []  # window far away: reroute
    assert victim not in server.servers and victim not in cluster.hosts
    responses += server.drain()
    rids = sorted(r.rid for r in responses)
    assert rids == list(range(30))                # zero loss, no duplicates
    rep = server.report()
    assert rep["completed"] == 30
    assert rep["per_host"][victim]["status"] == "retired"
    # rerouted requests kept their original submit time across the move
    assert all(r.t_submit <= 1e-4 * 30 for r in responses)


def test_remove_last_up_host_with_queued_requests_refuses():
    cluster = _cluster(2, TENANTS[:2])
    server = ShardedEnsembleServer(
        cluster, BatchConfig(adaptive=False, fixed_window_units=10_000),
        service_model=lambda n: 1e-4)
    hid0, hid1 = list(server.servers)
    server.remove_host(hid0)
    loaded = server.servers[hid1]
    assert server.submit(TENANTS[0], np.zeros(6, np.float32), 0.0)[0]
    assert loaded.queue.depth == 1
    with pytest.raises(ValueError):
        server.remove_host(hid1)
    assert hid1 in server.servers                 # refused: still serving
    assert len(server.drain()) == 1


def test_retired_host_id_cannot_be_reused():
    cluster = _cluster(3, TENANTS)
    server = ShardedEnsembleServer(cluster, BatchConfig(),
                                   service_model=lambda n: 1e-4)
    victim = next(iter(server.servers))
    server.remove_host(victim)
    with pytest.raises(ValueError):
        server.add_host(victim)                 # report keys stay unique
    server.add_host("fresh-0")
    assert "fresh-0" in server.servers


def test_autoscaler_sheds_downed_host_first_and_reroutes_its_queue():
    """A host marked down by failover is not capacity: scale-in must pick
    it over a live host and reroute its stuck queue onto survivors."""
    cluster = _cluster(3, TENANTS)
    server = ShardedEnsembleServer(
        cluster, BatchConfig(adaptive=False, fixed_window_units=10_000,
                             queue_budget=64),
        service_model=lambda n: 1e-4)
    scaler = FleetAutoscaler(server, AutoscaleConfig(
        min_hosts=1, max_hosts=3, target_queue=64.0, adapt_every_s=0.01,
        step_down=1.0))
    rng = np.random.RandomState(0)
    accepted = 0
    for i in range(18):                         # queue a little everywhere
        accepted += server.submit(TENANTS[i % len(TENANTS)],
                                  rng.randn(6).astype(np.float32),
                                  now=1e-4 * i)[0]
    dead = max(server.servers,
               key=lambda hid: server.servers[hid].queue.depth)
    stuck = server.servers[dead].queue.depth
    assert stuck > 0
    cluster.mark_down(dead)
    responses, t = [], 0.0
    while scaler.stats.scale_ins == 0 and t < 2.0:   # idle: pressure ~ 0
        t += 0.02
        responses += server.advance(t)
        responses += scaler.step(t)
    assert scaler.stats.scale_ins >= 1
    # the dead host is not capacity: the controller may first scale out a
    # replacement (up-count below target), but the first host it *sheds*
    # must be the dead replica, not a live one
    ins = [e for e in scaler.stats.events if e[1] == "in"]
    assert ins[0][2] == dead
    assert scaler.stats.rerouted == stuck       # its queue moved, not lost
    responses += server.drain()
    rids = [r.rid for r in responses]
    assert len(rids) == accepted and len(set(rids)) == accepted


# -------------------------------------------------------------- controller
def test_autoscaler_scales_out_under_pressure_and_back_in_when_idle():
    cluster = _cluster(1, TENANTS)
    server = ShardedEnsembleServer(
        cluster, BatchConfig(queue_budget=16, max_batch=4, adaptive=False,
                             fixed_window_units=1),
        service_model=lambda n: 5e-3)
    scaler = FleetAutoscaler(server, AutoscaleConfig(
        min_hosts=1, max_hosts=3, target_queue=2.0, adapt_every_s=0.01,
        step_down=1.0))
    rng = np.random.RandomState(0)
    responses, accepted, t = [], 0, 0.0
    for i in range(300):                          # sustained overload
        t += 5e-4
        ok, out = server.submit(TENANTS[i % len(TENANTS)],
                                rng.randn(6).astype(np.float32), t)
        accepted += ok
        responses += out
        responses += scaler.step(t)
    assert scaler.stats.scale_outs >= 1
    assert len(server.servers) <= 3               # eq.-(1) clip: bounded
    grown = len(server.servers)
    assert grown > 1
    for _ in range(200):                          # idle: pressure ~ 0
        t += 0.02
        responses += server.advance(t)
        responses += scaler.step(t)
    assert scaler.stats.scale_ins >= 1
    assert len(server.servers) >= 1               # floor respected
    assert len(server.servers) < grown
    responses += server.drain()
    rids = [r.rid for r in responses]
    assert len(rids) == accepted and len(set(rids)) == accepted
    assert server.report()["completed"] == accepted


def test_rebuilt_autoscaler_skips_retired_ids():
    """A second FleetAutoscaler on the same fleet restarts its id sequence;
    its first scale-out must probe past ids already taken (live or
    retired) instead of crashing on add_host's reuse refusal."""
    cluster = _cluster(1, TENANTS)
    server = ShardedEnsembleServer(
        cluster, BatchConfig(queue_budget=16, max_batch=4, adaptive=False,
                             fixed_window_units=1),
        service_model=lambda n: 5e-3)
    cfg = AutoscaleConfig(min_hosts=1, max_hosts=3, target_queue=2.0,
                          adapt_every_s=0.01, step_down=1.0)

    def overload(scaler, t0):
        rng, t = np.random.RandomState(0), t0
        for i in range(200):
            t += 5e-4
            server.submit(TENANTS[i % len(TENANTS)],
                          rng.randn(6).astype(np.float32), t)
            scaler.step(t)
        return t

    first = FleetAutoscaler(server, cfg)
    t = overload(first, 0.0)
    for _ in range(200):                          # drain back to min
        t += 0.02
        server.advance(t)
        first.step(t)
    assert first.stats.scale_ins >= 1             # 'scale-0' now retired
    second = FleetAutoscaler(server, cfg)         # sequence restarts at 0
    t = overload(second, t)
    assert second.stats.scale_outs >= 1           # no ValueError collision
    server.drain()


def test_two_host_autoscaled_fleet_membership_churn_is_loss_free():
    """The CI serve-fleet leg's anchor: a 2-host fleet under a bursty load
    with live churn (autoscaler-driven scale-outs and scale-ins) must
    answer every accepted request exactly once and keep a coherent merged
    report."""
    cluster = _cluster(2, TENANTS)
    server = ShardedEnsembleServer(
        cluster, BatchConfig(queue_budget=16, max_batch=4, adaptive=False,
                             fixed_window_units=1),
        service_model=lambda n: 4e-3)
    scaler = FleetAutoscaler(server, AutoscaleConfig(
        min_hosts=2, max_hosts=4, target_queue=2.0, adapt_every_s=0.01,
        step_down=1.0))
    rng = np.random.RandomState(7)
    responses, accepted, t = [], 0, 0.0
    for burst in range(4):                        # on/off phases force churn
        for i in range(150):
            t += 4e-4
            ok, out = server.submit(TENANTS[rng.randint(len(TENANTS))],
                                    rng.randn(6).astype(np.float32), t)
            accepted += ok
            responses += out
            responses += scaler.step(t)
        for _ in range(60):
            t += 0.02
            responses += server.advance(t)
            responses += scaler.step(t)
    responses += server.drain()
    assert scaler.stats.scale_outs >= 1 and scaler.stats.scale_ins >= 1
    rids = [r.rid for r in responses]
    assert len(rids) == accepted and len(set(rids)) == accepted
    rep = server.report()
    assert rep["completed"] == accepted
    assert 2 <= len(server.servers) <= 4
    statuses = {h["status"] for h in rep["per_host"].values()}
    assert "retired" in statuses and "up" in statuses


# ----------------------------------------------------- cost-aware budget
def _bursty_submit(server, scaler, rate, duration, seed=0):
    """Drive a bursty closed loop (3x on-phase / 0.1x off-phase, as in
    benchmarks/autoscale_load) and return (accepted, rids, max_hosts)."""
    rng = np.random.RandomState(seed)
    accepted, rids, max_hosts, t = 0, [], len(server.servers), 0.0
    while t < duration:
        lam = rate * (3.0 if (t % 0.5) < 0.25 else 0.1)
        t += rng.exponential(1.0 / max(lam, 1e-9))
        if t >= duration:
            break
        ok, out = server.submit(TENANTS[rng.randint(len(TENANTS))],
                                rng.randn(6).astype(np.float32), t)
        accepted += ok
        rids.extend(r.rid for r in out)
        if scaler is not None:
            rids.extend(r.rid for r in scaler.step(t))
            max_hosts = max(max_hosts, len(server.servers))
    rids.extend(r.rid for r in server.drain())
    return accepted, rids, max_hosts


def test_budget_caps_scale_out_under_1800rps_burst():
    """Cost-aware knob: with hosts at 0.5 $/h and a 1.5 $/h budget the
    fleet may afford 3 hosts; the same 1800 rps burst that grows an
    uncapped fleet past 3 must leave the capped fleet at <= 3 with the
    refusals counted — and still lose no accepted request."""
    cfg = AutoscaleConfig(min_hosts=2, max_hosts=8, target_queue=16.0,
                          target_p99_s=0.10, adapt_every_s=0.02,
                          step_down=0.1)
    batch = BatchConfig(queue_budget=64, max_batch=16)
    model = lambda n: 1.2e-3 + 8.0e-4 * n

    results = {}
    for label, kwargs in (("uncapped", {}),
                          ("capped", {"budget_per_host": 0.5,
                                      "budget_per_hour": 1.5})):
        cluster = _cluster(2, TENANTS)
        server = ShardedEnsembleServer(cluster, batch, service_model=model)
        scaler = FleetAutoscaler(server, cfg, **kwargs)
        accepted, rids, max_hosts = _bursty_submit(server, scaler,
                                                   rate=1800.0, duration=1.5)
        assert len(rids) == accepted and len(set(rids)) == accepted
        results[label] = (scaler, max_hosts)

    uncapped, uncapped_max = results["uncapped"]
    capped, capped_max = results["capped"]
    assert uncapped_max > 3              # the burst genuinely wants > 3 hosts
    assert capped_max <= 3               # ... but the budget binds
    assert capped.stats.budget_capped > 0
    assert capped.max_affordable() == 3
    assert capped.projected_cost(3) == pytest.approx(1.5)
    assert uncapped.stats.budget_capped == 0


def test_budget_never_forces_below_min_hosts():
    # a budget below the floor refuses growth but never drives the fleet
    # under min_hosts
    cluster = _cluster(2, TENANTS)
    server = ShardedEnsembleServer(cluster, BatchConfig(queue_budget=32))
    scaler = FleetAutoscaler(server,
                             AutoscaleConfig(min_hosts=2, max_hosts=8),
                             budget_per_host=1.0, budget_per_hour=0.5)
    assert scaler.max_affordable() == 2
    for i in range(50):
        scaler.step(i * 0.1)
    assert len(server.servers) == 2
