"""Dry-run integration: one representative (arch x shape) per mode lowers
and compiles against the production mesh in a subprocess (512 placeholder
devices; the main pytest process keeps 1 device).

The full 10-arch x 4-shape x 2-mesh sweep is run by
``python -m repro.launch.dryrun --all [--multi-pod]`` and recorded in
EXPERIMENTS.md §Dry-run; this test guards the machinery.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run(arch, shape, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--force", *extra]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900, cwd=REPO)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    art = os.path.join(REPO, "artifacts", "dryrun")
    mesh = "pod2x16x16" if "--multi-pod" in extra else "pod16x16"
    with open(os.path.join(art, f"{arch}__{shape}__{mesh}__baseline.json")) as f:
        return json.load(f)


@pytest.mark.slow
def test_dryrun_train_single_pod():
    rec = _run("qwen1.5-0.5b", "train_4k")
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["flops_corrected"] > 1e12          # ~19 TF/device expected
    assert rec["collective_bytes_total"] > 0
    assert rec["memory_analysis"].get("argument_size_in_bytes", 0) > 0


@pytest.mark.slow
def test_dryrun_decode_multi_pod():
    rec = _run("qwen1.5-0.5b", "decode_32k", ("--multi-pod",))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 512


def test_skip_rule_encoded():
    """Full-attention archs skip long_500k (no subprocess needed)."""
    from repro.configs.registry import ARCHS, SHAPES, shape_applicable
    skipped = [a for a in ARCHS
               if not shape_applicable(ARCHS[a], SHAPES["long_500k"])]
    assert set(skipped) == {
        "qwen2.5-3b", "yi-9b", "qwen1.5-0.5b", "qwen3-moe-30b-a3b",
        "llama4-scout-17b-a16e", "whisper-base", "chameleon-34b"}
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert all(shape_applicable(ARCHS[a], SHAPES[s]) for a in ARCHS)
