"""Partitioner property suite: every partitioner yields a cover of the
dataset with no within-client duplicates, is deterministic per seed, and
respects the minimum-samples floor; iid/label_shard covers are exactly
disjoint.  (Dirichlet's >=8-sample top-up may duplicate samples *across*
clients — never within one client; that within-client duplication was the
bug this suite pins.)"""
import numpy as np
import pytest

from repro.data.partition import (
    dirichlet_partition, iid_partition, label_shard_partition)

# the property tests are hypothesis-gated (CI's property-suites job runs
# them and forbids skips); the deterministic regression tests below run
# everywhere
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


def _tagged_data(n: int, seed: int, n_classes: int = 2):
    """x[:, 0] is a unique sample id so partition outputs are traceable
    back to dataset indices."""
    rng = np.random.RandomState(seed)
    x = np.stack([np.arange(n, dtype=np.float64), rng.randn(n)], axis=1)
    y = rng.randint(0, n_classes, size=n).astype(np.float64) * 2.0 - 1.0
    return x, y


def _ids(parts):
    return [p[0][:, 0].astype(int) for p in parts]


def _check_cover_floor_unique(parts, n: int):
    ids = _ids(parts)
    for cid, idc in enumerate(ids):
        assert len(np.unique(idc)) == len(idc), (
            f"client {cid} holds duplicate samples")
        assert len(idc) >= min(8, n)
        assert (0 <= idc).all() and (idc < n).all()
    covered = set(np.concatenate(ids).tolist())
    assert covered == set(range(n)), "partition must cover the dataset"
    # labels must travel with their features
    for x, y in parts:
        assert x.shape[0] == y.shape[0]


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(n=st.integers(24, 400), n_clients=st.integers(2, 8),
           alpha=st.floats(0.02, 5.0), seed=st.integers(0, 10_000))
    def test_dirichlet_cover_unique_floor_deterministic(n, n_clients, alpha,
                                                        seed):
        x, y = _tagged_data(n, seed)
        parts = dirichlet_partition(x, y, n_clients, alpha,
                                    np.random.RandomState(seed))
        _check_cover_floor_unique(parts, n)
        again = dirichlet_partition(x, y, n_clients, alpha,
                                    np.random.RandomState(seed))
        for (xa, ya), (xb, yb) in zip(parts, again):
            np.testing.assert_array_equal(xa, xb)
            np.testing.assert_array_equal(ya, yb)

    @settings(max_examples=40, deadline=None)
    @given(n=st.integers(16, 400), n_clients=st.integers(2, 8),
           seed=st.integers(0, 10_000))
    def test_iid_exact_disjoint_cover_deterministic(n, n_clients, seed):
        x, y = _tagged_data(n, seed)
        parts = iid_partition(x, y, n_clients, np.random.RandomState(seed))
        ids = _ids(parts)
        allids = np.concatenate(ids)
        assert len(allids) == n and len(set(allids.tolist())) == n
        again = iid_partition(x, y, n_clients, np.random.RandomState(seed))
        for (xa, _), (xb, _) in zip(parts, again):
            np.testing.assert_array_equal(xa, xb)

    @settings(max_examples=40, deadline=None)
    @given(n_clients=st.integers(2, 6), shards=st.integers(1, 4),
           seed=st.integers(0, 10_000), extra=st.integers(0, 50))
    def test_label_shard_exact_disjoint_cover_deterministic(n_clients,
                                                            shards, seed,
                                                            extra):
        n = n_clients * shards * 8 + extra
        x, y = _tagged_data(n, seed)
        parts = label_shard_partition(x, y, n_clients, shards,
                                      np.random.RandomState(seed))
        ids = _ids(parts)
        allids = np.concatenate(ids)
        assert len(allids) == n and len(set(allids.tolist())) == n
        again = label_shard_partition(x, y, n_clients, shards,
                                      np.random.RandomState(seed))
        for (xa, _), (xb, _) in zip(parts, again):
            np.testing.assert_array_equal(xa, xb)


def test_dirichlet_topup_regression_no_within_client_duplicates():
    """The pre-fix top-up handed starved clients indices they already held
    (pool.pop() ignored current holdings).  Extreme skew + a tiny dataset
    forces the top-up path for most clients."""
    for seed in range(20):
        n = 12
        x, y = _tagged_data(n, seed)
        parts = dirichlet_partition(x, y, 3, alpha=0.01,
                                    rng=np.random.RandomState(seed))
        _check_cover_floor_unique(parts, n)


def test_dirichlet_floor_caps_at_dataset_size():
    # fewer than 8 distinct samples exist: the floor is n, not 8, and the
    # top-up must not spin forever hunting for an impossible 8th sample
    n = 5
    x, y = _tagged_data(n, 0)
    parts = dirichlet_partition(x, y, 2, alpha=0.05,
                                rng=np.random.RandomState(0))
    for idc in _ids(parts):
        assert len(np.unique(idc)) == len(idc)
        assert len(idc) >= n
    _check_cover_floor_unique(parts, n)


def test_dirichlet_no_topup_means_exactly_disjoint():
    # plenty of data per client: no top-up fires, so the split is a true
    # partition (each sample on exactly one client)
    n = 2000
    x, y = _tagged_data(n, 1)
    parts = dirichlet_partition(x, y, 4, alpha=5.0,
                                rng=np.random.RandomState(1))
    ids = _ids(parts)
    allids = np.concatenate(ids)
    assert len(allids) == n and len(set(allids.tolist())) == n
