"""Trip-count-aware HLO analyzer unit tests (synthetic HLO snippets)."""
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo

HLO = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant(0)
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  %wl = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"},"known_init_step":{"init":"0","step":"1"}}
  %out = f32[8,8]{1,0} get-tuple-element(%wl), index=1
  %g = f32[8,8]{1,0} all-gather(%out), replica_groups={}, dimensions={0}
  ROOT %r = f32[8,8]{1,0} add(%g, %g)
}
"""


def test_parse_computations():
    comps = parse_hlo(HLO)
    assert any("body" in c for c in comps)
    assert any("main" in c for c in comps)


def test_trip_count_multiplies_loop_flops():
    r = analyze(HLO)
    # dot: 2*8*8*8 = 1024 flops, in a 5-trip loop
    assert r["flops_corrected"] == pytest.approx(5 * 1024)
    assert r["flops_loop_body_once"] == pytest.approx(1024)


def test_trip_count_multiplies_loop_collectives():
    r = analyze(HLO)
    ar = r["collectives"]["all-reduce"]
    assert ar["bytes"] == pytest.approx(5 * 8 * 8 * 4)
    ag = r["collectives"]["all-gather"]
    assert ag["bytes"] == pytest.approx(8 * 8 * 4)   # outside the loop: x1


def test_bytes_accessed_positive():
    r = analyze(HLO)
    assert r["bytes_accessed_corrected"] > 0
