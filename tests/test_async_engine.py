"""End-to-end behaviour of the async federated boosting engine — including
the paper's headline claims on a representative domain (full five-domain
validation lives in benchmarks/domains.py)."""
import dataclasses

import pytest

from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.core.metrics import common_target, time_to_error
from repro.data import make_domain_data


@pytest.fixture(scope="module")
def edge_runs():
    dom = DOMAINS["edge_vision"]
    data = make_domain_data(dom, seed=0)
    cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=25,
                         straggler_factor=dom.straggler_factor,
                         dropout_prob=dom.dropout_prob,
                         link_mbps=dom.link_mbps)
    return {m: FederatedBoostEngine(cfg, data, m).run()
            for m in ("baseline", "enhanced")}


def test_both_modes_learn(edge_runs):
    for m in edge_runs.values():
        assert m.final_val_error < 0.35


def test_comm_overhead_reduced(edge_runs):
    b, e = edge_runs["baseline"], edge_runs["enhanced"]
    assert e.total_bytes < b.total_bytes * 0.8          # >= 20% reduction
    assert e.n_messages < b.n_messages * 0.7


def test_fewer_syncs_than_baseline_messages(edge_runs):
    b, e = edge_runs["baseline"], edge_runs["enhanced"]
    # baseline syncs every round for every client; enhanced batches rounds
    assert e.n_syncs < b.n_syncs * len(
        [1]) * 25 or e.n_syncs < b.n_messages


def test_accuracy_within_band(edge_runs):
    b, e = edge_runs["baseline"], edge_runs["enhanced"]
    # paper: accuracy maintained or improved (+-2pp band)
    assert e.final_test_error <= b.final_test_error + 0.02


def test_time_to_common_target_reduced(edge_runs):
    b, e = edge_runs["baseline"], edge_runs["enhanced"]
    target = common_target([b.val_error_curve, e.val_error_curve])
    tb = time_to_error(b.val_error_curve, target)
    te = time_to_error(e.val_error_curve, target)
    assert tb is not None and te is not None
    assert te[0] < tb[0]


def test_deterministic_given_seed():
    dom = DOMAINS["iot"]
    data = make_domain_data(dom, seed=1)
    cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=8)
    a = FederatedBoostEngine(cfg, data, "enhanced").run()
    b = FederatedBoostEngine(cfg, data, "enhanced").run()
    assert a.total_bytes == b.total_bytes
    assert a.final_val_error == b.final_val_error
    assert a.sim_time_s == b.sim_time_s


def test_compensation_handles_staleness():
    """With heavy dropout, compensated merging must not blow up accuracy."""
    dom = dataclasses.replace(DOMAINS["mobile"], n_clients=8)
    data = make_domain_data(dom, seed=2)
    cfg = FedBoostConfig(n_clients=8, n_rounds=15, dropout_prob=0.3,
                         straggler_factor=6.0)
    e = FederatedBoostEngine(cfg, data, "enhanced").run()
    assert e.final_val_error < 0.45


def test_dropped_round_that_fills_interval_still_triggers_sync():
    """The paper's dropout stalls the *message*, not the interval rule: a
    drop whose buffered learner fills I_t must sync after the time penalty,
    not defer the trigger by a whole extra round.  With every round forced
    to drop, clients must still sync every I_t rounds — the regression
    (buffering then `continue`-ing past the interval check) collapsed this
    to exactly one tail-flush sync per client."""
    dom = dataclasses.replace(DOMAINS["edge_vision"], n_samples=300,
                              n_clients=3)
    data = make_domain_data(dom, seed=0)
    cfg = FedBoostConfig(n_clients=3, n_rounds=6, dropout_prob=1.0, seed=0)
    eng = FederatedBoostEngine(cfg, data, "enhanced")
    m = eng.run()
    assert m.learners_merged == 3 * 6            # nothing lost either way
    assert m.n_syncs > 3                         # > one tail flush per client
    # dropping a round still costs the stall penalty: every round pays
    # twice the per-round compute time
    assert all(c.clock >= 2 * 6 * FederatedBoostEngine.BASE_ROUND_S
               for c in eng.clients)


def test_relevance_filter_saves_bytes():
    """Beyond-paper knob: filtering low-weight buffered learners cuts bytes
    without collapsing accuracy."""
    dom = DOMAINS["mobile"]
    data = make_domain_data(dom, seed=0)
    base = FedBoostConfig(n_clients=dom.n_clients, n_rounds=15,
                          straggler_factor=dom.straggler_factor,
                          dropout_prob=dom.dropout_prob,
                          link_mbps=dom.link_mbps)
    filt = dataclasses.replace(base, relevance_filter=0.75)
    m0 = FederatedBoostEngine(base, data, "enhanced").run()
    m1 = FederatedBoostEngine(filt, data, "enhanced").run()
    assert m1.total_bytes < m0.total_bytes
    assert m1.final_test_error < m0.final_test_error + 0.08
