"""End-to-end behaviour tests for the paper's system.

The headline: across the five application domains, the enhanced
asynchronous AdaBoost (adaptive scheduling + delayed weight compensation)
must reduce communication and reach the common target error sooner than
synchronous distributed AdaBoost, at equal-or-better accuracy — the paper's
Table 1 bands, validated end-to-end on two domains here (all five in
benchmarks/domains.py).
"""
import pytest

from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.core.federated import run_fedavg, run_fedasync
from repro.core.metrics import common_target, pct_reduction, time_to_error
from repro.data import make_domain_data


def _run_domain(name, n_rounds=25, seed=0):
    dom = DOMAINS[name]
    data = make_domain_data(dom, seed=seed)
    cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=n_rounds,
                         straggler_factor=dom.straggler_factor,
                         dropout_prob=dom.dropout_prob,
                         link_mbps=dom.link_mbps, seed=seed,
                         balanced_init=dom.label_imbalance < 0.4)
    return {m: FederatedBoostEngine(cfg, data, m).run()
            for m in ("baseline", "enhanced")}


@pytest.fixture(scope="module")
def healthcare():
    return _run_domain("healthcare")


@pytest.fixture(scope="module")
def iot():
    return _run_domain("iot")


def test_healthcare_comm_reduction_in_paper_band(healthcare):
    b, e = healthcare["baseline"], healthcare["enhanced"]
    red = pct_reduction(b.total_bytes, e.total_bytes)
    assert red >= 15.0, f"comm reduction {red:.0f}% below paper band"


def test_healthcare_accuracy_maintained(healthcare):
    b, e = healthcare["baseline"], healthcare["enhanced"]
    assert e.final_test_error <= b.final_test_error + 0.02


def test_iot_high_recall_maintained(iot):
    """Paper: IoT anomaly detection keeps high recall under intermittent
    participation."""
    e = iot["enhanced"]
    assert e.final_test_recall > 0.6


def test_iot_messages_reduced(iot):
    b, e = iot["baseline"], iot["enhanced"]
    assert e.n_messages < b.n_messages


def test_enhanced_reaches_target_sooner(healthcare, iot):
    for runs in (healthcare, iot):
        b, e = runs["baseline"], runs["enhanced"]
        tgt = common_target([b.val_error_curve, e.val_error_curve])
        tb, te = (time_to_error(b.val_error_curve, tgt),
                  time_to_error(e.val_error_curve, tgt))
        assert te is not None and tb is not None
        assert te[0] <= tb[0]


def test_boosting_beats_fedavg_on_bytes_at_accuracy():
    """The paper's framing: weak-learner traffic is orders of magnitude
    cheaper than weight traffic at comparable accuracy."""
    dom = DOMAINS["blockchain"]
    data = make_domain_data(dom, seed=0)
    cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=20,
                         link_mbps=dom.link_mbps)
    boost = FederatedBoostEngine(cfg, data, "enhanced").run()
    avg = run_fedavg(data, n_rounds=20, link_mbps=dom.link_mbps)
    assert boost.total_bytes < avg.total_bytes / 5
    assert boost.final_test_error < avg.final_test_error + 0.10


def test_fedasync_baseline_runs():
    dom = DOMAINS["mobile"]
    data = make_domain_data(dom, seed=0)
    m = run_fedasync(data, n_rounds=5)
    assert 0.0 <= m.final_test_error <= 1.0
    assert m.total_bytes > 0
