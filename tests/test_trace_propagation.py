"""Distributed trace propagation: context hand-off across hosts/nodes,
cross-host stitching, and the reporter's trace-id/link validation."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.chain import Chain, ChainRegistry
from repro.launch.obs_report import (check_trace, resolve_trace_key,
                                     stitch_trace)
from repro.obs import TraceContext
from repro.serve import (BatchConfig, GossipConfig, ShardCluster,
                         ShardedEnsembleServer)

TOL = 1e-6


# -------------------------------------------------------------- trace ids
def test_roots_get_fresh_traces_children_inherit():
    with obs.tracing() as tracer:
        with obs.span("a", host="h0"):
            obs.point("a.child")
        with obs.span("b"):
            pass
    spans = {s["name"]: s for s in tracer.finished()}
    assert spans["a"]["trace"] != spans["b"]["trace"]
    assert spans["a.child"]["trace"] == spans["a"]["trace"]
    # host inherits from the enclosing span unless overridden
    assert spans["a.child"]["host"] == "h0"
    assert "links" not in spans["a"]            # no edges -> key omitted


def test_ctx_continues_trace_and_records_link():
    with obs.tracing() as tracer:
        origin = obs.point("origin", host="h0")
        ctx = origin.ctx
        assert ctx == TraceContext(origin.trace_id, origin.span_id, "h0")
        # continuation under an unrelated open span, as on a remote host
        with obs.span("unrelated"):
            cont = obs.point("continuation", ctx=ctx, host="h1")
        assert cont.trace_id == origin.trace_id
    spans = {s["name"]: s for s in tracer.finished()}
    c = spans["continuation"]
    assert c["trace"] == spans["origin"]["trace"]
    assert c["parent"] == spans["unrelated"]["span"]    # stack nesting kept
    assert c["links"] == [[origin.trace_id, origin.span_id]]
    assert c["host"] == "h1"
    assert check_trace(tracer.finished()) == []


def test_late_annotation_after_point_still_exports():
    with obs.tracing() as tracer:
        p = obs.point("serve.submit", tenant="t")
        p.set(rid=42, accepted=True)            # the ring holds the object
    (d,) = tracer.finished()
    assert d["attrs"]["rid"] == 42


def test_null_span_has_no_ctx():
    assert obs.span("x").ctx is None            # tracing off -> NULL_SPAN


# --------------------------------------------------------- check_trace rules
def _span(name, span, trace, parent=None, links=(), t0=0.0, t1=1.0):
    d = {"name": name, "span": span, "parent": parent, "trace": trace,
         "host": "", "t0": t0, "t1": t1, "sim_t0": None, "sim_t1": None,
         "attrs": {}}
    if links:
        d["links"] = [list(l) for l in links]
    return d


def test_check_flags_cross_trace_child_without_link():
    spans = [_span("batch", 1, "tA"),
             _span("req", 2, "tB", parent=1, t0=0.1, t1=0.9)]
    errs = check_trace(spans)
    assert any("no link into its own trace" in e for e in errs)
    # the same shape with the link back into tB is clean
    spans[1]["links"] = [["tB", 99]]
    errs = check_trace(spans, meta={"dropped": 1})   # span 99 was dropped
    assert errs == []
    # ...but with a complete ring, the dangling link target is a violation
    assert any("links to missing span" in e
               for e in check_trace(spans, meta={"dropped": 0}))


def test_check_flags_link_trace_mismatch():
    spans = [_span("origin", 1, "tA"),
             _span("cont", 2, "tB", links=[("tB", 1)], t0=2.0, t1=3.0)]
    errs = check_trace(spans)
    assert any("link claims span 1 is in trace tB" in e for e in errs)


def test_check_tolerates_missing_parent_only_with_drops():
    orphan = [_span("child", 2, "tA", parent=1)]
    assert any("missing parent" in e for e in check_trace(orphan))
    assert any("missing parent" in e
               for e in check_trace(orphan, meta={"dropped": 0}))
    assert check_trace(orphan, meta={"dropped": 5}) == []


# ------------------------------------------------- sharded fleet propagation
def _publish(cluster, tenant, T=6, F=8, seed=0):
    rng = np.random.RandomState(seed)
    p = np.zeros((T, 4), np.float32)
    p[:, 0] = rng.randint(0, F, size=T)
    p[:, 1] = rng.randn(T)
    p[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    a = (rng.rand(T) + 0.1).astype(np.float32)
    cluster.publish_packed(tenant, jnp.asarray(p), jnp.asarray(a))


def _traced_fleet_run(n_requests=40, seed=0):
    tenants = [f"tenant-{i}" for i in range(4)]
    cluster = ShardCluster(3, GossipConfig(seed=seed))
    for i, t in enumerate(tenants):
        _publish(cluster, t, seed=i)
    cluster.run_until_quiescent()
    server = ShardedEnsembleServer(
        cluster, BatchConfig(queue_budget=64, max_batch=8),
        service_model=lambda n: 1e-3 + 1e-4 * n)
    rng = np.random.RandomState(seed)
    t = 0.0
    for _ in range(n_requests):
        t += rng.exponential(1.0 / 300.0)
        server.submit(tenants[rng.randint(len(tenants))],
                      rng.randn(8).astype(np.float32), t)
    server.drain()
    return server


def test_sharded_submit_propagates_trace_to_completion():
    with obs.tracing() as tracer:
        _traced_fleet_run()
        spans = tracer.finished()
    assert check_trace(spans, {"dropped": 0}) == []
    submits = {s["attrs"]["rid"]: s for s in spans
               if s["name"] == "serve.submit" and "rid" in s["attrs"]}
    requests = [s for s in spans if s["name"] == "serve.request"]
    assert submits and requests
    for req in requests:
        sub = submits[req["attrs"]["rid"]]
        # the completion continues the submit's trace across the host hop
        # and links back to the submit point
        assert req["trace"] == sub["trace"]
        assert [sub["trace"], sub["span"]] in req["links"]
        assert req["host"].startswith("host-")
    # the batch that served it belongs to the *host's* span tree, so the
    # request's stack parent is a serve.batch in another trace — exactly
    # the case the link rule covers
    batches = {s["span"]: s for s in spans if s["name"] == "serve.batch"}
    assert any(req["parent"] in batches and
               batches[req["parent"]]["trace"] != req["trace"]
               for req in requests)


def test_stitched_trace_reconstructs_e2e_latency():
    """The acceptance criterion: for a sampled request, the stitched
    cross-host trace reproduces end-to-end latency from its child spans
    (queue + batch + kernel) within 1e-6."""
    with obs.tracing() as tracer:
        _traced_fleet_run()
        spans = tracer.finished()
    tid = resolve_trace_key(spans, "auto")      # the slowest request
    st = stitch_trace(spans, tid)
    assert st["hosts"]                          # crossed at least one host
    names = {s["name"] for s in st["members"]}
    assert {"serve.submit", "serve.request"} <= names
    req = next(s for s in st["members"] if s["name"] == "serve.request")
    assert st["e2e_s"] == pytest.approx(req["attrs"]["latency_s"], abs=TOL)
    assert st["parts_s"] == pytest.approx(st["e2e_s"], abs=TOL)
    # rid-keyed lookup resolves to the same trace
    assert resolve_trace_key(spans, f"rid:{req['attrs']['rid']}") == tid


def test_rejected_submit_is_traced():
    tenants = ["t0"]
    cluster = ShardCluster(1, GossipConfig(seed=0))
    _publish(cluster, "t0")
    server = ShardedEnsembleServer(cluster, BatchConfig())
    with obs.tracing() as tracer:
        server.cluster.mark_down("host-0")
        ok, _ = server.submit("t0", np.zeros(8, np.float32), 0.0)
        assert not ok
    subs = [s for s in tracer.finished() if s["name"] == "serve.submit"]
    assert subs and subs[0]["attrs"]["accepted"] is False


# -------------------------------------------------------- gossip + chain
def test_gossip_exchange_points_share_round_trace():
    cluster = ShardCluster(3, GossipConfig(seed=0, fanout=2))
    _publish(cluster, "tenant-x")
    with obs.tracing() as tracer:
        cluster.gossip_round(0.0)
        spans = tracer.finished()
    rounds = [s for s in spans if s["name"] == "gossip.round"]
    exchanges = [s for s in spans if s["name"] == "gossip.exchange"]
    assert rounds and exchanges
    for ex in exchanges:
        assert ex["trace"] == rounds[0]["trace"]
        assert ex["parent"] == rounds[0]["span"]
        assert ex["host"] and ex["attrs"]["peer"]
    assert check_trace(spans, {"dropped": 0}) == []


def _packed(n, seed=0):
    rng = np.random.RandomState(seed)
    rows = np.zeros((n, 4), np.float32)
    rows[:, 0] = rng.randint(0, 6, size=n)
    rows[:, 1] = rng.randn(n)
    rows[:, 2] = 1.0
    return rows, (rng.rand(n) + 0.1).astype(np.float32)


def test_chain_commit_trace_links_through_mint_and_fold():
    chain = Chain(seed=3)
    pub = ChainRegistry(chain, node_id="pub")
    other = ChainRegistry(chain, node_id="other")
    with obs.tracing() as tracer:
        rows, alphas = _packed(3)
        pub.publish_packed("t", rows, alphas, clock=0.0)
        chain.finalize()
        other.latest("t")                      # folds the confirmed blocks
        spans = tracer.finished()
    commits = [s for s in spans if s["name"] == "chain.commit"]
    mints = [s for s in spans if s["name"] == "chain.mint"]
    aggs = [s for s in spans if s["name"] == "chain.aggregate"]
    assert commits and mints and aggs
    commit_edges = {(c["trace"], c["span"]) for c in commits}
    assert all(c["host"] == "pub" for c in commits)
    # the mint (possibly on another miner) links back into the commit trace
    mint_links = {tuple(l) for m in mints for l in m.get("links", [])}
    assert commit_edges <= mint_links
    # the folding node's aggregate span links to the commits it replayed
    agg = next(a for a in aggs if a["host"] == "other"
               and a.get("links"))
    assert commit_edges <= {tuple(l) for l in agg["links"]}
    assert check_trace(spans, {"dropped": 0}) == []
    # stitching the commit's trace pulls the cross-node mint/fold in
    st = stitch_trace(spans, commits[0]["trace"])
    st_names = {s["name"] for s in st["members"]}
    assert {"chain.commit", "chain.mint"} <= st_names


def test_chain_fingerprints_unaffected_by_tracing():
    rows, alphas = _packed(4, seed=1)
    def _run(traced):
        chain = Chain(seed=9)
        reg = ChainRegistry(chain, node_id="n0")
        if traced:
            with obs.tracing():
                reg.publish_packed("t", rows, alphas, clock=0.0)
                chain.finalize()
        else:
            reg.publish_packed("t", rows, alphas, clock=0.0)
            chain.finalize()
        return [b.hash for b in chain.blocks], reg.latest("t").fingerprint
    assert _run(traced=True) == _run(traced=False)
