"""Per-client contribution audits (repro.obs.audit) + engine integration."""
import numpy as np
import pytest

import repro.obs as obs
from repro.configs.paper_fedboost import DomainConfig, FedBoostConfig
from repro.core import FederatedBoostEngine
from repro.data import make_domain_data
from repro.obs.audit import AuditFlag, ContributionAudit, robust_z
from repro.obs.registry import MetricsRegistry


# ----------------------------------------------------------------- robust z
def test_robust_z_flags_the_lone_outlier():
    values = {i: 1.0 + 0.01 * (i % 3) for i in range(9)}
    values[9] = 50.0
    zs = robust_z(values)
    assert abs(zs[9]) > 3.5
    assert all(abs(z) <= 3.5 for cid, z in zs.items() if cid != 9)


def test_robust_z_degenerate_cases():
    # fewer than 3 clients: no basis for an outlier call
    assert robust_z({0: 1.0, 1: 99.0}) == {0: 0.0, 1: 0.0}
    # all identical: MAD and mean-dev both zero -> all scores 0
    assert set(robust_z({i: 2.0 for i in range(5)}).values()) == {0.0}
    # MAD == 0 but spread exists: mean-abs-dev fallback still scores
    vals = {i: 1.0 for i in range(6)}
    vals[6] = 100.0
    assert abs(robust_z(vals)[6]) > 3.5


# -------------------------------------------------------------------- audit
def test_audit_records_stats_and_instruments():
    reg = MetricsRegistry()
    audit = ContributionAudit(registry=reg, window=4)
    for i in range(6):
        audit.record(0, magnitude=0.5, error_delta=0.01, staleness=float(i))
    audit.record(1, magnitude=0.2, error_delta=-0.02, staleness=1.0,
                 outcome="rejected")
    st = audit.clients[0]
    assert st.merges == 6 and len(st.staleness) == 4     # window bounds
    assert st.mean("staleness") == pytest.approx((2 + 3 + 4 + 5) / 4)
    assert audit.clients[1].outcomes == {"rejected": 1}
    assert audit.recorded == 7
    snap = reg.snapshot()
    assert snap["counters"]["audit.outcomes{cid=0,outcome=merged}"] == 6.0
    assert snap["counters"]["audit.outcomes{cid=1,outcome=rejected}"] == 1.0
    assert "audit.update_magnitude{cid=0}" in snap["histograms"]
    assert "audit.staleness{cid=1}" in snap["histograms"]


def test_audit_flags_poisoning_client():
    audit = ContributionAudit(registry=MetricsRegistry())
    rng = np.random.RandomState(0)
    for cid in range(8):
        for _ in range(20):
            audit.record(cid, magnitude=0.5 + 0.01 * rng.randn(),
                         error_delta=0.01, staleness=1.0)
    for _ in range(20):    # cid 8 injects huge updates that hurt validation
        audit.record(8, magnitude=25.0, error_delta=-0.05, staleness=1.0)
    flagged = {(f.cid, f.metric) for f in audit.flags()}
    assert (8, "magnitude") in flagged
    assert (8, "error_delta") in flagged
    assert all(cid == 8 for cid, _ in flagged)
    only_mag = audit.flags("magnitude")
    assert {f.metric for f in only_mag} == {"magnitude"}
    summ = audit.summary()
    assert summ["recorded"] == 9 * 20
    assert any(f["cid"] == 8 for f in summ["flags"])


def test_audit_default_registry_follows_obs_scope():
    audit = ContributionAudit()
    with obs.tracing():
        audit.record(0, magnitude=1.0, error_delta=0.0, staleness=0.0)
        snap = obs.get_registry().snapshot()
        assert "audit.update_magnitude{cid=0}" in snap["histograms"]
    # the scope's fresh registry absorbed the write; the outer one is clean
    outer = obs.get_registry().snapshot()
    assert "audit.update_magnitude{cid=0}" not in outer["histograms"]


# -------------------------------------------------------- engine integration
def _engine(mode="enhanced", engine="events", fleet=None, seed=0):
    dom = DomainConfig(name="mobile", n_samples=900, n_features=10,
                       n_clients=6, noniid_alpha=0.5, label_imbalance=0.5,
                       noise=0.15, straggler_factor=3.0, dropout_prob=0.1,
                       link_mbps=5.0)
    data = make_domain_data(dom, seed=seed, partitioner="iid")
    cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=4,
                         straggler_factor=dom.straggler_factor,
                         dropout_prob=dom.dropout_prob, seed=seed)
    return FederatedBoostEngine(cfg, data, mode, engine=engine, fleet=fleet)


@pytest.mark.parametrize("engine", ["loop", "events"])
def test_attached_audit_observes_every_merge(engine):
    eng = _engine(engine=engine)
    audit = eng.attach_audit()
    metrics = eng.run()
    assert audit.recorded == metrics.learners_merged
    assert sum(st.outcomes.get("merged", 0)
               for st in audit.clients.values()) == metrics.learners_merged
    assert all(0 <= cid < 6 for cid in audit.clients)
    # staleness is measured in sync rounds: non-negative, finite
    for st in audit.clients.values():
        assert all(s >= 0 for s in st.staleness)
        assert all(np.isfinite(m) for m in st.magnitude)


@pytest.mark.parametrize("mode", ["baseline", "enhanced"])
def test_audit_is_pure_measurement(mode):
    plain = _engine(mode=mode).run()
    audited_eng = _engine(mode=mode)
    audited_eng.attach_audit()
    audited = audited_eng.run()
    assert plain.final_val_error == audited.final_val_error
    assert plain.learners_merged == audited.learners_merged
    assert plain.val_error_curve == audited.val_error_curve
    assert plain.sim_time_s == audited.sim_time_s


def test_fleet_profile_refuses_audit():
    eng = _engine(engine="events", fleet=True)
    with pytest.raises(ValueError, match="fleet"):
        eng.attach_audit()


def test_attach_audit_accepts_external_instance():
    audit = ContributionAudit(registry=MetricsRegistry(), window=8)
    eng = _engine()
    assert eng.attach_audit(audit) is audit
    eng.run()
    assert audit.recorded > 0


def test_audit_flag_to_dict_roundtrip():
    f = AuditFlag(cid=3, metric="magnitude", z=4.2, value=9.0, median=1.0)
    assert f.to_dict() == {"cid": 3, "metric": "magnitude", "z": 4.2,
                           "value": 9.0, "median": 1.0}
