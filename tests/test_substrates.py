"""Data / optimizer / checkpoint substrate tests."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, prune, restore, save
from repro.sim.scenarios import DOMAINS
from repro.data import make_domain_data, dirichlet_partition, iid_partition
from repro.data.tokens import MarkovTokens
from repro.optim import (adamw, clip_by_global_norm, cosine_schedule,
                         global_norm, sgd)


# ---------------------------------------------------------------------- data

@pytest.mark.parametrize("name", sorted(DOMAINS))
def test_domain_datasets_well_formed(name):
    dom = DOMAINS[name]
    data = make_domain_data(dom, seed=0)
    assert len(data["clients"]) == dom.n_clients
    for x, y in data["clients"]:
        assert x.shape[0] == y.shape[0] >= 8
        assert x.shape[1] == dom.n_features
        assert set(np.unique(np.asarray(y))) <= {-1.0, 1.0}
    xv, yv = data["val"]
    assert xv.shape[0] > 50


def test_domain_data_deterministic():
    a = make_domain_data(DOMAINS["iot"], seed=3)
    b = make_domain_data(DOMAINS["iot"], seed=3)
    np.testing.assert_array_equal(np.asarray(a["val"][0]),
                                  np.asarray(b["val"][0]))


def test_dirichlet_partition_covers_all_points():
    rng = np.random.RandomState(0)
    x = rng.randn(500, 4).astype(np.float32)
    y = np.where(rng.rand(500) > 0.5, 1.0, -1.0).astype(np.float32)
    parts = dirichlet_partition(x, y, 7, 0.3, rng)
    assert len(parts) == 7
    assert all(len(px) >= 8 for px, _ in parts)


def test_dirichlet_skew_increases_with_lower_alpha():
    rng = np.random.RandomState(0)
    x = rng.randn(2000, 4).astype(np.float32)
    y = np.where(rng.rand(2000) > 0.5, 1.0, -1.0).astype(np.float32)

    def skew(alpha):
        parts = dirichlet_partition(x, y, 8, alpha, np.random.RandomState(1))
        fracs = [float(np.mean(py > 0)) for _, py in parts]
        return np.std(fracs)

    assert skew(0.1) > skew(100.0)


def test_markov_tokens_learnable_structure():
    mt = MarkovTokens(vocab=64, seed=0, branching=2)
    s = mt.stream(2000)
    # successors of every token restricted to its branching set
    for t in range(0, 60):
        idx = np.where(s[:-1] == t)[0]
        if len(idx) > 3:
            succ = set(s[idx + 1].tolist())
            assert len(succ) <= 2


# --------------------------------------------------------------------- optim

def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))
    return target, loss


@pytest.mark.parametrize("make", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9), lambda: adamw(0.1)])
def test_optimizers_converge_on_quadratic(make):
    target, loss = _quad_problem()
    opt = make()
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for step in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, params, state, jnp.asarray(step))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((9,), -10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    # under the limit -> unchanged
    g2 = {"a": jnp.asarray([0.1, 0.1])}
    c2 = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), np.asarray(g2["a"]))


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup=10, total=100, final_frac=0.1)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(sched(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(sched(55)) < float(sched(12))


def test_adamw_weight_decay_shrinks_params():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.full((3,), 5.0)}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros(3)}
    p2, _ = opt.update(zero_g, params, state, jnp.asarray(0))
    assert float(jnp.max(p2["w"])) < 5.0


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)},
            "d": (jnp.ones((2,)), jnp.zeros((3,), jnp.bfloat16))}
    save(str(tmp_path), 7, tree, extra={"note": "x"})
    like = jax.tree.map(jnp.zeros_like, tree)
    got, step, extra = restore(str(tmp_path), like)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_latest_and_prune(tmp_path):
    tree = {"w": jnp.ones(2)}
    for s in (1, 5, 9, 12):
        save(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 12
    prune(str(tmp_path), keep=2)
    assert latest_step(str(tmp_path)) == 12
    assert len(os.listdir(tmp_path)) == 2


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"w": jnp.ones((3, 3))})
