"""Property-based suite for the snapshot registry and the sharded gossip
layer: version monotonicity, bounded history, atomic latest() under
concurrent publishers, and gossip convergence under arbitrary publish and
digest-exchange orders."""
import threading

import pytest

pytest.importorskip("hypothesis")  # property tests; CI installs requirements-dev.txt

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serve import EnsembleRegistry, GossipConfig, ShardCluster

TENANTS = ("alpha", "beta", "gamma")


def _packed(T, seed, F=6):
    rng = np.random.RandomState(seed)
    p = np.zeros((T, 4), np.float32)
    p[:, 0] = rng.randint(0, F, size=T)
    p[:, 1] = rng.randn(T)
    p[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    return jnp.asarray(p), jnp.asarray((rng.rand(T) + 0.1).astype(np.float32))


publish_events = st.lists(
    st.tuples(st.sampled_from(TENANTS),        # tenant
              st.integers(1, 5),               # ensemble size
              st.integers(0, 99)),             # content seed
    min_size=1, max_size=24)


# ------------------------------------------------------------ monotonicity
@given(events=publish_events, history=st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_versions_monotone_and_history_bounded(events, history):
    reg = EnsembleRegistry(history=history)
    last_version = {t: 0 for t in TENANTS}
    for tenant, T, seed in events:
        p, a = _packed(T, seed)
        snap = reg.publish_packed(tenant, p, a)
        assert snap.version == last_version[tenant] + 1   # +1 per publish
        last_version[tenant] = snap.version
    for tenant in TENANTS:
        hist = reg.history(tenant)
        assert len(hist) <= history                       # bounded window
        versions = [s.version for s in hist]
        assert versions == sorted(versions)               # ordered history
        if hist:
            assert reg.latest(tenant).version == last_version[tenant]
            assert reg.version_count(tenant) == last_version[tenant]


@given(events=publish_events)
@settings(max_examples=25, deadline=None)
def test_get_by_version_consistent_after_rebase(events):
    reg = EnsembleRegistry(history=8)
    clock = 0.0
    for i, (tenant, T, seed) in enumerate(events):
        p, a = _packed(T, seed)
        clock = float(i)
        reg.publish_packed(tenant, p, a, clock=clock)
    ages = {t: [clock - s.published_at for s in reg.history(t)]
            for t in TENANTS}
    reg.rebase_clock(1000.0)
    for t in TENANTS:
        hist = reg.history(t)
        if not hist:
            continue
        assert hist[-1].published_at == pytest.approx(1000.0)
        # relative ages survive the epoch change for every retained version
        new_ages = [1000.0 - reg.get(t, s.version).published_at
                    for s in hist]
        # offset between old/new age lists is constant (latest moved to 0)
        deltas = {round(o - n, 6) for o, n in zip(ages[t], new_ages)}
        assert len(deltas) == 1


# ------------------------------------------------------- concurrent latest
@given(n_threads=st.integers(2, 4), per_thread=st.integers(3, 10))
@settings(max_examples=10, deadline=None)
def test_latest_atomic_under_concurrent_publishers(n_threads, per_thread):
    reg = EnsembleRegistry(history=3)
    seen_bad = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            snap = reg.latest("t")
            if snap is None:
                continue
            # a torn snapshot would break one of these invariants
            if (snap.stump_params.shape != (snap.n_learners, 4)
                    or snap.version < 1):
                seen_bad.append(snap)

    def writer(wid):
        for i in range(per_thread):
            p, a = _packed(1 + (wid + i) % 4, seed=wid * 100 + i)
            reg.publish_packed("t", p, a)

    rt = threading.Thread(target=reader)
    rt.start()
    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rt.join()
    assert not seen_bad
    # every publish got a unique version; latest is the total count
    assert reg.latest("t").version == n_threads * per_thread


# ---------------------------------------------------- gossip convergence
@given(events=publish_events,
       exchange_seed=st.integers(0, 2**16),
       extra_exchanges=st.lists(
           st.tuples(st.integers(0, 2), st.integers(0, 2)), max_size=12))
@settings(max_examples=25, deadline=None)
def test_gossip_converges_any_publish_and_exchange_order(
        events, exchange_seed, extra_exchanges):
    cluster = ShardCluster(3, GossipConfig(seed=exchange_seed))
    hosts = list(cluster.hosts.values())
    for tenant, T, seed in events:
        p, a = _packed(T, seed)
        cluster.publish_packed(tenant, p, a, train_progress=seed)
    # arbitrary manual pairwise exchanges first (any digest-exchange order)
    for i, j in extra_exchanges:
        if i != j:
            cluster._anti_entropy(hosts[i], hosts[j], now=0.0)
    cluster.run_until_quiescent(now=0.0)
    assert cluster.converged()
    digests = [h.registry.digest() for h in hosts]
    assert digests[0] == digests[1] == digests[2]
    # version vector reflects every publish
    want = {}
    for tenant, *_ in events:
        want[tenant] = want.get(tenant, 0) + 1
    for tenant, count in want.items():
        assert digests[0][tenant][0] == count


@given(seed_a=st.integers(0, 50), seed_b=st.integers(51, 99),
       progress_a=st.integers(0, 30), progress_b=st.integers(0, 30),
       dt=st.floats(0.0, 4.0))
@settings(max_examples=25, deadline=None)
def test_concurrent_versions_reconcile_identically_everywhere(
        seed_a, seed_b, progress_a, progress_b, dt):
    """Two hosts race the same version number; after gossip all hosts hold
    the same winner, chosen by the staleness-weighted score."""
    cluster = ShardCluster(3, GossipConfig(seed=0, lam=0.5))
    hosts = list(cluster.hosts.values())
    pa, aa = _packed(3, seed_a)
    pb, ab = _packed(3, seed_b)
    hosts[0].registry.publish_packed("t", pa, aa, clock=0.0,
                                     train_progress=progress_a)
    hosts[1].registry.publish_packed("t", pb, ab, clock=dt,
                                     train_progress=progress_b)
    cluster.run_until_quiescent(now=5.0)
    assert cluster.converged()
    fps = {h.registry.latest("t").fingerprint for h in hosts}
    assert len(fps) == 1
