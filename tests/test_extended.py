"""Extended coverage: SAMME multiclass boosting invariants and the
sliding-window ring-cache prefill->decode continuity (gemma2's local
layers), plus generation-loop integration for three arch families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, reduced, ShapeConfig
from repro.configs.registry import ARCHS
from repro.core.boosting import samme_alpha, samme_update_distribution
from repro.models import Model, attention as attn_mod


# ------------------------------------------------------------------ SAMME

def test_samme_alpha_multiclass_chance_level():
    """SAMME's alpha is zero exactly at multiclass chance error
    1 - 1/K (Zhu et al. 2009)."""
    K = 5
    chance = 1.0 - 1.0 / K
    assert float(samme_alpha(chance, K)) == pytest.approx(0.0, abs=1e-4)
    assert float(samme_alpha(chance - 0.1, K)) > 0
    assert float(samme_alpha(chance + 0.1, K)) < 0


def test_samme_update_normalizes_and_upweights_misses():
    n = 64
    rng = np.random.RandomState(0)
    D = jnp.full((n,), 1.0 / n)
    y = jnp.asarray(rng.randint(0, 4, n))
    pred = jnp.asarray(rng.randint(0, 4, n))
    a = samme_alpha(0.4, 4)
    D2, Z = samme_update_distribution(D, a, y, pred)
    assert float(jnp.sum(D2)) == pytest.approx(1.0, abs=1e-5)
    miss = pred != y
    assert float(jnp.mean(D2[miss])) > float(jnp.mean(D2[~miss]))


# ------------------------------------------- sliding-window ring cache

def test_window_ring_cache_prefill_decode_continuity():
    """For a local (sliding-window) layer, decoding right after a prefill
    longer than the window must agree with full-sequence attention."""
    cfg = ArchConfig(name="w", family="dense", source="", n_layers=1,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                     vocab_size=64, head_dim=16, sliding_window=8)
    p = attn_mod.attn_init(jax.random.key(0), cfg)
    T = 21          # prompt longer than the window, not aligned to it
    x = jax.random.normal(jax.random.key(1), (2, T + 1, cfg.d_model))
    pos = jnp.arange(T + 1, dtype=jnp.int32)
    full = attn_mod.attn_apply(p, x, cfg, positions=pos, window=8)

    _, cache = attn_mod.attn_prefill(p, x[:, :T], cfg, positions=pos[:T],
                                     kind="attn_local", cache_seq=T)
    assert cache["k"].shape[1] == 8          # window-capped ring
    cache = {k: v.astype(jnp.float32) for k, v in cache.items()}
    out, cache2 = attn_mod.attn_decode(p, x[:, T:], cache, cfg,
                                       pos=jnp.asarray(T),
                                       kind="attn_local")
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=3e-2, atol=3e-2)
    assert cache2["k"].shape == cache["k"].shape


def test_ring_cache_multi_step_decode():
    """Ring cache stays correct across several decode steps (wrap-around)."""
    cfg = ArchConfig(name="w", family="dense", source="", n_layers=1,
                     d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                     vocab_size=64, head_dim=16, sliding_window=4)
    p = attn_mod.attn_init(jax.random.key(0), cfg)
    T = 12
    x = jax.random.normal(jax.random.key(1), (1, T, cfg.d_model))
    pos = jnp.arange(T, dtype=jnp.int32)
    full = attn_mod.attn_apply(p, x, cfg, positions=pos, window=4)

    cache = {k: v.astype(jnp.float32)
             for k, v in attn_mod.init_cache(cfg, "attn_local", 1, T,
                                             jnp.float32).items()}
    outs = []
    for t in range(T):
        o, cache = attn_mod.attn_decode(p, x[:, t:t + 1], cache, cfg,
                                        pos=jnp.asarray(t),
                                        kind="attn_local")
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=3e-2, atol=3e-2)


# -------------------------------------------------- generation integration

@pytest.mark.parametrize("arch", ["gemma2-27b", "mamba2-1.3b",
                                  "whisper-base"])
def test_generation_loop(arch):
    """Prefill + multi-token greedy decode through the serve path."""
    from repro.launch.serve import generate
    cfg = reduced(ARCHS[arch])
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, T0, NEW = 2, 8, 4
    prompts = jax.random.randint(jax.random.key(1), (B, T0), 0,
                                 cfg.vocab_size, jnp.int32)
    frames = (jnp.zeros((B, cfg.encoder_seq, cfg.d_model))
              if cfg.is_encoder_decoder else None)
    seqs = generate(model, params, prompts, NEW, cache_len=T0 + NEW,
                    frames=frames)
    assert seqs.shape == (B, T0 + NEW)
    assert int(jnp.max(seqs)) < cfg.vocab_size
