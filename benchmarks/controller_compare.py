"""BEYOND-PAPER: interval-controller shoot-out — the paper's bang-bang rule
(eq. 1) vs an EMA-slope proportional controller and an improvement-budget
controller (`core/controllers.py`), on two contrasting domains."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_fedboost import FedBoostConfig, SchedulerConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.core.controllers import BudgetScheduler, TrendScheduler
from repro.core.metrics import time_to_error
from repro.core.scheduling import HostScheduler


def run(domain: str, make_sched) -> Dict:
    from repro.data import make_domain_data
    dom = DOMAINS[domain]
    data = make_domain_data(dom, seed=0)
    cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=25,
                         straggler_factor=dom.straggler_factor,
                         dropout_prob=dom.dropout_prob,
                         link_mbps=dom.link_mbps,
                         balanced_init=dom.label_imbalance < 0.4)
    eng = FederatedBoostEngine(cfg, data, "enhanced")
    eng.scheduler = make_sched(cfg.scheduler)
    m = eng.run()
    return m


def main() -> List[Dict]:
    controllers = {
        "paper eq.1 (bang-bang)": lambda c: HostScheduler(c),
        "trend (EMA slope)": lambda c: TrendScheduler(c),
        "budget (gain/sync)": lambda c: BudgetScheduler(c),
    }
    out = []
    for domain in ("edge_vision", "mobile"):
        print(f"\n--- controller comparison: {domain} ---")
        print(f"{'controller':<24} {'bytes':>9} {'msgs':>6} {'syncs':>6} "
              f"{'val_err':>8}")
        for name, mk in controllers.items():
            m = run(domain, mk)
            print(f"{name:<24} {m.total_bytes:>9} {m.n_messages:>6} "
                  f"{m.n_syncs:>6} {m.final_val_error:>8.3f}", flush=True)
            out.append({"domain": domain, "controller": name,
                        "bytes": m.total_bytes, "err": m.final_val_error})
    return out


if __name__ == "__main__":
    main()
