"""Scenario matrix: every registered paper domain x every behavior trace,
end to end — train both engine modes through the behavior models, check
the Table-1 paper bands, then replay the publish/request trace into the
autoscaled serving fleet.

Acceptance (asserted): for every base domain the enhanced algorithm lands
within its paper band (band floor minus reproduction tolerance on
time/comm/accuracy — see ``PaperBand.check``) on the ``legacy`` trace AND
on at least two non-trivial behavior traces; every serve replay preserves
the fleet's zero-loss invariant (checked inside the harness).

    PYTHONPATH=src python -m benchmarks.scenario_matrix            # full
    PYTHONPATH=src python -m benchmarks.scenario_matrix --quick    # 2 domains
    PYTHONPATH=src python -m benchmarks.scenario_matrix --variants # + stress
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.sim.harness import result_row, run_scenario
from repro.sim.scenarios import (base_scenarios, get_scenario,
                                 variant_scenarios)

QUICK_DOMAINS = ("edge_vision", "healthcare")


def run_cell(name: str, trace: str, seeds: Sequence[int], n_rounds: int,
             serve: bool = True) -> Dict:
    """One (scenario, trace) cell: mean Table-1 row over seeds + band
    check on the mean + the last seed's serve replay."""
    sc = get_scenario(name)
    rows, serve_rep = [], None
    for seed in seeds:
        rep = run_scenario(sc, trace=trace, seed=seed, n_rounds=n_rounds,
                           serve=serve, serve_duration_s=1.0)
        rows.append(rep.row)
        serve_rep = rep.serve
    mean = {k: float(np.mean([r[k] for r in rows])) for k in rows[0]}
    out = {"scenario": name, "trace": trace, **mean,
           "band_failures": sc.band.check(mean), "serve": serve_rep}
    out["within_band"] = not out["band_failures"]
    return out


def main(quick: bool = False, seeds: Optional[Sequence[int]] = None,
         n_rounds: Optional[int] = None, include_variants: bool = False,
         serve: bool = True, chain: bool = True) -> List[Dict]:
    names = list(QUICK_DOMAINS) if quick else base_scenarios()
    if include_variants:
        names += variant_scenarios()
    rounds = n_rounds if n_rounds is not None else (12 if quick else 16)
    if seeds is None:
        # single-seed accuracy deltas are +-4pp noisy at these sizes; the
        # full matrix checks bands on a 2-seed mean (quick stays 1-seed
        # at 12 rounds, where every registered cell is calibrated green)
        seeds = (0,) if quick else (0, 1)

    print("=" * 100)
    print(f"scenario matrix — {len(names)} scenario(s) x behavior traces, "
          f"{len(seeds)} seed(s), {rounds} rounds, "
          f"train -> serve replay{' (quick)' if quick else ''}")
    print("=" * 100)
    print(f"{'scenario':<17} {'trace':<15} {'time↓%':>7} {'comm↓%':>7} "
          f"{'accΔpp':>7} {'band':<5} | {'served':>6} {'p99ms':>6} "
          f"{'hosts':>5} {'cache':>6}")
    print("-" * 100)

    rows: List[Dict] = []
    passing: Dict[str, int] = {}
    for name in names:
        sc = get_scenario(name)
        for trace in ["legacy"] + sc.nontrivial_traces:
            cell = run_cell(name, trace, seeds, rounds, serve=serve)
            rows.append(cell)
            s = cell["serve"] or {}
            print(f"{name:<17} {trace:<15} {cell['time_down']:>7.1f} "
                  f"{cell['comm_down']:>7.1f} {cell['acc_delta_pp']:>+7.1f} "
                  f"{'ok' if cell['within_band'] else 'FAIL':<5} | "
                  f"{s.get('completed', 0):>6} {s.get('p99_ms', 0.0):>6.2f} "
                  f"{s.get('hosts_final', 0):>5} "
                  f"{s.get('cache_hit_rate', 0.0):>6.0%}", flush=True)
            if not cell["within_band"]:
                print(f"{'':<33} out of band: "
                      f"{'; '.join(cell['band_failures'])}")
            if trace != "legacy" and cell["within_band"]:
                passing[name] = passing.get(name, 0) + 1
    if chain and serve:
        # the decentralized chain-of-record leg: same environment and band
        # as the blockchain base domain, but publishes commit to a shared
        # chain (no central registry) and the harness kills the committee
        # leader mid-replay — the band AND the zero-loss serve invariant
        # (asserted inside replay_serve) must hold anyway.  This variant
        # is asserted even though variant bands normally aren't: it
        # shares the calibrated blockchain band.
        cell = run_cell("blockchain_flchain", "block_delay", seeds, rounds)
        rows.append(cell)
        s = cell["serve"] or {}
        print(f"{'blockchain_flchain':<17} {'block_delay':<15} "
              f"{cell['time_down']:>7.1f} {cell['comm_down']:>7.1f} "
              f"{cell['acc_delta_pp']:>+7.1f} "
              f"{'ok' if cell['within_band'] else 'FAIL':<5} | "
              f"{s.get('completed', 0):>6} {s.get('p99_ms', 0.0):>6.2f} "
              f"{s.get('hosts_final', 0):>5} "
              f"{s.get('cache_hit_rate', 0.0):>6.0%}  "
              f"[killed {s.get('killed_host')}]", flush=True)
        assert cell["within_band"], (
            "blockchain_flchain out of band: "
            + "; ".join(cell["band_failures"]))
        assert s.get("killed_host"), (
            "chain leg did not exercise the mid-replay leader kill")
    print("-" * 100)

    failures = []
    for name in names:
        sc = get_scenario(name)
        need = min(2, len(sc.nontrivial_traces))
        got = passing.get(name, 0)
        legacy_ok = next(r["within_band"] for r in rows
                         if r["scenario"] == name and r["trace"] == "legacy")
        print(f"{name:<17} {got}/{len(sc.nontrivial_traces)} non-trivial "
              f"trace(s) within band (need >= {need}); "
              f"legacy {'ok' if legacy_ok else 'FAIL'}")
        if sc.variant_of is None:        # bands are calibrated for bases
            if got < need:
                failures.append(f"{name}: only {got}/{need} non-trivial "
                                "traces within band")
            if not legacy_ok:
                failures.append(f"{name}: legacy trace out of band")
    assert not failures, "; ".join(failures)
    return rows


def csv_rows(rows: List[Dict]) -> List:
    """Harness-convention (name, us, derived) rows for benchmarks.run."""
    out = []
    for r in rows:
        s = r["serve"] or {}
        out.append((
            f"scenario_{r['scenario']}_{r['trace']}", 0.0,
            f"time_down={r['time_down']:.1f}%;comm_down={r['comm_down']:.1f}%;"
            f"acc_delta={r['acc_delta_pp']:+.1f}pp;"
            f"band={'ok' if r['within_band'] else 'fail'};"
            f"serve_p99={s.get('p99_ms', 0.0):.2f}ms;"
            f"hosts={s.get('hosts_final', 0)}"))
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 domains x 1 seed (the CI smoke)")
    ap.add_argument("--seeds", type=int, nargs="+", default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--variants", action="store_true",
                    help="include the stress variants (reported, not "
                         "asserted — bands are calibrated for the bases)")
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the serving replay (train-only matrix)")
    ap.add_argument("--no-chain", action="store_true",
                    help="skip the blockchain_flchain decentralized leg")
    args = ap.parse_args()
    main(quick=args.quick,
         seeds=None if args.seeds is None else tuple(args.seeds),
         n_rounds=args.rounds, include_variants=args.variants,
         serve=not args.no_serve, chain=not args.no_chain)
