"""Delayed-weight-compensation sensitivity (paper eq. 2): sweep the decay
constant lambda under heavy dropout/staleness.

lambda = 0 disables compensation (stale learners at full weight, the
baseline's failure mode); very large lambda discards stale work entirely.
The paper's claim is a sweet spot in between.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.paper_fedboost import CompensationConfig, FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.data import make_domain_data


def main() -> List[Dict]:
    dom = dataclasses.replace(DOMAINS["mobile"], n_clients=16)
    data = make_domain_data(dom, seed=0)
    print("=" * 70)
    print("Staleness compensation sweep (mobile, dropout=0.25, stragglers x6)")
    print("=" * 70)
    print(f"{'lambda':>8} {'val_err':>9} {'test_err':>9} {'syncs':>7}")
    out = []
    for lam in (0.0, 0.05, 0.15, 0.3, 0.6, 1.2, 3.0):
        cfg = FedBoostConfig(
            n_clients=16, n_rounds=25, dropout_prob=0.25,
            straggler_factor=6.0, link_mbps=dom.link_mbps,
            compensation=CompensationConfig(lam=lam), seed=0)
        m = FederatedBoostEngine(cfg, data, "enhanced").run()
        print(f"{lam:>8.2f} {m.final_val_error:>9.3f} "
              f"{m.final_test_error:>9.3f} {m.n_syncs:>7}", flush=True)
        out.append({"lambda": lam, "val_err": m.final_val_error,
                    "test_err": m.final_test_error})
    return out


if __name__ == "__main__":
    main()
