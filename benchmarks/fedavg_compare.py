"""Boosting vs gradient-averaging FL (FedAvg / FedAsync) — the paper's
framing that scheduled weak-learner traffic is orders of magnitude cheaper
than weight traffic at comparable accuracy (Figure-1-style comparison).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.core.federated import run_fedavg, run_fedasync
from repro.data import make_domain_data


def main() -> List[Dict]:
    print("=" * 78)
    print("Enhanced async AdaBoost vs FedAvg / FedAsync (bytes at accuracy)")
    print("=" * 78)
    print(f"{'domain':<13} {'method':<12} {'bytes':>12} {'msgs':>7} "
          f"{'test_err':>9} {'sim_time':>9}")
    out = []
    for name in ("edge_vision", "blockchain", "healthcare"):
        dom = DOMAINS[name]
        data = make_domain_data(dom, seed=0)
        cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=25,
                             straggler_factor=dom.straggler_factor,
                             dropout_prob=dom.dropout_prob,
                             link_mbps=dom.link_mbps)
        rows = {
            "fedboost+": FederatedBoostEngine(cfg, data, "enhanced").run(),
            "fedavg": run_fedavg(data, n_rounds=25,
                                 straggler_factor=dom.straggler_factor,
                                 link_mbps=dom.link_mbps),
            "fedasync": run_fedasync(data, n_rounds=25,
                                     straggler_factor=dom.straggler_factor,
                                     link_mbps=dom.link_mbps),
        }
        for meth, m in rows.items():
            print(f"{name:<13} {meth:<12} {m.total_bytes:>12} "
                  f"{m.n_messages:>7} {m.final_test_error:>9.3f} "
                  f"{m.sim_time_s:>9.1f}", flush=True)
            out.append({"domain": name, "method": meth,
                        "bytes": m.total_bytes,
                        "err": m.final_test_error})
    return out


if __name__ == "__main__":
    main()
