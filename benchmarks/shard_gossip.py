"""Sharded-registry benchmark: gossip convergence lag + result-cache A/B.

Phase 1 — convergence: publish bursts of snapshot versions for every tenant
into a rendezvous-sharded cluster (each publish lands only on the tenant's
owning host), then run anti-entropy rounds until quiescence and report the
convergence lag (rounds / digest exchanges / snapshots pulled) plus a
check that every host ends on the identical newest version vector.  A
deliberately injected concurrent-version conflict (two hosts publish the
same version number for one tenant, as happens across a partition)
demonstrates the staleness-weighted reconciliation path.

Phase 2 — caching: the same bursty closed-loop trace (hot-keyed: requests
draw from a small per-tenant pool of feature vectors, the regime dashboards
and retries create) runs against two sharded serve fleets over the *same*
converged cluster — one with the per-(tenant, version, x-hash) result
cache, one without — at three arrival rates.  The simulated service model
``c0 + c1 * n_kernel`` only charges for requests that reach the vote
kernel, so cache hits translate directly into shorter batches.  The table
reports p99 with/without caching, the hit rate, and verifies the two
fleets returned identical predictions request-for-request.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve import (BatchConfig, GossipConfig, ShardCluster,
                         ShardedEnsembleServer)

SERVICE_C0 = 1.2e-3
SERVICE_C1 = 2.0e-4


def service_model(n_kernel: int) -> float:
    return SERVICE_C0 + SERVICE_C1 * n_kernel


def synth_ensemble(T: int, F: int, rng) -> Tuple[jnp.ndarray, jnp.ndarray]:
    params = np.zeros((T, 4), np.float32)
    params[:, 0] = rng.randint(0, F, size=T)
    params[:, 1] = rng.randn(T)
    params[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    alphas = (rng.rand(T) + 0.1).astype(np.float32)
    return jnp.asarray(params), jnp.asarray(alphas)


# --------------------------------------------------------------- phase 1
def convergence_phase(n_hosts: int, tenants: Sequence[str], versions: int,
                      F: int, seed: int) -> Tuple[ShardCluster, Dict]:
    cluster = ShardCluster(n_hosts, GossipConfig(seed=seed))
    rng = np.random.RandomState(seed)
    lags: List[int] = []
    for v in range(versions):
        for i, t in enumerate(tenants):
            p, a = synth_ensemble(T=4 + v + i % 3, F=F, rng=rng)
            cluster.publish_packed(t, p, a, clock=float(v),
                                   train_progress=8 * (v + 1))
        lags.append(cluster.run_until_quiescent(now=float(v)))

    # concurrent-version conflict: two replicas race to the same version
    # number for one tenant (partition scenario); the fresher, further-
    # trained snapshot must win everywhere via s(dt) weighting
    t0 = tenants[0]
    hosts = list(cluster.hosts.values())
    base = cluster.latest(t0).version
    p1, a1 = synth_ensemble(6, F, rng)
    p2, a2 = synth_ensemble(6, F, rng)
    hosts[0].registry.publish_packed(t0, p1, a1, clock=float(versions),
                                     train_progress=10)
    hosts[1].registry.publish_packed(t0, p2, a2, clock=float(versions) + 0.5,
                                     train_progress=40)
    conflict_lag = cluster.run_until_quiescent(now=float(versions) + 1.0)
    winners = {h.registry.latest(t0).fingerprint
               for h in cluster.hosts.values()}
    assert len(winners) == 1, "conflict left hosts disagreeing"
    assert cluster.latest(t0).version == base + 1
    assert cluster.latest(t0).train_progress == 40, (
        "staleness-weighted reconciliation picked the wrong snapshot")

    digests = list(cluster.digests().values())
    newest = {t: max(d.get(t, (0, ""))[0] for d in digests) for t in tenants}
    all_newest = all(d.get(t, (0, ""))[0] == newest[t]
                     for d in digests for t in tenants)
    info = {
        "mean_lag_rounds": float(np.mean(lags)),
        "max_lag_rounds": int(np.max(lags)),
        "conflict_lag_rounds": conflict_lag,
        "reconciled": cluster.stats.reconciled,
        "pulled": cluster.stats.pulled,
        "exchanges": cluster.stats.exchanges,
        "all_hosts_newest": bool(all_newest and cluster.converged()),
    }
    cluster.rebase_clock(0.0)
    return cluster, info


# --------------------------------------------------------------- phase 2
def gen_arrivals(tenants: Sequence[str], pools: Dict[str, np.ndarray],
                 rate: float, duration_s: float, seed: int
                 ) -> List[Tuple[float, str, np.ndarray]]:
    """Bursty hot-keyed trace: Poisson bursts, feature vectors drawn from
    the small per-tenant pool with a skewed (geometric-ish) distribution."""
    rng = np.random.RandomState(seed)
    out: List[Tuple[float, str, np.ndarray]] = []
    t = 0.0
    while t < duration_s:
        lam = rate * (3.0 if (t % 0.5) < 0.25 else 0.1)
        t += rng.exponential(1.0 / max(lam, 1e-9))
        if t >= duration_s:
            break
        tenant = tenants[rng.randint(len(tenants))]
        pool = pools[tenant]
        # skewed hot keys: floor of an exponential, clipped to the pool
        idx = min(pool.shape[0] - 1, int(rng.exponential(pool.shape[0] / 8)))
        out.append((t, tenant, pool[idx]))
    return out


def run_fleet(cluster: ShardCluster, arrivals, cache_capacity: int) -> Dict:
    server = ShardedEnsembleServer(
        cluster, BatchConfig(cache_capacity=cache_capacity),
        service_model=service_model)
    responses = []
    for t, tenant, x in arrivals:
        _, done = server.submit(tenant, x, t)
        responses += done
    responses += server.drain()
    rep = server.report()
    rep["margins"] = {r.rid: r.margin for r in responses}
    server.close()        # detach cache subscriptions from the shared cluster
    return rep


def main(quick: bool = False, seed: int = 0) -> List[Dict]:
    n_hosts = 3
    tenants = ["edge_vision", "iot", "healthcare", "finance"]
    versions = 3 if quick else 5
    F = 12
    duration = 2.0 if quick else 4.0
    rates = (120.0, 1500.0) if quick else (60.0, 400.0, 1500.0)
    pool_size = 48

    print("=" * 86)
    print(f"sharded registry — {n_hosts} hosts, {len(tenants)} tenants, "
          f"{versions} publish bursts, then cached-vs-uncached serve")
    print("=" * 86)
    cluster, conv = convergence_phase(n_hosts, tenants, versions, F, seed)
    print(f"gossip convergence lag: mean {conv['mean_lag_rounds']:.1f} / "
          f"max {conv['max_lag_rounds']} rounds per burst; "
          f"conflict reconciled in {conv['conflict_lag_rounds']} round(s) "
          f"({conv['reconciled']} reconciliations, {conv['pulled']} pulls, "
          f"{conv['exchanges']} exchanges)")
    print(f"every host on the newest version vector: "
          f"{conv['all_hosts_newest']}")

    rng = np.random.RandomState(seed + 1)
    pools = {t: rng.randn(pool_size, F).astype(np.float32) for t in tenants}

    hdr = (f"{'rate':>6} {'mode':<9} {'done':>6} {'p50 ms':>7} {'p99 ms':>7} "
           f"{'batch':>6} {'hit rate':>9}")
    print(hdr)
    print("-" * 86)
    rows: List[Dict] = []
    wins = []
    for rate in rates:
        arrivals = gen_arrivals(tenants, pools, rate, duration, seed)
        uncached = run_fleet(cluster, arrivals, cache_capacity=0)
        cached = run_fleet(cluster, arrivals, cache_capacity=65536)
        identical = (uncached["margins"] == cached["margins"]
                     and len(cached["margins"]) == len(arrivals))
        for mode, rep in (("uncached", uncached), ("cached", cached)):
            print(f"{rate:>6.0f} {mode:<9} {rep['completed']:>6} "
                  f"{rep['p50_ms']:>7.2f} {rep['p99_ms']:>7.2f} "
                  f"{rep['mean_batch']:>6.1f} "
                  f"{rep['cache']['hit_rate']:>9.1%}", flush=True)
            rows.append({
                "rate": rate, "mode": mode, "completed": rep["completed"],
                "p50_ms": rep["p50_ms"], "p99_ms": rep["p99_ms"],
                "hit_rate": rep["cache"]["hit_rate"],
                "identical_predictions": identical,
                "mean_lag_rounds": conv["mean_lag_rounds"],
            })
        won = (identical and cached["p99_ms"] < uncached["p99_ms"]
               and cached["completed"] >= 0.98 * uncached["completed"])
        if won:
            wins.append(rate)
        print(f"       identical predictions: {identical}   "
              f"cached p99 {'beats' if won else 'does not beat'} uncached")
    print("-" * 86)
    print(f"cached serve beats uncached p99 at {len(wins)}/{len(rates)} "
          f"rates: {', '.join(f'{w:.0f} rps' for w in wins) or '—'}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
