"""Wall-clock (non-simulated) kernel x backend x shape-bucket matrix.

For every public kernel and a small/large shape per kernel, times each
*available* backend (p50/p99 over repeated launches, after a warm-up
compile), records the per-bucket winner into a
:class:`~repro.kernels.dispatch.KernelPolicy` calibration table, and
persists it to ``artifacts/backend_calibration.json`` so serving restarts
skip recalibration.  A second (calibrated) pass then re-drives every case
through the dispatcher from the persisted table and asserts the cached
choice matches the measured winner.

This is the roadmap's wall-clock load test against the real kernel
latency — no simulated service model anywhere in this module.

    PYTHONPATH=src python -m benchmarks.run backend_matrix
    PYTHONPATH=src python -m benchmarks.backend_matrix --quick
"""
from __future__ import annotations

import argparse
import os
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.dispatch import (
    DEFAULT_CALIBRATION_PATH, KernelPolicy, available_backends)


def _cases(quick: bool) -> List[Tuple[str, str, tuple, dict]]:
    """(kernel, label, args, kwargs) per shape; small + (full-run) large."""
    ks = jax.random.split(jax.random.key(0), 6)

    def stump_scan(N, F, T):
        x = jax.random.normal(ks[0], (N, F))
        y = jnp.sign(jax.random.normal(ks[1], (N,)))
        w = jax.nn.softmax(jax.random.normal(ks[2], (N,)))
        thr = jnp.sort(jax.random.normal(ks[3], (F, T)), axis=1)
        return ("stump_scan", f"N{N}xF{F}xT{T}", (x, y, w, thr), {})

    def vote(T, N):
        m = jnp.sign(jax.random.normal(ks[0], (T, N)))
        a = jax.random.normal(ks[1], (T,))
        return ("ensemble_vote", f"T{T}xN{N}", (m, a), {})

    def vote_batched(B, T, N):
        m = jnp.sign(jax.random.normal(ks[0], (B, T, N)))
        a = jax.random.normal(ks[1], (B, T))
        return ("ensemble_vote_batched", f"B{B}xT{T}xN{N}", (m, a), {})

    def stump_vote(B, T, N):
        xsel = jax.random.normal(ks[0], (B, T, N))
        thr = jax.random.normal(ks[1], (B, T))
        pol = jnp.sign(jax.random.normal(ks[2], (B, T)) + 0.1)
        a = jax.random.normal(ks[3], (B, T))
        return ("stump_vote_batched", f"B{B}xT{T}xN{N}",
                (xsel, thr, pol, a), {})

    def dist(N):
        D = jax.nn.softmax(jax.random.normal(ks[0], (N,)))
        y = jnp.sign(jax.random.normal(ks[1], (N,)))
        h = jnp.sign(jax.random.normal(ks[2], (N,)))
        return ("dist_update", f"N{N}", (0.7, D, y, h), {})

    def flash(B, H, T, d):
        q = jax.random.normal(ks[0], (B, H, T, d), jnp.float32)
        k = jax.random.normal(ks[1], (B, H, T, d), jnp.float32)
        v = jax.random.normal(ks[2], (B, H, T, d), jnp.float32)
        return ("flash_attention", f"B{B}H{H}T{T}d{d}", (q, k, v), {})

    cases = [stump_scan(512, 16, 8), vote(64, 1024),
             vote_batched(4, 64, 256), stump_vote(4, 64, 256),
             dist(4096), flash(1, 2, 128, 64)]
    if not quick:
        cases += [stump_scan(2048, 64, 16), vote(256, 8192),
                  vote_batched(8, 128, 1024), stump_vote(8, 128, 1024),
                  dist(16384), flash(1, 2, 256, 128)]
    return cases


def main(quick: bool = False,
         out_path: str = DEFAULT_CALIBRATION_PATH) -> List[tuple]:
    reps = 5 if quick else 15
    policy = KernelPolicy()
    rows: List[tuple] = []
    entries = []
    print(f"backend matrix: backends {available_backends()} on "
          f"'{jax.default_backend()}', {reps} reps/case")
    for kernel, label, args, kwargs in _cases(quick):
        bucket, samples = policy.calibrate_call(kernel, *args, reps=reps,
                                                **kwargs)
        winner = policy.table[(kernel, bucket)]
        bstr = "x".join(map(str, bucket))
        print(f"{kernel:<22} {label:<16} bucket {bstr}")
        for name in sorted(samples):
            us = np.asarray(samples[name]) * 1e6
            p50, p99 = np.percentile(us, 50), np.percentile(us, 99)
            mark = "*" if name == winner else " "
            print(f"   {mark} {name:<10} p50 {p50:10.1f} us   "
                  f"p99 {p99:10.1f} us")
            rows.append((f"backend_{kernel}_{label}_{name}", float(p50),
                         f"p99_us={p99:.1f};bucket={bstr};winner={winner}"))
        entries.append((kernel, label, args, kwargs, bucket, winner))
    path = policy.save(out_path)
    print(f"calibration table ({len(policy.table)} buckets) -> {path}")

    # second (calibrated) run: reload the persisted table and drive every
    # case through the dispatcher with no explicit/env override — the
    # dispatcher's cached choice must match the calibrated winner.
    loaded = KernelPolicy.load(path)
    env_saved = os.environ.pop(loaded.env_var, None) if loaded.env_var \
        else None
    try:
        n_ok = 0
        for kernel, label, args, kwargs, bucket, winner in entries:
            getattr(ops, kernel)(*args, policy=loaded, **kwargs)
            got = loaded.choices[(kernel, bucket)]
            if got == winner:
                n_ok += 1
            else:
                print(f"  MISMATCH {kernel} bucket={bucket}: "
                      f"dispatched '{got}', calibrated '{winner}'")
    finally:
        if env_saved is not None:
            os.environ[loaded.env_var] = env_saved
    print(f"calibrated dispatch check: {n_ok}/{len(entries)} cached "
          f"choices match per-bucket winners")
    rows.append(("backend_matrix_dispatch_check", 0.0,
                 f"match={n_ok}/{len(entries)}"))
    if n_ok != len(entries):
        raise RuntimeError(
            f"calibrated dispatch check failed: only {n_ok}/{len(entries)} "
            f"cached choices match the winners persisted in {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_CALIBRATION_PATH)
    a = ap.parse_args()
    main(quick=a.quick, out_path=a.out)
