"""Wall-clock kernel x backend x block-layout x shape-bucket matrix.

For every public kernel and a small/large shape per kernel, times each
*available* backend over the kernel's **layout sweep grid**
(:data:`repro.kernels.dispatch.LAYOUT_GRIDS` — ``(block_t, block_n)`` for
the vote kernels, ``block_n`` for stump_scan/dist_update, ``(block_q,
block_k)`` for flash attention; the ``xla`` oracle has no block layout and
is measured once).  p50/p99 are reported per (backend, layout) after a
warm-up compile launch; the per-bucket ``(backend, layout)`` median winner
is recorded into a :class:`~repro.kernels.dispatch.KernelPolicy`
calibration table and persisted as schema v2 to
``artifacts/backend_calibration.json`` so serving restarts skip
recalibration.  A second (calibrated) pass then re-drives every case
through the dispatcher from the persisted table and asserts both the
cached backend choice *and* the injected layout match the measured winner.

The run also tallies ``layout wins``: (kernel, bucket) entries where some
non-default layout's p50 beats the reference layout's p50 on the same
Pallas backend — the autotune payoff the ISSUE's acceptance criteria pin
(>= 2 on CPU; small shapes whose candidate layouts all clamp to the same
effective blocks can't win and don't count).

Regenerating the checked-in table (CPU now; re-run on a TPU host for
Mosaic-measured layouts when hardware is available)::

    PYTHONPATH=src python -m benchmarks.backend_matrix            # full
    PYTHONPATH=src python -m benchmarks.backend_matrix --quick    # 6 cases
    PYTHONPATH=src python -m benchmarks.run backend_matrix        # via run.py

This is the roadmap's wall-clock load test against the real kernel
latency — no simulated service model anywhere in this module.
"""
from __future__ import annotations

import argparse
import os
import statistics
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.dispatch import (
    DEFAULT_CALIBRATION_PATH, DEFAULT_LAYOUTS, KernelPolicy,
    available_backends, layout_key, layout_label)


def _cases(quick: bool) -> List[Tuple[str, str, tuple, dict]]:
    """(kernel, label, args, kwargs) per shape; small + (full-run) large."""
    ks = jax.random.split(jax.random.key(0), 6)

    def stump_scan(N, F, T):
        x = jax.random.normal(ks[0], (N, F))
        y = jnp.sign(jax.random.normal(ks[1], (N,)))
        w = jax.nn.softmax(jax.random.normal(ks[2], (N,)))
        thr = jnp.sort(jax.random.normal(ks[3], (F, T)), axis=1)
        return ("stump_scan", f"N{N}xF{F}xT{T}", (x, y, w, thr), {})

    def vote(T, N):
        m = jnp.sign(jax.random.normal(ks[0], (T, N)))
        a = jax.random.normal(ks[1], (T,))
        return ("ensemble_vote", f"T{T}xN{N}", (m, a), {})

    def vote_batched(B, T, N):
        m = jnp.sign(jax.random.normal(ks[0], (B, T, N)))
        a = jax.random.normal(ks[1], (B, T))
        return ("ensemble_vote_batched", f"B{B}xT{T}xN{N}", (m, a), {})

    def stump_vote(B, T, N):
        xsel = jax.random.normal(ks[0], (B, T, N))
        thr = jax.random.normal(ks[1], (B, T))
        pol = jnp.sign(jax.random.normal(ks[2], (B, T)) + 0.1)
        a = jax.random.normal(ks[3], (B, T))
        return ("stump_vote_batched", f"B{B}xT{T}xN{N}",
                (xsel, thr, pol, a), {})

    def stump_vote_fp(B, T, N):
        xsel = jax.random.normal(ks[0], (B, T, N))
        thr = jax.random.normal(ks[1], (B, T))
        pol = jnp.sign(jax.random.normal(ks[2], (B, T)) + 0.1)
        a = jax.random.normal(ks[3], (B, T))
        return ("stump_vote_fp_batched", f"B{B}xT{T}xN{N}",
                (xsel, thr, pol, a), {})

    def dist(N):
        D = jax.nn.softmax(jax.random.normal(ks[0], (N,)))
        y = jnp.sign(jax.random.normal(ks[1], (N,)))
        h = jnp.sign(jax.random.normal(ks[2], (N,)))
        return ("dist_update", f"N{N}", (0.7, D, y, h), {})

    def flash(B, H, T, d):
        q = jax.random.normal(ks[0], (B, H, T, d), jnp.float32)
        k = jax.random.normal(ks[1], (B, H, T, d), jnp.float32)
        v = jax.random.normal(ks[2], (B, H, T, d), jnp.float32)
        return ("flash_attention", f"B{B}H{H}T{T}d{d}", (q, k, v), {})

    cases = [stump_scan(512, 16, 8), vote(64, 1024),
             vote_batched(4, 64, 256), stump_vote(4, 64, 256),
             stump_vote_fp(4, 64, 256), dist(4096), flash(1, 2, 128, 64)]
    if not quick:
        cases += [stump_scan(2048, 64, 16), vote(256, 8192),
                  vote_batched(8, 128, 1024), stump_vote(8, 128, 1024),
                  stump_vote_fp(8, 128, 1024), dist(16384),
                  flash(1, 2, 256, 128)]
    return cases


def main(quick: bool = False,
         out_path: str = DEFAULT_CALIBRATION_PATH) -> List[tuple]:
    reps = 5 if quick else 15
    policy = KernelPolicy()
    rows: List[tuple] = []
    entries = []
    layout_wins = 0
    print(f"backend matrix: backends {available_backends()} on "
          f"'{jax.default_backend()}', {reps} reps/case, layout sweep per "
          f"Pallas backend")
    for kernel, label, args, kwargs in _cases(quick):
        bucket, samples = policy.calibrate_call(kernel, *args, reps=reps,
                                                **kwargs)
        entry = policy.table[(kernel, bucket)]
        winner_key = (entry.backend, entry.layout)
        bstr = "x".join(map(str, bucket))
        print(f"{kernel:<22} {label:<16} bucket {bstr}")
        ref_key = layout_key(DEFAULT_LAYOUTS.get(kernel, {}))
        p50s = {}
        for skey in sorted(samples):
            name, lkey = skey
            us = np.asarray(samples[skey]) * 1e6
            p50, p99 = np.percentile(us, 50), np.percentile(us, 99)
            p50s[skey] = float(statistics.median(samples[skey]))
            mark = "*" if skey == winner_key else " "
            lstr = layout_label(lkey)
            print(f"   {mark} {name:<10} {lstr:<28} p50 {p50:10.1f} us   "
                  f"p99 {p99:10.1f} us")
            rows.append((f"backend_{kernel}_{label}_{name}_{lstr}",
                         float(p50),
                         f"p99_us={p99:.1f};bucket={bstr};"
                         f"winner={entry.backend}/"
                         f"{layout_label(entry.layout)}"))
        # layout win: on some Pallas backend, a non-default layout's p50
        # beats the reference layout's p50 for this (kernel, bucket)
        for name in {n for n, _ in samples if n != "xla"}:
            if (name, ref_key) not in p50s:
                continue
            best_key = min((k for k in p50s if k[0] == name),
                           key=lambda k: p50s[k])
            if best_key[1] != ref_key and \
                    p50s[best_key] < p50s[(name, ref_key)]:
                layout_wins += 1
                print(f"     layout win [{name}]: "
                      f"{layout_label(best_key[1])} beats default "
                      f"{layout_label(ref_key)} "
                      f"({p50s[best_key] * 1e6:.1f} vs "
                      f"{p50s[(name, ref_key)] * 1e6:.1f} us p50)")
        entries.append((kernel, label, args, kwargs, bucket, entry))
    path = policy.save(out_path)
    print(f"calibration table ({len(policy.table)} buckets, schema v2) "
          f"-> {path}")
    print(f"layout wins (tuned beats default p50 on a Pallas backend): "
          f"{layout_wins}")
    rows.append(("backend_matrix_layout_wins", float(layout_wins), ""))
    if layout_wins < 2:
        raise RuntimeError(
            f"layout sweep produced only {layout_wins} (kernel, bucket) "
            f"entries where a tuned layout beats the hardcoded default "
            f"(need >= 2) — autotuning is not paying for itself")

    # second (calibrated) run: reload the persisted table and drive every
    # case through the dispatcher with no explicit/env override — the
    # dispatcher's cached backend choice and injected layout must both
    # match the calibrated winner.
    loaded = KernelPolicy.load(path)
    env_saved = os.environ.pop(loaded.env_var, None) if loaded.env_var \
        else None
    try:
        n_ok = 0
        for kernel, label, args, kwargs, bucket, entry in entries:
            getattr(ops, kernel)(*args, policy=loaded, **kwargs)
            got = loaded.choices[(kernel, bucket)]
            got_layout = layout_key(loaded.layout_choices[(kernel, bucket)])
            want_layout = entry.layout if entry.layout else layout_key(
                DEFAULT_LAYOUTS.get(kernel, {}))
            if got == entry.backend and got_layout == want_layout:
                n_ok += 1
            else:
                print(f"  MISMATCH {kernel} bucket={bucket}: dispatched "
                      f"'{got}'/{layout_label(got_layout)}, calibrated "
                      f"'{entry.backend}'/{layout_label(want_layout)}")
    finally:
        if env_saved is not None:
            os.environ[loaded.env_var] = env_saved
    print(f"calibrated dispatch check: {n_ok}/{len(entries)} cached "
          f"(backend, layout) choices match per-bucket winners")
    rows.append(("backend_matrix_dispatch_check", 0.0,
                 f"match={n_ok}/{len(entries)}"))
    if n_ok != len(entries):
        raise RuntimeError(
            f"calibrated dispatch check failed: only {n_ok}/{len(entries)} "
            f"cached choices match the winners persisted in {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_CALIBRATION_PATH)
    a = ap.parse_args()
    main(quick=a.quick, out_path=a.out)
