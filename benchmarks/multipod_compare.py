"""Single-pod (16x16) vs multi-pod (2x16x16) scaling report from the
dry-run artifacts: per-device roofline terms should ~halve when the pod
axis doubles data parallelism, EXCEPT collective terms that cross the
(slower) inter-pod links — the table surfaces which archs scale cleanly.
"""
from __future__ import annotations

from typing import List

from benchmarks.roofline import analyze_record, load_records


def main() -> List[dict]:
    single = {(r["arch"], r["shape"]): analyze_record(r)
              for r in load_records("pod16x16")}
    multi = {(r["arch"], r["shape"]): analyze_record(r)
             for r in load_records("pod2x16x16")}
    print(f"{'arch':<24} {'shape':<12} {'cmp x':>6} {'coll x':>7}  verdict")
    out = []
    for key in sorted(single):
        a, b = single.get(key), multi.get(key)
        if not a or not b:
            continue
        cr = (b["t_compute_s"] / a["t_compute_s"]
              if a["t_compute_s"] else float("nan"))
        xr = (b["t_collective_s"] / a["t_collective_s"]
              if a["t_collective_s"] else float("nan"))
        verdict = ("clean" if cr < 0.6 and (xr != xr or xr < 0.75)
                   else "comm-limited" if cr < 0.6 else "flat")
        print(f"{key[0]:<24} {key[1]:<12} {cr:>6.2f} {xr:>7.2f}  {verdict}")
        out.append({"arch": key[0], "shape": key[1], "compute_ratio": cr,
                    "collective_ratio": xr, "verdict": verdict})
    return out


if __name__ == "__main__":
    main()
