"""Kernel microbenchmarks: wall time with the backend pinned to
Pallas-interpret (correctness path, NOT TPU-representative, immune to
REPRO_KERNEL_BACKEND overrides — see benchmarks/backend_matrix.py for the
cross-backend matrix) + the structural numbers that matter for TPU:
per-block VMEM footprint, FLOPs, and arithmetic intensity per kernel tile.

Emits ``name,us_per_call,derived`` CSV rows (harness convention).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def stump_vmem_bytes(block_n: int, F: int, T: int) -> int:
    # x block + y/w + threshold grid + (bn,F,T) predicate tile + (F,T) acc
    return 4 * (block_n * F + 2 * block_n + F * T + block_n * F * T + F * T)


def flash_vmem_bytes(bq: int, bk: int, d: int) -> int:
    # q,k,v tiles + scores + m/l/acc scratch (f32)
    return 4 * (bq * d + 2 * bk * d + bq * bk + 2 * bq + bq * d)


def rows() -> List[Tuple[str, float, str]]:
    out = []
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)

    # stump_scan: the boosting inner loop
    N, F, T = 2048, 64, 16
    x = jax.random.normal(ks[0], (N, F))
    y = jnp.sign(jax.random.normal(ks[1], (N,)))
    w = jax.nn.softmax(jax.random.normal(ks[2], (N,)))
    thr = jnp.sort(jax.random.normal(ks[3], (F, T)), axis=1)
    us_k = _time(lambda *a: ops.stump_scan(*a, backend="interpret"), x, y, w, thr)
    us_r = _time(lambda *a: ref.stump_scan_ref(*a), x, y, w, thr)
    flops = 2.0 * N * F * T
    vmem = stump_vmem_bytes(256, F, T)
    out.append(("stump_scan_pallas_interp", us_k,
                f"N{N}xF{F}xT{T};vmem_block={vmem/1e3:.0f}KB;"
                f"flops={flops/1e6:.1f}MF"))
    out.append(("stump_scan_jnp_ref", us_r, "same-shape oracle"))

    # dist_update: the per-round distribution refresh (paper eq. 4)
    Nd = 8192
    D = jax.nn.softmax(jax.random.normal(ks[0], (Nd,)))
    yd = jnp.sign(jax.random.normal(ks[1], (Nd,)))
    hd = jnp.sign(jax.random.normal(ks[2], (Nd,)))
    us_k = _time(lambda *z: ops.dist_update(*z, backend="interpret"), 0.7, D, yd, hd)
    us_r = _time(lambda *z: ref.dist_update_ref(*z), 0.7, D, yd, hd)
    out.append(("dist_update_pallas_interp", us_k,
                f"N{Nd};hbm_sweeps=1-vs-3;bytes={3*Nd*4/1e3:.0f}KB"))
    out.append(("dist_update_jnp_ref", us_r, ""))

    # ensemble_vote
    Tm, Nm = 256, 8192
    m = jnp.sign(jax.random.normal(ks[0], (Tm, Nm)))
    a = jax.random.normal(ks[1], (Tm,))
    out.append(("ensemble_vote_pallas_interp",
                _time(lambda *z: ops.ensemble_vote(*z, backend="interpret"), m, a),
                f"T{Tm}xN{Nm};hbm_saved={(Tm*Nm*4)/1e6:.1f}MB-roundtrip"))
    out.append(("ensemble_vote_jnp_ref",
                _time(lambda *z: ref.ensemble_vote_ref(*z), m, a), ""))

    # flash_attention: 32k-prefill block (scaled for CPU interpret)
    B, H, Tt, d = 1, 2, 1024, 128
    q = jax.random.normal(ks[0], (B, H, Tt, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, Tt, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, Tt, d), jnp.float32)
    us_k = _time(lambda *z: ops.flash_attention(*z, backend="interpret"), q, k, v)
    us_r = _time(lambda *z: ref.flash_attention_ref(*z), q, k, v)
    vmem = flash_vmem_bytes(128, 128, d)
    ai = (4 * Tt * Tt * d) / (4 * 3 * Tt * d)   # flops / bytes-in per head
    out.append(("flash_attention_pallas_interp", us_k,
                f"T{Tt}xd{d};vmem_block={vmem/1e3:.0f}KB;"
                f"arith_intensity={ai:.0f}"))
    out.append(("flash_attention_jnp_ref", us_r,
                f"hbm_logits={(H*Tt*Tt*4)/1e6:.0f}MB-materialized"))
    return out


def main() -> None:
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
