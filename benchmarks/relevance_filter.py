"""BEYOND-PAPER: client-side relevance filtering of buffered learners.

The paper remarks (Mobile Personalization) that "fewer but more relevant
updates enabled better efficiency" but gives no mechanism.  We add one: at
sync, a client drops buffered learners whose staleness-compensated local
vote weight is below `f x` the buffer's best — they would enter the global
ensemble with negligible weight anyway, so their uplink bytes are wasted.

This composes with the paper's scheduling (it filters WITHIN the buffers
the adaptive interval creates).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.data import make_domain_data


def main() -> List[dict]:
    dom = DOMAINS["mobile"]
    data = make_domain_data(dom, seed=0)
    print("=" * 72)
    print("Beyond-paper: relevance-filtered buffers (mobile domain)")
    print("=" * 72)
    print(f"{'filter':>7} {'uplink_B':>9} {'total_B':>9} {'learners':>9} "
          f"{'test_err':>9}")
    out = []
    for f in (0.0, 0.1, 0.25, 0.5, 0.75):
        cfg = FedBoostConfig(
            n_clients=dom.n_clients, n_rounds=25,
            straggler_factor=dom.straggler_factor,
            dropout_prob=dom.dropout_prob, link_mbps=dom.link_mbps,
            relevance_filter=f, seed=0)
        m = FederatedBoostEngine(cfg, data, "enhanced").run()
        print(f"{f:>7.2f} {m.uplink_bytes:>9} {m.total_bytes:>9} "
              f"{m.learners_merged:>9} {m.final_test_error:>9.3f}",
              flush=True)
        out.append({"filter": f, "bytes": m.total_bytes,
                    "err": m.final_test_error})
    return out


if __name__ == "__main__":
    main()
