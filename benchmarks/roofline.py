"""Roofline analysis from the dry-run artifacts (§Roofline).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs_per_chip / 197e12        [bf16 MXU peak]
    memory term     = HLO_bytes_per_chip / 819e9         [HBM bw]
    collective term = collective_bytes_per_chip / 50e9   [ICI link bw]

HLO_FLOPs / collective bytes are the trip-count-corrected values from
launch/hlo_analysis.py (XLA's cost_analysis visits loop bodies once; see
that module).  Two memory conventions are reported:
    mem(hlo)  — HloCostAnalysis-style sum of operand+result bytes
                (upper bound: ignores fusion locality)
    mem(min)  — analytic streaming lower bound: parameter + optimizer +
                KV/state-cache traffic per step per chip
The dominant term is judged with mem(min) (the defensible bound); when
mem(hlo) flips the verdict it is flagged.

MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference),
per chip; the ratio MODEL_FLOPS/HLO_FLOPs measures how much compiled
compute is useful (remat + capacity slack + attention show up here).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs.registry import ARCHS, SHAPES

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops_per_chip(arch: str, shape: str, n_devices: int) -> float:
    cfg = ARCHS[arch]
    sh = SHAPES[shape]
    n_active = cfg.active_param_count()
    if sh.mode == "train":
        tokens = sh.global_batch * sh.seq_len
        total = 6.0 * n_active * tokens
    elif sh.mode == "prefill":
        tokens = sh.global_batch * sh.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * sh.global_batch
    return total / n_devices


def mem_min_per_chip(arch: str, shape: str, n_devices: int) -> float:
    """Analytic streaming lower bound on HBM bytes per step per chip."""
    cfg = ARCHS[arch]
    sh = SHAPES[shape]
    p = cfg.param_count()
    if sh.mode == "train":
        # params read (bf16) x3 (fwd/bwd/remat) + grads w (bf16)
        # + adam m,v r/w (bf16) + params w
        per_param = 2 * 3 + 2 + 4 * 2 + 2
        base = p * per_param
        act = sh.global_batch * sh.seq_len * cfg.d_model * cfg.n_layers * 2 * 4
        return (base + act) / n_devices
    if sh.mode == "prefill":
        act = sh.global_batch * sh.seq_len * cfg.d_model * cfg.n_layers * 2 * 2
        return (p * 2 + act) / n_devices
    # decode: all (active) params + full KV/state cache read per token
    cache = 0
    hd = cfg.resolved_head_dim
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind in ("attn", "attn_local"):
            S = sh.seq_len
            if kind == "attn_local" and cfg.sliding_window:
                S = min(S, cfg.sliding_window)
            cache += 2 * sh.global_batch * S * cfg.n_kv_heads * hd * 2
        else:
            mc = cfg.mamba
            cache += sh.global_batch * mc.n_heads(cfg.d_model) * mc.head_dim \
                * mc.d_state * 4
    return (cfg.active_param_count() * 2 + cache) / n_devices


def load_records(mesh: str = "pod16x16", variant: str = "baseline"
                 ) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(
            ART_DIR, f"*__{mesh}__{variant}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    arch, shape = rec["arch"], rec["shape"]
    flops = rec.get("flops_corrected", 0.0)
    mem_hlo = rec.get("bytes_accessed_corrected", 0.0)
    coll = rec.get("collective_bytes_total", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m_hlo = mem_hlo / HBM_BW
    t_m_min = mem_min_per_chip(arch, shape, n) / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m_min, "collective": t_x}
    dom = max(terms, key=terms.get)
    terms_hlo = {"compute": t_c, "memory": t_m_hlo, "collective": t_x}
    dom_hlo = max(terms_hlo, key=terms_hlo.get)
    mf = model_flops_per_chip(arch, shape, n)
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "t_compute_s": t_c, "t_mem_min_s": t_m_min, "t_mem_hlo_s": t_m_hlo,
        "t_collective_s": t_x,
        "dominant": dom, "dominant_hlo_conv": dom_hlo,
        "model_flops_per_chip": mf,
        "useful_ratio": (mf / flops) if flops else 0.0,
        "compile_s": rec.get("compile_s"),
    }


def table(mesh: str = "pod16x16", variant: str = "baseline") -> List[Dict]:
    rows = [r for r in (analyze_record(x) for x in load_records(mesh, variant))
            if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def render(rows: List[Dict]) -> str:
    hdr = (f"| {'arch':<24} | {'shape':<11} | {'compute s':>9} | "
           f"{'mem(min) s':>10} | {'mem(hlo) s':>10} | {'coll s':>9} | "
           f"{'dominant':<10} | {'useful':>6} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']:<24} | {r['shape']:<11} | {r['t_compute_s']:>9.4f} | "
            f"{r['t_mem_min_s']:>10.4f} | {r['t_mem_hlo_s']:>10.4f} | "
            f"{r['t_collective_s']:>9.4f} | {r['dominant']:<10} | "
            f"{r['useful_ratio']:>6.2f} |")
    return "\n".join(out)


def main() -> None:
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = table(mesh)
        if not rows:
            print(f"(no artifacts for {mesh}; run "
                  f"`python -m repro.launch.dryrun --all`)")
            continue
        print(f"\n### Roofline — {mesh} (baseline)\n")
        print(render(rows))
        doms = {}
        for r in rows:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\ndominant-term census: {doms}")


if __name__ == "__main__":
    main()
