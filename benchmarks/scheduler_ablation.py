"""Ablation of the adaptive scheduling rule (paper eq. 1): fixed intervals
vs the adaptive controller, and sensitivity to (alpha, beta, I_max).

Shows the paper's core trade: a fixed small interval wastes communication,
a fixed large interval hurts early convergence; the adaptive rule gets the
comm savings of the large interval without its convergence penalty.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.paper_fedboost import FedBoostConfig, SchedulerConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.core.metrics import time_to_error
from repro.data import make_domain_data


def run_one(sched: SchedulerConfig, data, dom, n_rounds=25, seed=0):
    cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=n_rounds,
                         scheduler=sched,
                         straggler_factor=dom.straggler_factor,
                         dropout_prob=dom.dropout_prob,
                         link_mbps=dom.link_mbps, seed=seed)
    return FederatedBoostEngine(cfg, data, "enhanced").run()


def main() -> List[Dict]:
    dom = DOMAINS["edge_vision"]
    data = make_domain_data(dom, seed=0)
    variants = {
        "fixed I=1 (sync-ish)": SchedulerConfig(alpha=0, beta=0, i_init=1),
        "fixed I=4": SchedulerConfig(alpha=0, beta=0, i_init=4, i_max=4),
        "fixed I=8": SchedulerConfig(alpha=0, beta=0, i_init=8, i_max=8),
        "adaptive (paper)": SchedulerConfig(),
        "adaptive fast (a=2)": SchedulerConfig(alpha=2.0),
        "adaptive cautious (b=4)": SchedulerConfig(beta=4.0),
        "adaptive Imax=16": SchedulerConfig(i_max=16),
    }
    print("=" * 86)
    print("Scheduler ablation (edge_vision): adaptive rule vs fixed intervals")
    print("=" * 86)
    print(f"{'variant':<26} {'bytes':>10} {'msgs':>6} {'syncs':>6} "
          f"{'val_err':>8} {'t->0.25':>8}")
    out = []
    for name, sched in variants.items():
        m = run_one(sched, data, dom)
        hit = time_to_error(m.val_error_curve, 0.25)
        t = f"{hit[0]:8.1f}" if hit else "     n/a"
        print(f"{name:<26} {m.total_bytes:>10} {m.n_messages:>6} "
              f"{m.n_syncs:>6} {m.final_val_error:>8.3f} {t}", flush=True)
        out.append({"variant": name, "bytes": m.total_bytes,
                    "messages": m.n_messages, "err": m.final_val_error})
    return out


if __name__ == "__main__":
    main()
