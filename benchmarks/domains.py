"""Paper Table 1 reproduction: relative improvements of the enhanced
asynchronous AdaBoost over synchronous distributed AdaBoost across the five
application domains.

Domain definitions and paper bands are sourced from the scenario registry
(:mod:`repro.sim.scenarios`) — the single place that binds each domain to
its environment, partitioner, behavior traces, and Table-1 bands.  This
module reproduces the table under the ``legacy`` (scalar) behavior trace;
``benchmarks/scenario_matrix.py`` sweeps the full trace matrix.

Metrics per domain (mean over seeds):
  * training time down   — time to reach the common target error
                           (paper band: ~15-35 %)
  * comm overhead down   — total bytes on the wire (paper band: ~20-40 %)
  * convergence down     — merged learners to the common target
                           (paper band: ~15-20 %)
  * accuracy delta       — final test-accuracy difference in pp
                           (paper band: ~0 to +2 pp)
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.sim.harness import result_row, train_pair
from repro.sim.scenarios import base_scenarios, get_scenario


def __getattr__(name: str):
    # DEPRECATED: the bands table moved into the scenario registry; this
    # shim keeps `benchmarks.domains.PAPER_BANDS` alive for one release.
    if name == "PAPER_BANDS":
        import warnings
        warnings.warn(
            "benchmarks.domains.PAPER_BANDS is deprecated; use "
            "repro.sim.scenarios.PAPER_BANDS (band midpoints) or "
            "get_scenario(name).band (full ranges)",
            DeprecationWarning, stacklevel=2)
        from repro.sim.scenarios import PAPER_BANDS
        return PAPER_BANDS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def run_domain(name: str, n_rounds: int = 30, seeds=(0, 1, 2)) -> Dict:
    sc = get_scenario(name)
    rows = []
    for seed in seeds:
        _, runs = train_pair(sc, "legacy", seed=seed, n_rounds=n_rounds)
        row = result_row(runs)
        row.pop("unavailable_rounds", None)
        rows.append(row)
    agg = {k: float(np.mean([r[k] for r in rows])) for k in rows[0]}
    agg["domain"] = name
    return agg


def main(n_rounds: int = 30, seeds=(0, 1, 2)) -> List[Dict]:
    print("=" * 98)
    print("Table 1 reproduction — enhanced async AdaBoost vs sync distributed"
          " AdaBoost (mean of %d seeds)" % len(seeds))
    print("=" * 98)
    hdr = (f"{'domain':<13} {'time↓%':>7} {'comm↓%':>7} {'msgs↓%':>7} "
           f"{'conv↓%':>7} {'accΔpp':>7} | paper: time/comm/conv/acc")
    print(hdr)
    print("-" * 98)
    out = []
    for name in base_scenarios():
        agg = run_domain(name, n_rounds=n_rounds, seeds=seeds)
        p = get_scenario(name).band.midpoints
        print(f"{name:<13} {agg['time_down']:>7.1f} {agg['comm_down']:>7.1f} "
              f"{agg['msgs_down']:>7.1f} {agg['conv_down']:>7.1f} "
              f"{agg['acc_delta_pp']:>+7.1f} | "
              f"~{p[0]:.0f}% / ~{p[1]:.0f}% / ~{p[2]:.0f}% / +{p[3]}pp",
              flush=True)
        out.append(agg)
    print("-" * 98)
    return out


if __name__ == "__main__":
    main()
