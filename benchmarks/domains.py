"""Paper Table 1 reproduction: relative improvements of the enhanced
asynchronous AdaBoost over synchronous distributed AdaBoost across the five
application domains.

Metrics per domain (mean over seeds):
  * training time down   — time to reach the common target error
                           (paper band: ~15-35 %)
  * comm overhead down   — total bytes on the wire (paper band: ~20-40 %)
  * convergence down     — merged learners to the common target
                           (paper band: ~15-20 %)
  * accuracy delta       — final test-accuracy difference in pp
                           (paper band: ~0 to +2 pp)
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.configs.paper_fedboost import DOMAINS, FedBoostConfig
from repro.core import FederatedBoostEngine
from repro.core.metrics import common_target, pct_reduction, time_to_error
from repro.data import make_domain_data

PAPER_BANDS = {
    # domain: (time down %, comm down %, conv down %, acc delta pp) midpoints
    "edge_vision": (25, 30, 20, 1.0),
    "blockchain": (32, 40, 20, 0.9),
    "mobile": (22, 27, 15, 0.5),
    "iot": (20, 25, 15, 0.0),
    "healthcare": (17, 25, 20, 1.5),
}


def run_domain(name: str, n_rounds: int = 30, seeds=(0, 1, 2)) -> Dict:
    dom = DOMAINS[name]
    rows = []
    for seed in seeds:
        data = make_domain_data(dom, seed=seed)
        cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=n_rounds,
                             straggler_factor=dom.straggler_factor,
                             dropout_prob=dom.dropout_prob,
                             link_mbps=dom.link_mbps, seed=seed,
                             balanced_init=dom.label_imbalance < 0.4)
        runs = {m: FederatedBoostEngine(cfg, data, m).run()
                for m in ("baseline", "enhanced")}
        b, e = runs["baseline"], runs["enhanced"]
        tgt = common_target([b.val_error_curve, e.val_error_curve])
        tb = time_to_error(b.val_error_curve, tgt)
        te = time_to_error(e.val_error_curve, tgt)
        rows.append({
            "time_down": pct_reduction(tb[0], te[0]) if tb and te else 0.0,
            "comm_down": pct_reduction(b.total_bytes, e.total_bytes),
            "msgs_down": pct_reduction(b.n_messages, e.n_messages),
            "conv_down": pct_reduction(tb[1], te[1]) if tb and te else 0.0,
            "acc_delta_pp": 100 * (b.final_test_error - e.final_test_error),
            "base_err": b.final_test_error,
            "enh_err": e.final_test_error,
            "base_bytes": b.total_bytes,
            "enh_bytes": e.total_bytes,
        })
    agg = {k: float(np.mean([r[k] for r in rows])) for k in rows[0]}
    agg["domain"] = name
    return agg


def main(n_rounds: int = 30, seeds=(0, 1, 2)) -> List[Dict]:
    print("=" * 98)
    print("Table 1 reproduction — enhanced async AdaBoost vs sync distributed"
          " AdaBoost (mean of %d seeds)" % len(seeds))
    print("=" * 98)
    hdr = (f"{'domain':<13} {'time↓%':>7} {'comm↓%':>7} {'msgs↓%':>7} "
           f"{'conv↓%':>7} {'accΔpp':>7} | paper: time/comm/conv/acc")
    print(hdr)
    print("-" * 98)
    out = []
    for name in DOMAINS:
        agg = run_domain(name, n_rounds=n_rounds, seeds=seeds)
        p = PAPER_BANDS[name]
        print(f"{name:<13} {agg['time_down']:>7.1f} {agg['comm_down']:>7.1f} "
              f"{agg['msgs_down']:>7.1f} {agg['conv_down']:>7.1f} "
              f"{agg['acc_delta_pp']:>+7.1f} | "
              f"~{p[0]}% / ~{p[1]}% / ~{p[2]}% / +{p[3]}pp", flush=True)
        out.append(agg)
    print("-" * 98)
    return out


if __name__ == "__main__":
    main()
