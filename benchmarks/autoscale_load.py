"""Closed-loop fleet-autoscaling benchmark: a queue-pressure-autoscaled
sharded fleet vs a fixed-size fleet, swept over bursty arrival rates.

Both fleets serve the identical bursty Poisson trace (3x nominal rate
on-phase, 0.1x off-phase) against the same rendezvous-sharded cluster
under a simulated clock with the analytic batch service-time model
``c0 + c1*n`` — the regime where a host is a genuine unit of capacity, so
membership is the knob that moves p99 and shed load.  Ensembles are
synthetic packed stumps: the capacity-control question is independent of
how the ensembles were trained, and a hermetic registry keeps the A/B
free of training noise (the serve-side hand-off path itself is exercised
by ``benchmarks/serving_load`` and ``shard_gossip``).

* ``fixed``      — ``ShardedEnsembleServer`` over ``min_hosts`` hosts;
* ``autoscaled`` — the same server driven by :class:`FleetAutoscaler`
  (eq.-(1) controller on the negated integrated queue/p99 pressure),
  free to grow to ``max_hosts`` and to drain back down.

Acceptance (asserted): the autoscaled fleet beats the fixed fleet on p99
latency (at comparable completed traffic) **or** on rejection rate at
two or more of the three load levels, and no accepted request is ever
lost across the membership churn (completed == accepted, rids unique).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve import (AutoscaleConfig, BatchConfig, FleetAutoscaler,
                         GossipConfig, ShardCluster, ShardedEnsembleServer)

# batch service-time model: fixed dispatch overhead + per-request cost
SERVICE_C0 = 1.2e-3
SERVICE_C1 = 8.0e-4

N_TENANTS = 8
MIN_HOSTS = 2
MAX_HOSTS = 8

BATCH = BatchConfig(queue_budget=64, max_batch=16, target_p99_s=0.05)
AUTOSCALE = AutoscaleConfig(min_hosts=MIN_HOSTS, max_hosts=MAX_HOSTS,
                            target_queue=16.0, target_p99_s=0.10,
                            adapt_every_s=0.02, step_down=0.1)


def service_model(n: int) -> float:
    return SERVICE_C0 + SERVICE_C1 * n


def build_cluster(n_hosts: int, tenants: Sequence[str], seed: int,
                  T: int = 24, F: int = 16) -> ShardCluster:
    """A converged cluster holding one synthetic stump ensemble per tenant."""
    cluster = ShardCluster(n_hosts, GossipConfig(seed=seed))
    rng = np.random.RandomState(seed)
    for tenant in tenants:
        params = np.zeros((T, 4), np.float32)
        params[:, 0] = rng.randint(0, F, size=T)
        params[:, 1] = rng.randn(T)
        params[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
        alphas = (rng.rand(T) + 0.1).astype(np.float32)
        cluster.publish_packed(tenant, jnp.asarray(params),
                               jnp.asarray(alphas))
    cluster.run_until_quiescent()
    return cluster


def gen_arrivals(tenants: Sequence[str], rate: float, duration_s: float,
                 seed: int, F: int = 16
                 ) -> List[Tuple[float, str, np.ndarray]]:
    """Bursty Poisson trace, same shape as ``benchmarks/serving_load``."""
    rng = np.random.RandomState(seed)
    out: List[Tuple[float, str, np.ndarray]] = []
    t = 0.0
    while t < duration_s:
        lam = rate * (3.0 if (t % 0.5) < 0.25 else 0.1)
        t += rng.exponential(1.0 / max(lam, 1e-9))
        if t >= duration_s:
            break
        out.append((t, tenants[rng.randint(len(tenants))],
                    rng.randn(F).astype(np.float32)))
    return out


def run_fleet(arrivals, seed: int, autoscale: bool) -> Dict:
    tenants = [f"tenant-{i}" for i in range(N_TENANTS)]
    cluster = build_cluster(MIN_HOSTS, tenants, seed=seed)
    server = ShardedEnsembleServer(cluster, BATCH,
                                   service_model=service_model)
    scaler = FleetAutoscaler(server, AUTOSCALE) if autoscale else None
    accepted = 0
    rids: List[int] = []
    for t, tenant, x in arrivals:
        ok, out = server.submit(tenant, x, t)
        accepted += ok
        rids.extend(r.rid for r in out)
        if scaler is not None:
            rids.extend(r.rid for r in scaler.step(t))
    rids.extend(r.rid for r in server.drain())

    # zero-loss invariant: every accepted request answered exactly once,
    # through every scale-out warm-up and scale-in drain
    if len(rids) != accepted or len(set(rids)) != len(rids):
        raise AssertionError(
            f"request loss under churn: accepted={accepted} "
            f"answered={len(rids)} unique={len(set(rids))}")

    rep = server.report()
    row = {
        "fleet": "autoscaled" if autoscale else "fixed",
        "completed": rep["completed"], "rejected": rep["rejected"],
        "p50_ms": rep["p50_ms"], "p99_ms": rep["p99_ms"],
        "throughput_rps": rep["throughput_rps"],
        "hosts_final": len(server.servers),
        "scale_outs": scaler.stats.scale_outs if scaler else 0,
        "scale_ins": scaler.stats.scale_ins if scaler else 0,
        "rerouted": scaler.stats.rerouted if scaler else 0,
    }
    offered = row["completed"] + row["rejected"]
    row["rej_rate"] = row["rejected"] / offered if offered else 0.0
    return row


def _beats(auto: Dict, fixed: Dict) -> bool:
    """Autoscaling wins a load level on shed load or on tail latency."""
    if fixed["rej_rate"] > 0.01 and auto["rej_rate"] < 0.8 * fixed["rej_rate"]:
        return True
    comparable = auto["completed"] >= 0.98 * fixed["completed"]
    return comparable and auto["p99_ms"] < 0.95 * fixed["p99_ms"]


def main(quick: bool = False, seed: int = 0) -> List[Dict]:
    duration = 1.5 if quick else 3.0
    rates = (300.0, 900.0, 1800.0)
    tenants = [f"tenant-{i}" for i in range(N_TENANTS)]

    print("=" * 86)
    print(f"fleet autoscaling — eq.-(1) pressure controller "
          f"({MIN_HOSTS}..{MAX_HOSTS} hosts) vs fixed {MIN_HOSTS}-host fleet")
    print("=" * 86)
    hdr = (f"{'rate':>6} {'fleet':<11} {'done':>6} {'rej':>6} {'rej%':>6} "
           f"{'p50 ms':>8} {'p99 ms':>8} {'hosts':>5} {'out/in':>7}")
    print(hdr)
    print("-" * 86)

    rows: List[Dict] = []
    wins = []
    for rate in rates:
        arrivals = gen_arrivals(tenants, rate, duration, seed)
        pair = {}
        for autoscale in (False, True):
            row = run_fleet(arrivals, seed=seed, autoscale=autoscale)
            row["rate"] = rate
            pair[row["fleet"]] = row
            rows.append(row)
            print(f"{rate:>6.0f} {row['fleet']:<11} {row['completed']:>6} "
                  f"{row['rejected']:>6} {100 * row['rej_rate']:>5.1f}% "
                  f"{row['p50_ms']:>8.2f} {row['p99_ms']:>8.2f} "
                  f"{row['hosts_final']:>5} "
                  f"{row['scale_outs']:>3}/{row['scale_ins']:<3}", flush=True)
        if _beats(pair["autoscaled"], pair["fixed"]):
            wins.append(rate)
    print("-" * 86)
    print(f"autoscaled beats fixed on p99 or rejection rate at "
          f"{len(wins)}/{len(rates)} load levels: "
          f"{', '.join(f'{w:.0f} rps' for w in wins) or '—'}")
    assert len(wins) * 3 >= 2 * len(rates), (
        f"autoscaling won only {len(wins)}/{len(rates)} load levels")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
