"""Sustained-load SLO benchmark: error budgets and burn-rate alerting on a
sharded fleet through an injected latency burst.

A rendezvous-sharded fleet (synthetic packed-stump ensembles, as in
``benchmarks/autoscale_load`` — the SLO question is independent of how the
ensembles were trained) serves a steady Poisson stream under the simulated
clock with the analytic batch service-time model ``c0 + c1*n``.  Partway
through the run the service model degrades by ``BURST_FACTOR`` for
``BURST_S`` simulated seconds — an incident.  An :class:`SLOMonitor` with
per-tenant objectives consumes every outcome through the serving stack's
``on_slo`` hook (completions) and the sharded front door (rejections), and
the :class:`FleetAutoscaler` additionally reads the monitor's burn rate as
a pressure signal, so budget burn itself can recruit capacity.

Asserted acceptance:

* at least one burn-rate alert **fires inside the burst window** and every
  alert **resolves after it** — none still active at the end of the run;
* the error-budget **ledger is exact**: per-tenant good/bad totals equal
  the journal (one entry per recorded outcome), and the journal covers
  every request the fleet completed or rejected — nothing sampled,
  nothing double-counted.

With ``--trace-out`` the run executes under tracing and exports the JSONL
trace (``alert.fire`` / ``alert.resolve`` points land in the same stream
as the serving spans); ``--alerts-out`` writes the alert timeline JSON.
The CI obs job runs the quick configuration and stitches the trace.
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

import repro.obs as obs
from repro.obs.slo import SLObjective, SLOMonitor
from repro.serve import (AutoscaleConfig, BatchConfig, FleetAutoscaler,
                         GossipConfig, ShardCluster, ShardedEnsembleServer)

# batch service-time model: fixed dispatch overhead + per-request cost
SERVICE_C0 = 1.2e-3
SERVICE_C1 = 2.0e-4

N_TENANTS = 4
MIN_HOSTS = 2
MAX_HOSTS = 6

# the incident: service time multiplies by BURST_FACTOR over [T0, T0+BURST_S)
BURST_FACTOR = 25.0

# an objective loose enough that the healthy fleet sits well inside it and
# tight enough that the burst violates it outright (c0 * BURST_FACTOR = 30ms)
LATENCY_SLO_S = 0.020
TARGET = 0.95
WINDOW_S = 0.5

BATCH = BatchConfig(queue_budget=64, max_batch=16, target_p99_s=0.01)
AUTOSCALE = AutoscaleConfig(min_hosts=MIN_HOSTS, max_hosts=MAX_HOSTS,
                            target_queue=16.0, target_p99_s=0.05,
                            adapt_every_s=0.02, step_down=0.1)


def build_cluster(n_hosts: int, tenants: Sequence[str], seed: int,
                  T: int = 24, F: int = 16) -> ShardCluster:
    """A converged cluster holding one synthetic stump ensemble per tenant."""
    cluster = ShardCluster(n_hosts, GossipConfig(seed=seed))
    rng = np.random.RandomState(seed)
    for tenant in tenants:
        params = np.zeros((T, 4), np.float32)
        params[:, 0] = rng.randint(0, F, size=T)
        params[:, 1] = rng.randn(T)
        params[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
        alphas = (rng.rand(T) + 0.1).astype(np.float32)
        cluster.publish_packed(tenant, jnp.asarray(params),
                               jnp.asarray(alphas))
    cluster.run_until_quiescent()
    return cluster


def gen_arrivals(tenants: Sequence[str], rate: float, duration_s: float,
                 seed: int, F: int = 16
                 ) -> List[Tuple[float, str, np.ndarray]]:
    """Steady Poisson trace — the *service model* carries the incident, so
    the offered load stays constant and the SLO breach is unambiguous."""
    rng = np.random.RandomState(seed)
    out: List[Tuple[float, str, np.ndarray]] = []
    t = 0.0
    while t < duration_s:
        t += rng.exponential(1.0 / rate)
        if t >= duration_s:
            break
        out.append((t, tenants[rng.randint(len(tenants))],
                    rng.randn(F).astype(np.float32)))
    return out


def run_incident(arrivals, tenants: Sequence[str], duration_s: float,
                 burst_t0: float, burst_s: float, seed: int) -> Dict:
    """One closed-loop run through the incident; returns everything the
    assertions and the report need."""
    # the service model reads the *dispatch-time* clock through this box,
    # so batches dispatched inside the burst window are slow regardless of
    # when their requests arrived — exactly how a real incident behaves
    clock = {"now": 0.0}

    def service_model(n: int) -> float:
        base = SERVICE_C0 + SERVICE_C1 * n
        if burst_t0 <= clock["now"] < burst_t0 + burst_s:
            return base * BURST_FACTOR
        return base

    journal: List[Dict] = []
    monitor = SLOMonitor(
        [SLObjective(tenant=t, latency_threshold_s=LATENCY_SLO_S,
                     target=TARGET, window_s=WINDOW_S) for t in tenants],
        journal=journal)

    cluster = build_cluster(MIN_HOSTS, tenants, seed=seed)
    server = ShardedEnsembleServer(cluster, BATCH,
                                   service_model=service_model)
    server.attach_slo(monitor)
    scaler = FleetAutoscaler(server, AUTOSCALE, slo=monitor)

    fired: List[Dict] = []
    for t, tenant, x in arrivals:
        clock["now"] = t
        server.submit(tenant, x, t)
        scaler.step(t)
        fired.extend(e.to_dict() for e in monitor.check(t))
    clock["now"] = duration_s
    server.drain()
    # let every short window drain past the last recorded outcome so any
    # alert the burst raised has the room to resolve
    t_end = duration_s + WINDOW_S
    fired.extend(e.to_dict() for e in monitor.check(t_end))

    rep = server.report()
    return {"monitor": monitor, "journal": journal, "events": fired,
            "report": rep, "scaler": scaler, "t_end": t_end}


def reconcile(run: Dict) -> None:
    """The exact-ledger assertion: budgets == journal == request log."""
    monitor: SLOMonitor = run["monitor"]
    journal = run["journal"]
    rep = run["report"]
    per_tenant: Dict[str, List[int]] = {}
    for e in journal:
        g, b = per_tenant.setdefault(e["tenant"], [0, 0])
        per_tenant[e["tenant"]] = [g + e["good"], b + (not e["good"])]
    for tenant, budget in monitor.budgets.items():
        jg, jb = per_tenant.get(tenant, [0, 0])
        assert (budget.good_total, budget.bad_total) == (jg, jb), (
            f"ledger drift for {tenant}: budget "
            f"{(budget.good_total, budget.bad_total)} != journal {(jg, jb)}")
    outcomes = rep["completed"] + rep["rejected"]
    assert len(journal) == outcomes, (
        f"journal has {len(journal)} entries but the fleet settled "
        f"{outcomes} requests (completed={rep['completed']} "
        f"rejected={rep['rejected']})")


def main(quick: bool = False, seed: int = 0, trace_out: str = "",
         alerts_out: str = "") -> List[Dict]:
    duration = 2.0 if quick else 4.0
    rate = 400.0 if quick else 600.0
    burst_t0 = duration * 0.4
    burst_s = duration * 0.2
    tenants = [f"tenant-{i}" for i in range(N_TENANTS)]

    print("=" * 86)
    print(f"sustained SLO — {TARGET:.0%} of requests under "
          f"{LATENCY_SLO_S * 1e3:.0f} ms over {WINDOW_S}s windows; "
          f"{BURST_FACTOR:.0f}x latency burst over "
          f"[{burst_t0:.2f}s, {burst_t0 + burst_s:.2f}s)")
    print("=" * 86)

    if trace_out:
        with obs.tracing(ring=1 << 18) as tracer:
            run = run_incident(gen_arrivals(tenants, rate, duration, seed),
                               tenants, duration, burst_t0, burst_s, seed)
            tracer.export_jsonl(trace_out)
        print(f"wrote trace -> {trace_out}")
    else:
        run = run_incident(gen_arrivals(tenants, rate, duration, seed),
                           tenants, duration, burst_t0, burst_s, seed)

    monitor: SLOMonitor = run["monitor"]
    rep = run["report"]
    slo_report = monitor.report(run["t_end"])

    fires = [e for e in run["events"] if e["kind"] == "fire"]
    resolves = [e for e in run["events"] if e["kind"] == "resolve"]
    in_burst = [e for e in fires
                if burst_t0 <= e["t"] < burst_t0 + burst_s + WINDOW_S]

    print(f"{'tenant':<12} {'good':>6} {'bad':>5} {'budget left':>12} "
          f"{'burn(window)':>13}")
    print("-" * 86)
    rows: List[Dict] = []
    for tenant, t_rep in slo_report["tenants"].items():
        print(f"{tenant:<12} {t_rep['good']:>6} {t_rep['bad']:>5} "
              f"{t_rep['budget_remaining']:>11.0%} "
              f"{t_rep['burn_window']:>12.2f}x")
        rows.append(dict(t_rep, tenant=tenant))
    print("-" * 86)
    print(f"fleet: {rep['completed']} completed, {rep['rejected']} rejected, "
          f"p99 {rep['p99_ms']:.2f} ms, "
          f"{run['scaler'].stats.scale_outs} scale-outs")
    for e in run["events"]:
        print(f"  alert {e['kind']:<8} t={e['t']:.3f}s {e['tenant']:<10} "
              f"{e['rule']:<7} burn short/long = "
              f"{e['burn_short']:.1f}/{e['burn_long']:.1f}")

    reconcile(run)
    assert in_burst, (
        f"no burn-rate alert fired inside the burst window "
        f"[{burst_t0:.2f}, {burst_t0 + burst_s:.2f}); fires: {fires}")
    assert len(resolves) == len(fires), (
        f"{len(fires)} fire(s) but {len(resolves)} resolve(s)")
    assert not slo_report["active_alerts"], (
        f"alerts still active at end of run: {slo_report['active_alerts']}")
    print(f"OK: {len(in_burst)} alert(s) fired in the burst window, all "
          f"{len(fires)} resolved; ledger exact over "
          f"{len(run['journal'])} outcomes")

    if alerts_out:
        with open(alerts_out, "w") as f:
            json.dump({"events": run["events"],
                       "tenants": slo_report["tenants"]}, f, indent=2,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote alert timeline -> {alerts_out}")

    rows.append({"tenant": "__fleet__", "completed": rep["completed"],
                 "rejected": rep["rejected"], "p99_ms": rep["p99_ms"],
                 "alerts_fired": len(fires),
                 "alerts_in_burst": len(in_burst),
                 "alerts_resolved": len(resolves)})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--trace-out", default="",
                    help="run under tracing and export the JSONL trace here")
    ap.add_argument("--alerts-out", default="",
                    help="write the alert timeline JSON here")
    args = ap.parse_args()
    main(quick=args.quick, trace_out=args.trace_out,
         alerts_out=args.alerts_out)
