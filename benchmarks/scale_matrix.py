"""Fleet-scale matrix: the 100k-client scenario through the vectorized
fleet profile (repro.core.fleet) — the event core's scale acceptance.

Runs the registered ``*_100k`` scenario(s) end to end (train both modes;
the serve replay is off by scenario design) and records per-cell
wall-clock, simulated-time, communication, and band results.  Asserts the
whole matrix completes inside ``WALL_BUDGET_S`` — the scale-smoke CI job
runs the quick matrix under this budget and archives the BENCH json.

    PYTHONPATH=src python -m benchmarks.scale_matrix            # full
    PYTHONPATH=src python -m benchmarks.scale_matrix --quick    # 1 trace, 2 rounds
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.sim.harness import run_scenario
from repro.sim.scenarios import SCENARIOS, get_scenario

# wall-clock acceptance budget for the whole matrix (seconds)
WALL_BUDGET_S = {"quick": 900.0, "full": 3600.0}


def scale_scenarios() -> List[str]:
    return [n for n in SCENARIOS if n.endswith("_100k")]


def run_cell(name: str, trace: str, seed: int, n_rounds: int) -> Dict:
    sc = get_scenario(name)
    t0 = time.time()
    rep = run_scenario(sc, trace=trace, seed=seed, n_rounds=n_rounds)
    wall = time.time() - t0
    b, e = rep.baseline, rep.enhanced
    return {
        "scenario": name, "trace": trace, "seed": seed,
        "n_clients": sc.domain.n_clients, "n_rounds": n_rounds,
        "wall_s": round(wall, 1),
        "sim_time_baseline_s": b.sim_time_s,
        "sim_time_enhanced_s": e.sim_time_s,
        "learners_merged": e.learners_merged,
        "syncs_enhanced": e.n_syncs,
        "bytes_baseline": b.total_bytes, "bytes_enhanced": e.total_bytes,
        **{k: rep.row[k] for k in ("time_down", "comm_down", "msgs_down",
                                   "acc_delta_pp")},
        "band_failures": rep.band_failures,
        "within_band": rep.within_band,
    }


def main(quick: bool = False, seeds: Optional[Sequence[int]] = None,
         n_rounds: Optional[int] = None) -> List[Dict]:
    names = scale_scenarios()
    rounds = n_rounds if n_rounds is not None else (2 if quick else 4)
    seeds = seeds if seeds is not None else (0,)
    budget = WALL_BUDGET_S["quick" if quick else "full"]

    print("=" * 100)
    print(f"fleet-scale matrix: {', '.join(names)} "
          f"({rounds} rounds, seeds {tuple(seeds)}, "
          f"budget {budget:.0f}s wall)")
    print("=" * 100)
    t0 = time.time()
    results: List[Dict] = []
    for name in names:
        sc = get_scenario(name)
        traces = ["legacy"] if quick else ["legacy"] + sc.nontrivial_traces
        for trace in traces:
            for seed in seeds:
                cell = run_cell(name, trace, seed, rounds)
                results.append(cell)
                print(f"{name:<14} {trace:<10} seed {seed}: "
                      f"wall {cell['wall_s']:7.1f}s  "
                      f"time_down {cell['time_down']:+6.1f}%  "
                      f"comm_down {cell['comm_down']:+6.1f}%  "
                      f"acc {cell['acc_delta_pp']:+5.2f}pp  "
                      + ("WITHIN BAND" if cell["within_band"] else
                         "OUT OF BAND: " + "; ".join(cell["band_failures"])))
    total_wall = time.time() - t0
    print(f"\ntotal wall: {total_wall:.1f}s (budget {budget:.0f}s)")
    assert total_wall <= budget, (
        f"scale matrix blew its wall-clock budget: "
        f"{total_wall:.1f}s > {budget:.0f}s")
    return results


def csv_rows(results: List[Dict]) -> List:
    return [(f"scale_{r['scenario']}_{r['trace']}", r["wall_s"] * 1e6,
             f"time_down={r['time_down']:.1f}%;"
             f"comm_down={r['comm_down']:.1f}%;"
             f"within_band={int(r['within_band'])}")
            for r in results]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
