"""Benchmark harness — one module per paper table/figure plus the roofline
report.  Prints ``name,us_per_call,derived`` CSV lines at the end (harness
convention); the human-readable tables precede them.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --quick    # 1 seed, fewer rounds
    PYTHONPATH=src python -m benchmarks.run backend_matrix serving_load
                                                       # named subset only

Each benchmark additionally persists its raw result as
``BENCH_<name>.json`` under ``--out-dir`` (default ``artifacts/bench``) so
runs are diffable across commits — the perf trajectory.  ``--timestamp``
stamps the files (CI passes the commit SHA); ``--out-dir ''`` disables
the JSON emission entirely.

``--baseline DIR`` turns a run into a trajectory point *and* a
comparison: every fresh result is diffed against ``DIR/BENCH_<name>.json``
(normally the checked-in ``artifacts/bench`` set), a ratio table is
printed, and the process exits non-zero if any benchmark's wall time
regressed past ``--regress-threshold`` (default 3.0x — CI noise on shared
runners is real; the gate is for order-of-magnitude breakage, not
single-digit percent drift).  Benchmarks with no baseline file are
reported as new; baselines recorded under a different ``quick`` config
are compared but never gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time


def _jsonable(x):
    """Best-effort conversion of a benchmark result to JSON-serializable
    plain data: dataclasses -> dicts, numpy scalars/arrays -> python,
    tuples/sets -> lists, anything else unknown -> str."""
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return _jsonable(dataclasses.asdict(x))
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple, set, frozenset)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if hasattr(x, "item") and not hasattr(x, "__len__"):   # numpy scalar
        return _jsonable(x.item())
    if hasattr(x, "tolist"):                               # numpy array
        return _jsonable(x.tolist())
    return str(x)


def write_bench_json(out_dir: str, name: str, result, *, wall_us: float,
                     quick: bool, seeds, n_rounds: int,
                     timestamp: str) -> str:
    """One ``BENCH_<name>.json`` per benchmark: the raw result plus enough
    config to reproduce it.  Returns the path written."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    doc = {
        "name": name,
        "config": {"quick": quick, "seeds": list(seeds),
                   "n_rounds": n_rounds},
        "seeds": list(seeds),
        "wall_us": round(wall_us, 1),
        "metrics": _jsonable(result),
        "timestamp": timestamp,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def compare_to_baseline(baseline_dir: str, fresh: dict, threshold: float
                        ) -> int:
    """Diff fresh ``{name: wall_us-bearing doc}`` results against the
    ``BENCH_<name>.json`` set in ``baseline_dir``; print the trajectory
    table and return the number of gating regressions (fresh wall time
    > ``threshold`` x baseline under a comparable config)."""
    regressions = 0
    print(f"\n--- perf trajectory vs {baseline_dir} "
          f"(gate: >{threshold:g}x wall) ---")
    print(f"{'benchmark':<22} {'baseline_us':>14} {'fresh_us':>14} "
          f"{'ratio':>7}  verdict")
    for name in sorted(fresh):
        doc = fresh[name]
        base_path = os.path.join(baseline_dir, f"BENCH_{name}.json")
        if not os.path.exists(base_path):
            print(f"{name:<22} {'-':>14} {doc['wall_us']:>14.1f} "
                  f"{'-':>7}  new (no baseline)")
            continue
        with open(base_path) as f:
            base = json.load(f)
        base_us = float(base.get("wall_us", 0.0))
        fresh_us = float(doc["wall_us"])
        ratio = fresh_us / base_us if base_us > 0 else float("inf")
        comparable = (base.get("config", {}).get("quick")
                      == doc.get("config", {}).get("quick"))
        if not comparable:
            verdict = "config mismatch (quick differs; not gating)"
        elif ratio > threshold:
            verdict = "REGRESSION"
            regressions += 1
        else:
            verdict = "ok"
        print(f"{name:<22} {base_us:>14.1f} {fresh_us:>14.1f} "
              f"{ratio:>6.2f}x  {verdict}")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out-dir", default="artifacts/bench", metavar="DIR",
                    help="write BENCH_<name>.json result files here "
                         "('' disables; default: artifacts/bench)")
    ap.add_argument("--timestamp", default=None, metavar="TAG",
                    help="stamp for the BENCH json files (e.g. a commit "
                         "SHA; default: current UTC time)")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="diff results against the BENCH_<name>.json set "
                         "in DIR and exit non-zero on wall-time "
                         "regressions past --regress-threshold")
    ap.add_argument("--regress-threshold", type=float, default=3.0,
                    metavar="X",
                    help="gating wall-time ratio for --baseline "
                         "(default: 3.0)")
    ap.add_argument("only", nargs="*", metavar="BENCH",
                    help="run only the named benchmarks (default: all)")
    args = ap.parse_args()
    seeds = (0,) if args.quick else (0, 1, 2)
    n_rounds = 20 if args.quick else 30

    from benchmarks import (autoscale_load, backend_matrix,
                            controller_compare, domains, fedavg_compare,
                            kernel_bench, multipod_compare, relevance_filter,
                            roofline, scale_matrix, scenario_matrix,
                            scheduler_ablation, serving_load, shard_gossip,
                            staleness, sustained_slo)

    # the single benchmark registry: name -> thunk, in run order
    benches = {
        # Table 1 (the paper's main quantitative claim)
        "table1_domains": lambda: domains.main(n_rounds=n_rounds,
                                               seeds=seeds),
        # scenario registry: domains x behavior traces, train -> serve
        # (picks its own seed count: 2-seed means for the band checks)
        "scenario_matrix": lambda: scenario_matrix.main(quick=args.quick),
        # scheduling-rule ablation (paper eq. 1)
        "scheduler_ablation": scheduler_ablation.main,
        # staleness compensation sweep (paper eq. 2)
        "staleness_sweep": staleness.main,
        # FL baselines comparison (paper's framing vs FedAvg/FedAsync)
        "fedavg_compare": fedavg_compare.main,
        # beyond-paper: relevance-filtered buffers + alt controllers
        "relevance_filter": relevance_filter.main,
        "controller_compare": controller_compare.main,
        # roofline report from the dry-run artifacts (§Roofline)
        "roofline_report": roofline.main,
        # single- vs multi-pod scaling census
        "multipod_compare": multipod_compare.main,
        # serving: adaptive micro-batch window vs fixed, closed-loop load
        "serving_load": lambda: serving_load.main(quick=args.quick),
        # sharded registry: gossip convergence + result-cache p99 A/B
        "shard_gossip": lambda: shard_gossip.main(quick=args.quick),
        # fleet autoscaling: eq.-(1) pressure controller vs fixed fleet
        "autoscale_load": lambda: autoscale_load.main(quick=args.quick),
        # SLO error budgets + burn-rate alerting through a latency burst
        "sustained_slo": lambda: sustained_slo.main(quick=args.quick),
        # kernel x backend x shape-bucket wall-clock + calibration table
        "backend_matrix": lambda: backend_matrix.main(quick=args.quick),
        # 100k-client fleet-scale smoke through the vectorized fleet profile
        "scale_matrix": lambda: scale_matrix.main(quick=args.quick),
        # per-kernel microbench rows (not wall-timed by the harness)
        "kernel_bench": kernel_bench.rows,
    }
    unknown = sorted(set(args.only) - set(benches))
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"choose from {', '.join(benches)}")

    stamp = args.timestamp or time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                            time.gmtime())
    csv_rows = []
    results = {}
    written = []
    fresh_docs = {}
    for name, fn in benches.items():
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        results[name] = fn()
        wall_us = (time.time() - t0) * 1e6
        if name != "kernel_bench":        # kernel_bench emits its own CSV
            csv_rows.append((name, wall_us, "bench-wall"))
        fresh_docs[name] = {"wall_us": round(wall_us, 1),
                            "config": {"quick": args.quick}}
        if args.out_dir:
            written.append(write_bench_json(
                args.out_dir, name, results[name], wall_us=wall_us,
                quick=args.quick, seeds=seeds, n_rounds=n_rounds,
                timestamp=stamp))

    print("\n--- kernel microbench + harness CSV ---")
    csv_rows.extend(results.get("kernel_bench", []))
    for d in results.get("table1_domains", []):
        csv_rows.append((
            f"table1_{d['domain']}", 0.0,
            f"time_down={d['time_down']:.1f}%;comm_down={d['comm_down']:.1f}%;"
            f"conv_down={d['conv_down']:.1f}%;acc_delta={d['acc_delta_pp']:+.1f}pp"))
    for r in results.get("serving_load", []):
        csv_rows.append((
            f"serve_{r['policy']}_{r['rate']:.0f}rps", 0.0,
            f"thr={r['throughput_rps']:.0f}rps;p50={r['p50_ms']:.2f}ms;"
            f"p99={r['p99_ms']:.2f}ms;batch={r['mean_batch']:.1f};"
            f"rej={r['rejected']}"))
    for r in results.get("shard_gossip", []):
        csv_rows.append((
            f"shard_{r['mode']}_{r['rate']:.0f}rps", 0.0,
            f"p99={r['p99_ms']:.2f}ms;hit={r['hit_rate']:.2f};"
            f"identical={int(r['identical_predictions'])};"
            f"lag={r['mean_lag_rounds']:.1f}r"))
    for r in results.get("sustained_slo", []):
        if r.get("tenant") == "__fleet__":
            csv_rows.append((
                "sustained_slo_fleet", 0.0,
                f"p99={r['p99_ms']:.2f}ms;fired={r['alerts_fired']};"
                f"in_burst={r['alerts_in_burst']};"
                f"resolved={r['alerts_resolved']};rej={r['rejected']}"))
    for r in results.get("autoscale_load", []):
        csv_rows.append((
            f"autoscale_{r['fleet']}_{r['rate']:.0f}rps", 0.0,
            f"p99={r['p99_ms']:.2f}ms;rej={100 * r['rej_rate']:.1f}%;"
            f"hosts={r['hosts_final']};out={r['scale_outs']};"
            f"in={r['scale_ins']};rerouted={r['rerouted']}"))
    csv_rows.extend(results.get("backend_matrix", []))
    csv_rows.extend(scenario_matrix.csv_rows(
        results.get("scenario_matrix", [])))
    csv_rows.extend(scale_matrix.csv_rows(
        results.get("scale_matrix", [])))
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    if written:
        print(f"\nwrote {len(written)} BENCH json file(s) "
              f"[{stamp}]: {', '.join(written)}")
    if args.baseline:
        regressions = compare_to_baseline(args.baseline, fresh_docs,
                                          args.regress_threshold)
        if regressions:
            print(f"{regressions} benchmark(s) regressed past "
                  f"{args.regress_threshold:g}x — failing the run")
            sys.exit(1)


if __name__ == "__main__":
    main()
