"""Benchmark harness — one module per paper table/figure plus the roofline
report.  Prints ``name,us_per_call,derived`` CSV lines at the end (harness
convention); the human-readable tables precede them.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --quick    # 1 seed, fewer rounds
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    seeds = (0,) if args.quick else (0, 1, 2)
    n_rounds = 20 if args.quick else 30

    csv_rows = []

    def timed(name, fn):
        t0 = time.time()
        out = fn()
        csv_rows.append((name, (time.time() - t0) * 1e6, "bench-wall"))
        return out

    from benchmarks import (controller_compare, domains, fedavg_compare,
                            kernel_bench, multipod_compare, relevance_filter,
                            roofline, scheduler_ablation, serving_load,
                            shard_gossip, staleness)

    # Table 1 (the paper's main quantitative claim)
    tab1 = timed("table1_domains",
                 lambda: domains.main(n_rounds=n_rounds, seeds=seeds))
    # scheduling-rule ablation (paper eq. 1)
    timed("scheduler_ablation", scheduler_ablation.main)
    # staleness compensation sweep (paper eq. 2)
    timed("staleness_sweep", staleness.main)
    # FL baselines comparison (paper's framing vs FedAvg/FedAsync)
    timed("fedavg_compare", fedavg_compare.main)
    # beyond-paper: relevance-filtered buffers + alternative controllers
    timed("relevance_filter", relevance_filter.main)
    timed("controller_compare", controller_compare.main)
    # roofline report from the dry-run artifacts (§Roofline)
    timed("roofline_report", roofline.main)
    # single- vs multi-pod scaling census
    timed("multipod_compare", multipod_compare.main)
    # serving: adaptive micro-batch window vs fixed under closed-loop load
    serve_rows = timed("serving_load",
                       lambda: serving_load.main(quick=args.quick))
    # sharded registry: gossip convergence + result-cache p99 A/B
    shard_rows = timed("shard_gossip",
                       lambda: shard_gossip.main(quick=args.quick))

    print("\n--- kernel microbench + harness CSV ---")
    for name, us, derived in kernel_bench.rows():
        csv_rows.append((name, us, derived))
    for d in tab1:
        csv_rows.append((
            f"table1_{d['domain']}", 0.0,
            f"time_down={d['time_down']:.1f}%;comm_down={d['comm_down']:.1f}%;"
            f"conv_down={d['conv_down']:.1f}%;acc_delta={d['acc_delta_pp']:+.1f}pp"))
    for r in serve_rows:
        csv_rows.append((
            f"serve_{r['policy']}_{r['rate']:.0f}rps", 0.0,
            f"thr={r['throughput_rps']:.0f}rps;p50={r['p50_ms']:.2f}ms;"
            f"p99={r['p99_ms']:.2f}ms;batch={r['mean_batch']:.1f};"
            f"rej={r['rejected']}"))
    for r in shard_rows:
        csv_rows.append((
            f"shard_{r['mode']}_{r['rate']:.0f}rps", 0.0,
            f"p99={r['p99_ms']:.2f}ms;hit={r['hit_rate']:.2f};"
            f"identical={int(r['identical_predictions'])};"
            f"lag={r['mean_lag_rounds']:.1f}r"))
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
