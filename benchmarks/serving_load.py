"""Closed-loop serving load benchmark: adaptive micro-batch window vs fixed
windows, swept over arrival rates on the paper's domain workloads.

Ensembles are trained with the async engine on several of the five domains
(publishing snapshots into the registry mid-training, exactly the serving
hand-off path), then a bursty Poisson request stream is replayed against
:class:`~repro.serve.service.EnsembleServer` under a simulated clock with an
analytic batch service-time model ``c0 + c1*n`` (dispatch overhead + per-
request cost — the regime where micro-batching pays).

For every arrival rate the same trace runs under three batching policies:

* ``adaptive``   — the eq.-(1) controller on the negated-p99 signal
* ``fixed-1ms``  — minimum-latency fixed window (batch size ~1 at low load)
* ``fixed-8ms``  — throughput-oriented fixed window

and the table reports throughput, p50/p99 latency, mean batch size, and
rejected (backpressured) requests.  The acceptance check: the adaptive
window beats each fixed window on p99 (at comparable completed traffic) at
two or more rates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.data import make_domain_data
from repro.serve import BatchConfig, EnsembleRegistry, EnsembleServer

# batch service-time model: fixed dispatch overhead + per-request cost
SERVICE_C0 = 1.2e-3
SERVICE_C1 = 2.0e-4


def service_model(n: int) -> float:
    return SERVICE_C0 + SERVICE_C1 * n


def build_registry(domains: Sequence[str], n_rounds: int, seed: int
                   ) -> Tuple[EnsembleRegistry, Dict[str, np.ndarray]]:
    """Train one ensemble per domain, publishing mid-training; returns the
    registry plus per-tenant feature pools (test sets) for request traffic."""
    registry = EnsembleRegistry()
    pools: Dict[str, np.ndarray] = {}
    for name in domains:
        dom = dataclasses.replace(DOMAINS[name],
                                  n_samples=min(DOMAINS[name].n_samples, 1500),
                                  n_clients=min(DOMAINS[name].n_clients, 6))
        data = make_domain_data(dom, seed=seed)
        cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=n_rounds,
                             straggler_factor=dom.straggler_factor,
                             dropout_prob=dom.dropout_prob, seed=seed,
                             balanced_init=dom.label_imbalance < 0.4)
        eng = FederatedBoostEngine(cfg, data, "enhanced")
        eng.attach_registry(registry, name)
        eng.run()
        pools[name] = np.asarray(data["test"][0], np.float32)
    # training and serving run on different simulated clocks: restamp the
    # latest snapshots onto the serving epoch so staleness reads correctly
    registry.rebase_clock(0.0)
    return registry, pools


def gen_arrivals(tenants: Sequence[str], pools: Dict[str, np.ndarray],
                 rate: float, duration_s: float, seed: int,
                 burst_factor: float = 3.0, burst_period_s: float = 0.5
                 ) -> List[Tuple[float, str, np.ndarray]]:
    """Bursty Poisson trace around a nominal ``rate``: each half period the
    instantaneous rate alternates between ``rate*burst_factor`` (on-phase)
    and ``rate*0.1`` (off-phase), so the batcher sees genuine load swings."""
    rng = np.random.RandomState(seed)
    lo = 0.1
    out: List[Tuple[float, str, np.ndarray]] = []
    t = 0.0
    while t < duration_s:
        phase_on = (t % burst_period_s) < 0.5 * burst_period_s
        lam = rate * (burst_factor if phase_on else lo)
        t += rng.exponential(1.0 / max(lam, 1e-9))
        if t >= duration_s:
            break
        tenant = tenants[rng.randint(len(tenants))]
        pool = pools[tenant]
        out.append((t, tenant, pool[rng.randint(pool.shape[0])]))
    return out


def run_policy(registry: EnsembleRegistry, arrivals, cfg: BatchConfig
               ) -> Dict:
    server = EnsembleServer(registry, cfg, service_model=service_model)
    for t, tenant, x in arrivals:
        server.submit(tenant, x, t)
    server.drain()
    rep = server.metrics.report()
    rep["window_units_final"] = server.window.units
    return rep


def policies() -> Dict[str, BatchConfig]:
    return {
        "adaptive": BatchConfig(adaptive=True),
        "fixed-1ms": BatchConfig(adaptive=False, fixed_window_units=1),
        "fixed-8ms": BatchConfig(adaptive=False, fixed_window_units=8),
    }


def main(quick: bool = False, domains=("edge_vision", "iot", "healthcare"),
         seed: int = 0) -> List[Dict]:
    n_rounds = 8 if quick else 12
    duration = 2.0 if quick else 4.0
    rates = (120.0, 1500.0) if quick else (60.0, 400.0, 1500.0)

    print("=" * 86)
    print("serving load — adaptive micro-batch window vs fixed "
          f"(domains: {', '.join(domains)})")
    print("=" * 86)
    registry, pools = build_registry(domains, n_rounds=n_rounds, seed=seed)
    for name in registry.tenants():
        s = registry.latest(name)
        print(f"  tenant {name:<12} v{s.version:<3} {s.n_learners} learners "
              f"(published mid-training, {registry.version_count(name)} versions)")

    hdr = (f"{'rate':>6} {'policy':<10} {'done':>6} {'rej':>5} {'thr rps':>8} "
           f"{'p50 ms':>7} {'p99 ms':>7} {'batch':>6}")
    print(hdr)
    print("-" * 86)
    rows: List[Dict] = []
    by_rate: Dict[float, Dict[str, Dict]] = {}
    for rate in rates:
        arrivals = gen_arrivals(list(domains), pools, rate, duration, seed)
        for pname, cfg in policies().items():
            rep = run_policy(registry, arrivals, cfg)
            rep.update(rate=rate, policy=pname)
            rows.append(rep)
            by_rate.setdefault(rate, {})[pname] = rep
            print(f"{rate:>6.0f} {pname:<10} {rep['completed']:>6} "
                  f"{rep['rejected']:>5} {rep['throughput_rps']:>8.0f} "
                  f"{rep['p50_ms']:>7.2f} {rep['p99_ms']:>7.2f} "
                  f"{rep['mean_batch']:>6.1f}", flush=True)
    print("-" * 86)

    for fixed in ("fixed-1ms", "fixed-8ms"):
        wins = [r for r in rates if _beats(by_rate[r]["adaptive"],
                                           by_rate[r][fixed])]
        print(f"adaptive beats {fixed} on p99 at comparable traffic at "
              f"{len(wins)}/{len(rates)} rates: "
              f"{', '.join(f'{w:.0f} rps' for w in wins) or '—'}")
    return rows


def _beats(adaptive: Dict, fixed: Dict) -> bool:
    """Adaptive wins a rate when p99 improves without giving up traffic."""
    comparable = adaptive["completed"] >= 0.98 * fixed["completed"]
    return comparable and adaptive["p99_ms"] < 0.95 * fixed["p99_ms"]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
