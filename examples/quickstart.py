"""Quickstart: enhanced asynchronous AdaBoost federated learning in ~40
lines, using the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.core.metrics import common_target, pct_reduction, time_to_error
from repro.data import make_domain_data

# 1. a federated environment: 12 edge cameras, non-IID data, stragglers
dom = DOMAINS["edge_vision"]
data = make_domain_data(dom, seed=0)

# 2. the paper's algorithm (adaptive scheduling + delayed compensation)
#    vs synchronous distributed AdaBoost
cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=25,
                     straggler_factor=dom.straggler_factor,
                     dropout_prob=dom.dropout_prob, link_mbps=dom.link_mbps)
baseline = FederatedBoostEngine(cfg, data, "baseline").run()
enhanced = FederatedBoostEngine(cfg, data, "enhanced").run()

# 3. the paper's metrics
tgt = common_target([baseline.val_error_curve, enhanced.val_error_curve])
tb = time_to_error(baseline.val_error_curve, tgt)
te = time_to_error(enhanced.val_error_curve, tgt)

print(f"target error {tgt:.3f}")
print(f"  baseline: {baseline.total_bytes:>8d} B on wire, "
      f"{baseline.n_messages} msgs, hit target at t={tb[0]:.1f}s "
      f"({tb[1]} learners), test err {baseline.final_test_error:.3f}")
print(f"  enhanced: {enhanced.total_bytes:>8d} B on wire, "
      f"{enhanced.n_messages} msgs, hit target at t={te[0]:.1f}s "
      f"({te[1]} learners), test err {enhanced.final_test_error:.3f}")
print(f"  -> comm reduction {pct_reduction(baseline.total_bytes, enhanced.total_bytes):.0f}%, "
      f"time-to-target reduction {pct_reduction(tb[0], te[0]):.0f}%, "
      f"accuracy delta {100*(baseline.final_test_error - enhanced.final_test_error):+.1f}pp")
