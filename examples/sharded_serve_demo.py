"""Minimal sharded-serving walkthrough: rendezvous ownership, gossip
replication, result caching, and failover — no training, synthetic stump
ensembles only, runs in seconds.

    PYTHONPATH=src python examples/sharded_serve_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro.serve import (BatchConfig, GossipConfig, ShardCluster,
                         ShardedEnsembleServer)

F = 8          # feature dim
TENANTS = ["vision", "iot", "finance"]


def publish_version(cluster, tenant, T, clock, progress, seed):
    rng = np.random.RandomState(seed)
    params = np.zeros((T, 4), np.float32)
    params[:, 0] = rng.randint(0, F, size=T)
    params[:, 1] = rng.randn(T)
    params[:, 2] = np.where(rng.rand(T) > 0.5, 1.0, -1.0)
    alphas = (rng.rand(T) + 0.1).astype(np.float32)
    return cluster.publish_packed(tenant, jnp.asarray(params),
                                  jnp.asarray(alphas), clock=clock,
                                  train_progress=progress)


def main():
    cluster = ShardCluster(3, GossipConfig(seed=0))
    print("rendezvous ownership:")
    for t in TENANTS:
        print(f"  {t:<8} -> {cluster.owner(t)}")

    # two published versions per tenant; publishes land on the owner only
    for v in range(2):
        for i, t in enumerate(TENANTS):
            publish_version(cluster, t, T=4 + v, clock=float(v),
                            progress=6 * (v + 1), seed=10 * v + i)
    rounds = cluster.run_until_quiescent(now=2.0)
    print(f"\ngossip: converged in {rounds} round(s) "
          f"({cluster.stats.pulled} snapshots pulled); every host now "
          f"serves every tenant's v2")

    server = ShardedEnsembleServer(
        cluster, BatchConfig(cache_capacity=512),
        service_model=lambda n: 1e-3 + 2e-4 * n)
    rng = np.random.RandomState(42)
    hot = rng.randn(4, F).astype(np.float32)    # a few hot feature vectors
    responses = []
    for i in range(60):
        t = TENANTS[i % 3]
        _, done = server.submit(t, hot[i % 4], now=2.0 + 1e-3 * i)
        responses += done
    responses += server.drain()
    stats = server.cache_stats()
    print(f"\nserved {len(responses)} requests; cache hit rate "
          f"{stats['hit_rate']:.0%} ({stats['hits']} hits / "
          f"{stats['fills']} kernel fills)")

    # failover: kill the owner of 'vision'; its gossiped replica serves on
    owner = cluster.owner("vision")
    cluster.mark_down(owner)
    backup = cluster.route("vision").host_id
    _, _ = server.submit("vision", hot[0], now=3.0)
    (resp,) = server.drain()
    print(f"\nfailover: {owner} down -> vision served by {backup}, "
          f"still snapshot v{resp.snapshot_version} "
          f"(margin {resp.margin:+.3f})")

    # a fresh publish routes to the new owner and invalidates stale cache
    snap = publish_version(cluster, "vision", T=7, clock=3.5, progress=20,
                           seed=99)
    print(f"new publish while {owner} down -> v{snap.version} owned by "
          f"{cluster.owner('vision')}; cache invalidated "
          f"{server.cache_stats()['invalidated']} stale entries")


if __name__ == "__main__":
    main()
