"""The paper's technique as a first-class DISTRIBUTED feature: federated
async boosting compiled into a single pjit/shard_map step over a device
mesh — adaptive interval, buffers, compensation and the sync collective all
inside jit (DESIGN.md §3-4).

Run standalone (it forks no subprocess; it sets the placeholder-device flag
itself, so run it in a fresh interpreter):

    PYTHONPATH=src python examples/fed_mesh_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import fed_mesh
from repro.data import make_domain_data
from repro.models.weak import stump_thresholds

K = 8   # one federated client per device along the mesh's client axis
dom = dataclasses.replace(DOMAINS["edge_vision"], n_clients=K)
data = make_domain_data(dom, seed=0)

# pack client shards into stacked arrays (K, n, F) / (K, n)
n_local = min(c[0].shape[0] for c in data["clients"])
x = jnp.stack([c[0][:n_local] for c in data["clients"]])
y = jnp.stack([c[1][:n_local] for c in data["clients"]])
xv_full, yv_full = data["val"]
nvl = xv_full.shape[0] // K
xv = xv_full[:K * nvl].reshape(K, nvl, -1)
yv = yv_full[:K * nvl].reshape(K, nvl)

mesh = jax.make_mesh((K,), ("clients",))
cfg = FedBoostConfig(n_clients=K)
thresholds = stump_thresholds(x.reshape(-1, x.shape[-1]))
step = fed_mesh.make_fed_boost_step(cfg, mesh, "clients", thresholds)
state = fed_mesh.init_state(cfg, K, n_local, nvl, buffer_cap=8,
                            ens_cap=2048, key=jax.random.key(0))

shardings = jax.tree.map(
    lambda s: NamedSharding(mesh, s),
    fed_mesh.state_shardings(mesh, "clients"),
    is_leaf=lambda v: isinstance(v, P))
dsh = NamedSharding(mesh, P("clients"))
state = jax.device_put(state, shardings)
x, y, xv, yv = (jax.device_put(a, dsh) for a in (x, y, xv, yv))

jstep = jax.jit(step, donate_argnums=0)
print(f"{K} clients on a {mesh.devices.shape} mesh; "
      f"sync = all_gather of the stump buffers over the client axis\n")
print(f"{'round':>6} {'interval':>9} {'syncs':>6} {'ensemble':>9} {'val_err':>8}")
for r in range(48):
    state = jstep(state, x, y, xv, yv)
    if (r + 1) % 8 == 0:
        print(f"{r+1:>6} {float(state.interval):>9.1f} "
              f"{int(state.sync_count):>6} {int(state.ens_count):>9} "
              f"{float(state.prev_err):>8.3f}")
print("\nThe interval widened in-graph (lax.cond-gated collective) while the"
      "\nensemble error fell — the paper's scheduling on SPMD hardware.")
