"""Minimal serving demo: train a federated ensemble on one paper domain,
publish snapshots mid-training, and answer prediction traffic through the
adaptive micro-batching server.

    PYTHONPATH=src python examples/serve_ensemble_demo.py
"""
import dataclasses

import numpy as np

from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.data import make_domain_data
from repro.serve import BatchConfig, EnsembleRegistry, EnsembleServer


def main() -> None:
    # 1. train, publishing a snapshot into the registry at every sync
    registry = EnsembleRegistry()
    dom = dataclasses.replace(DOMAINS["iot"], n_samples=1200, n_clients=6)
    data = make_domain_data(dom, seed=0)
    cfg = FedBoostConfig(n_clients=6, n_rounds=10, seed=0, balanced_init=True)
    engine = FederatedBoostEngine(cfg, data, "enhanced")
    engine.attach_registry(registry, "iot")
    engine.run()
    snap = registry.latest("iot")
    print(f"published {registry.version_count('iot')} snapshot versions; "
          f"serving v{snap.version} with {snap.n_learners} learners")
    registry.rebase_clock(0.0)

    # 2. serve a small burst through the adaptive micro-batcher
    server = EnsembleServer(registry, BatchConfig(max_batch=16),
                            service_model=lambda n: 1e-3 + 1e-4 * n)
    xt, yt = np.asarray(data["test"][0]), np.asarray(data["test"][1])
    responses = []
    for i in range(128):
        _accepted, done = server.submit("iot", xt[i], now=i * 5e-4)
        responses += done
    responses += server.drain()

    correct = sum(r.label == yt[r.rid] for r in responses)
    rep = server.metrics.report()
    print(f"served {rep['completed']} requests in {rep['n_batches']} "
          f"micro-batches (mean batch {rep['mean_batch']:.1f})")
    print(f"latency p50 {rep['p50_ms']:.2f} ms, p99 {rep['p99_ms']:.2f} ms; "
          f"accuracy {correct / len(responses):.3f}")


if __name__ == "__main__":
    main()
