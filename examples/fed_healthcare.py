"""Federated healthcare diagnostics (paper domain 5): six hospitals with
imbalanced diagnostic labels train a shared classifier without sharing
patient data.  Compares the paper's enhanced async AdaBoost against the
synchronous boosting baseline AND against FedAvg — showing the comm and
robustness profile the paper claims for this domain.

The domain definition, paper band, and behavior traces come from the
scenario registry (repro.sim.scenarios); pass ``--trace maintenance`` to
run the hospitals through correlated maintenance-window outages instead
of the legacy scalar model.

    PYTHONPATH=src python examples/fed_healthcare.py [--trace maintenance]
"""
import argparse

from repro.core import FederatedBoostEngine
from repro.core.federated import run_fedavg
from repro.core.metrics import pct_reduction
from repro.sim.scenarios import get_scenario

sc = get_scenario("healthcare")
ap = argparse.ArgumentParser()
ap.add_argument("--trace", default="legacy", choices=sorted(sc.traces))
trace = ap.parse_args().trace
dom = sc.domain
data = sc.make_data(seed=0)
print(f"{dom.n_clients} hospitals, {dom.n_samples} records, "
      f"positive rate {dom.label_imbalance:.0%} (imbalanced), "
      f"uplink {dom.link_mbps} Mb/s, behavior trace: {trace}\n")

cfg = sc.fedboost_config(seed=0, n_rounds=30)

runs = {
    "sync AdaBoost (baseline)": FederatedBoostEngine(
        cfg, data, "baseline", behavior_for=sc.behavior_for(trace)).run(),
    "async AdaBoost (paper)": FederatedBoostEngine(
        cfg, data, "enhanced", behavior_for=sc.behavior_for(trace)).run(),
}
avg = run_fedavg(data, n_rounds=30, link_mbps=dom.link_mbps,
                 straggler_factor=dom.straggler_factor)

print(f"{'method':<26} {'bytes':>10} {'msgs':>6} {'test_err':>9} {'recall':>7}")
for name, m in runs.items():
    print(f"{name:<26} {m.total_bytes:>10} {m.n_messages:>6} "
          f"{m.final_test_error:>9.3f} {m.final_test_recall:>7.3f}")
print(f"{'FedAvg (weights on wire)':<26} {avg.total_bytes:>10} "
      f"{avg.n_messages:>6} {avg.final_test_error:>9.3f} {'':>7}")

b = runs["sync AdaBoost (baseline)"]
e = runs["async AdaBoost (paper)"]
band = sc.band
print(f"\npaper band check (healthcare): comm down "
      f"{pct_reduction(b.total_bytes, e.total_bytes):.0f}% "
      f"(paper: ~{band.comm_down[0]:.0f}-{band.comm_down[1]:.0f}%), "
      f"accuracy delta "
      f"{100*(b.final_test_error - e.final_test_error):+.1f}pp "
      f"(paper: {band.acc_delta_pp[0]:+.0f}-{band.acc_delta_pp[1]:+.0f}pp "
      f"under class imbalance)")
