"""Federated healthcare diagnostics (paper domain 5): six hospitals with
imbalanced diagnostic labels train a shared classifier without sharing
patient data.  Compares the paper's enhanced async AdaBoost against the
synchronous boosting baseline AND against FedAvg — showing the comm and
robustness profile the paper claims for this domain.

    PYTHONPATH=src python examples/fed_healthcare.py
"""
from repro.configs.paper_fedboost import DOMAINS, FedBoostConfig
from repro.core import FederatedBoostEngine
from repro.core.federated import run_fedavg
from repro.core.metrics import pct_reduction
from repro.data import make_domain_data

dom = DOMAINS["healthcare"]
data = make_domain_data(dom, seed=0)
print(f"{dom.n_clients} hospitals, {dom.n_samples} records, "
      f"positive rate {dom.label_imbalance:.0%} (imbalanced), "
      f"uplink {dom.link_mbps} Mb/s\n")

cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=30,
                     straggler_factor=dom.straggler_factor,
                     dropout_prob=dom.dropout_prob, link_mbps=dom.link_mbps)

runs = {
    "sync AdaBoost (baseline)": FederatedBoostEngine(cfg, data, "baseline").run(),
    "async AdaBoost (paper)": FederatedBoostEngine(cfg, data, "enhanced").run(),
}
avg = run_fedavg(data, n_rounds=30, link_mbps=dom.link_mbps,
                 straggler_factor=dom.straggler_factor)

print(f"{'method':<26} {'bytes':>10} {'msgs':>6} {'test_err':>9} {'recall':>7}")
for name, m in runs.items():
    print(f"{name:<26} {m.total_bytes:>10} {m.n_messages:>6} "
          f"{m.final_test_error:>9.3f} {m.final_test_recall:>7.3f}")
print(f"{'FedAvg (weights on wire)':<26} {avg.total_bytes:>10} "
      f"{avg.n_messages:>6} {avg.final_test_error:>9.3f} {'':>7}")

b = runs["sync AdaBoost (baseline)"]
e = runs["async AdaBoost (paper)"]
print(f"\npaper band check (healthcare): comm down "
      f"{pct_reduction(b.total_bytes, e.total_bytes):.0f}% "
      f"(paper: ~20-30%), accuracy delta "
      f"{100*(b.final_test_error - e.final_test_error):+.1f}pp "
      f"(paper: +1-2pp under class imbalance)")
