"""Batched serving demo: prefill a batch of prompts, then decode with KV /
SSM-state caches — across three different architecture families (dense
sliding-window, MoE, attention-free SSM) through the same API.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.launch.serve import generate
from repro.models import Model

for arch in ("gemma2-27b", "qwen3-moe-30b-a3b", "mamba2-1.3b"):
    cfg = reduced(get_arch(arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, T0, NEW = 4, 16, 12
    prompts = jax.random.randint(jax.random.key(1), (B, T0), 0,
                                 cfg.vocab_size, jnp.int32)
    frames = (jnp.zeros((B, cfg.encoder_seq, cfg.d_model))
              if cfg.is_encoder_decoder else None)
    t0 = time.time()
    seqs = generate(model, params, prompts, NEW, cache_len=T0 + NEW,
                    frames=frames, temperature=0.8)
    dt = time.time() - t0
    assert seqs.shape == (B, T0 + NEW)
    print(f"{cfg.name:<28} ({cfg.family:<6}) {B}x{NEW} tokens in {dt:5.1f}s "
          f"-> {B*NEW/dt:6.1f} tok/s   sample: "
          f"{jax.numpy.asarray(seqs[0, T0:T0+6]).tolist()}")
