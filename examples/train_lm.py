"""End-to-end LM training driver over the assigned architectures.

Default (CPU-scale): a ~10M-parameter reduced qwen-family model for a few
hundred steps on the synthetic Markov stream — loss is asserted to drop,
checkpoints written and resumable.  ``--full`` selects the real config
(qwen1.5-0.5b, ~100M-class activations at batch 8 x 512) — the same code
path a TPU run takes; on this CPU container expect it to be slow.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-1.3b --steps 200
"""
import argparse
import tempfile

import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="train the full config (TPU-scale; slow on CPU)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg, n_layers=4, d_model=256, vocab=2048)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    with tempfile.TemporaryDirectory() as ckpt:
        _, losses = train_loop(
            cfg, steps=args.steps, batch=8, seq=128, lr=3e-3,
            ckpt_dir=ckpt, ckpt_every=max(args.steps // 4, 1), log_every=20)
    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first - 0.2 else 'WARN: flat'})")


if __name__ == "__main__":
    main()
