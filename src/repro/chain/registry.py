"""ChainRegistry: an EnsembleRegistry-shaped view over the chain of record.

One instance is one *node*'s local view.  ``publish``/``publish_packed``
cut the ensemble delta since the node's last submission into per-client
:class:`~repro.chain.core.ChainCommit`s and queue them on the shared
:class:`~repro.chain.core.Chain`; every read (``latest``/``get``/
``digest``/...) first folds any newly confirmed blocks into the local
view.  The fold is a pure function of the confirmed prefix, so every node
— including one created *after* the publisher died — reconstructs
bit-identical :class:`EnsembleSnapshot`s with identical version stamps and
fingerprints: there is no central registry instance to lose.

``provenance(tenant, version)`` answers which client updates entered a
served version — the ``(cid, round, block_hash)`` triple per merged
learner — from chain history alone.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.chain.core import Block, Chain, ChainCommit
from repro.serve.registry import (EnsembleRegistry, EnsembleSnapshot,
                                  pack_stumps)


class _TenantFold:
    """Accumulated confirmed state for one tenant: the growing ensemble
    plus the per-entry provenance ledger."""

    def __init__(self):
        self.rows: List[Tuple[float, ...]] = []       # packed stump rows
        self.learners: List = []                      # generic pytrees
        self.alphas: List[float] = []
        self.weak_name = "stump"
        self.train_progress = 0
        self.versions = 0
        self.provenance: List[Tuple[int, int, str]] = []
        self.version_entries: Dict[int, int] = {}     # version -> prefix len


class ChainRegistry:
    """Quacks as :class:`~repro.serve.registry.EnsembleRegistry` (publish,
    publish_packed, latest, get, history, ingest, digest, subscribe,
    staleness, rebase_clock) while sourcing every snapshot from the
    chain's confirmed prefix."""

    def __init__(self, chain: Optional[Chain] = None, *,
                 node_id: str = "node-0", history: int = 4,
                 participant: bool = True):
        self.chain = chain or Chain()
        self.node_id = node_id
        self.participant = bool(participant)
        if self.participant:
            self.chain.join(node_id)
        self._view = EnsembleRegistry(history=history)
        self._folds: Dict[str, _TenantFold] = {}
        self._next_height = 1             # first unfolded block height
        self._submitted: Dict[str, int] = {}   # tenant -> entries committed

    # ------------------------------------------------------------- publish
    def publish(self, tenant: str, learners: Sequence,
                alphas: Sequence[float], *, clock: float = 0.0,
                train_progress: int = 0, weak_name: str = "stump",
                owners: Optional[Sequence[int]] = None,
                rounds: Optional[Sequence[int]] = None
                ) -> Optional[EnsembleSnapshot]:
        """Commit the delta since this node's last submission, one commit
        per contiguous owner run (clients commit their own deltas), then
        sync.  Returns the latest *confirmed* snapshot — possibly a stale
        version or None while the delta waits for inclusion: chain mode
        really does serve only confirmed state."""
        learners = list(learners)
        alphas = [float(a) for a in alphas]
        if len(learners) != len(alphas):
            raise ValueError(
                f"publish({tenant!r}): {len(learners)} learners vs "
                f"{len(alphas)} alphas — refusing a mismatched commit")
        base = self._submitted.get(tenant, 0)
        if len(learners) < base:
            raise ValueError(
                f"publish({tenant!r}): ensemble shrank below the "
                f"{base} entries already committed on chain")
        rows = (pack_stumps(learners) if weak_name == "stump" else None)
        for lo, hi in _owner_runs(owners, base, len(learners)):
            self._submit(tenant, ChainCommit(
                tenant=tenant,
                cid=int(owners[lo]) if owners is not None else -1,
                seq=self.chain.next_seq(),
                rounds=tuple(int(rounds[i]) for i in range(lo, hi)
                             ) if rounds is not None else (0,) * (hi - lo),
                alphas=tuple(alphas[lo:hi]),
                stump_rows=(tuple(map(tuple, np.asarray(rows[lo:hi])))
                            if rows is not None else None),
                learners=(tuple(learners[lo:hi]) if rows is None else ()),
                weak_name=weak_name,
                train_progress=int(train_progress),
                submitted_at=float(clock)), clock)
        self._submitted[tenant] = len(learners)
        self.sync(clock)
        return self._view.latest(tenant)

    def publish_packed(self, tenant: str, stump_params, alphas, *,
                       clock: float = 0.0, train_progress: int = 0,
                       owners: Optional[Sequence[int]] = None,
                       rounds: Optional[Sequence[int]] = None
                       ) -> Optional[EnsembleSnapshot]:
        """Commit a packed ``(T, 4)`` stump delta (the fed_mesh wire
        format) — same delta/commit semantics as :meth:`publish`."""
        rows = np.asarray(stump_params, np.float32)
        alphas = [float(a) for a in np.asarray(alphas, np.float32)]
        assert rows.shape == (len(alphas), 4), (rows.shape, len(alphas))
        base = self._submitted.get(tenant, 0)
        if len(alphas) < base:
            raise ValueError(
                f"publish_packed({tenant!r}): ensemble shrank below the "
                f"{base} entries already committed on chain")
        for lo, hi in _owner_runs(owners, base, len(alphas)):
            self._submit(tenant, ChainCommit(
                tenant=tenant,
                cid=int(owners[lo]) if owners is not None else -1,
                seq=self.chain.next_seq(),
                rounds=tuple(int(rounds[i]) for i in range(lo, hi)
                             ) if rounds is not None else (0,) * (hi - lo),
                alphas=tuple(alphas[lo:hi]),
                stump_rows=tuple(map(tuple, rows[lo:hi])),
                train_progress=int(train_progress),
                submitted_at=float(clock)), clock)
        self._submitted[tenant] = len(alphas)
        self.sync(clock)
        return self._view.latest(tenant)

    def _submit(self, tenant: str, commit: ChainCommit, clock: float
                ) -> None:
        with obs.span("chain.commit", sim_t=clock, host=self.node_id,
                      tenant=tenant, cid=commit.cid,
                      n_entries=commit.n_entries,
                      node=self.node_id) as sp:
            if obs.enabled():
                # the commit carries this span's context onto the chain, so
                # the mint event and every node's fold link back to it —
                # ctx is outside the fingerprint, hashes are unchanged
                commit = dataclasses.replace(commit, ctx=sp.ctx)
            wait = self.chain.submit(commit, float(clock))
            sp.set(confirm_wait_s=wait, seq=commit.seq)
            sp.end_sim(clock + wait)
        obs.count("chain.commits")

    # ---------------------------------------------------------------- sync
    def sync(self, now: Optional[float] = None) -> int:
        """Fold newly confirmed blocks into the local view.  ``now``
        advances the shared chain clock first (mints due blocks); None
        only folds what other nodes already minted.  Returns the number
        of snapshots ingested — every read path calls this, so a node's
        view is always a pure function of the confirmed prefix."""
        if now is not None:
            self.chain.advance(float(now))
        blocks = [b for b in self.chain.confirmed_blocks()
                  if b.height >= self._next_height]
        if not blocks:
            return 0
        ingested = 0
        t0 = blocks[0].mined_at
        links = [c.ctx for b in blocks for c in b.commits
                 if c.ctx is not None] if obs.enabled() else None
        with obs.span("chain.aggregate", sim_t=t0, host=self.node_id,
                      link=links, node=self.node_id, blocks=len(blocks),
                      leader=self.chain.leader() or "") as sp:
            for b in blocks:
                ingested += self._fold_block(b)
                self._next_height = b.height + 1
            sp.set(snapshots=ingested)
            sp.end_sim(blocks[-1].mined_at)
        obs.count("chain.aggregates", ingested)
        return ingested

    def _fold_block(self, block: Block) -> int:
        """Fold one confirmed block: all commits for a tenant in one block
        aggregate into one new snapshot version (the committee's
        deterministic aggregation step)."""
        touched: Dict[str, _TenantFold] = {}
        for c in block.commits:
            fold = self._folds.setdefault(c.tenant, _TenantFold())
            fold.weak_name = c.weak_name
            fold.train_progress = max(fold.train_progress,
                                      c.train_progress)
            if c.stump_rows is not None:
                fold.rows.extend(c.stump_rows)
            fold.learners.extend(c.learners)
            fold.alphas.extend(c.alphas)
            fold.provenance.extend(
                (c.cid, r, block.hash) for r in c.rounds)
            touched[c.tenant] = fold
        for tenant, fold in touched.items():
            fold.versions += 1
            fold.version_entries[fold.versions] = len(fold.alphas)
            snap = EnsembleSnapshot(
                tenant=tenant, version=fold.versions,
                published_at=float(block.mined_at),
                train_progress=int(fold.train_progress),
                weak_name=fold.weak_name,
                alphas=jnp.asarray(fold.alphas, jnp.float32),
                stump_params=(jnp.asarray(fold.rows, jnp.float32)
                              if fold.weak_name == "stump" else None),
                learners=tuple(fold.learners))
            self._view.ingest(snap)
        return len(touched)

    # ---------------------------------------------------------- provenance
    def provenance(self, tenant: str, version: Optional[int] = None
                   ) -> Tuple[Tuple[int, int, str], ...]:
        """The ``(cid, round, block_hash)`` lineage of every learner in
        ``version`` (default: the latest), oldest first — answered from
        chain history alone."""
        self.sync()
        fold = self._folds.get(tenant)
        if fold is None:
            return ()
        if version is None:
            version = fold.versions
        n = fold.version_entries.get(int(version))
        if n is None:
            raise KeyError(
                f"no confirmed version {version} for tenant {tenant!r} "
                f"(chain holds 1..{fold.versions})")
        return tuple(fold.provenance[:n])

    # ------------------------------------------------------ registry quack
    def latest(self, tenant: str) -> Optional[EnsembleSnapshot]:
        self.sync()
        return self._view.latest(tenant)

    def get(self, tenant: str, version: Optional[int] = None
            ) -> Optional[EnsembleSnapshot]:
        self.sync()
        return self._view.get(tenant, version)

    def history(self, tenant: str) -> List[EnsembleSnapshot]:
        self.sync()
        return self._view.history(tenant)

    def tenants(self) -> List[str]:
        self.sync()
        return self._view.tenants()

    def version_count(self, tenant: str) -> int:
        self.sync()
        return self._view.version_count(tenant)

    def staleness(self, tenant: str, now: float) -> float:
        self.sync()
        return self._view.staleness(tenant, now)

    def digest(self) -> Dict[str, Tuple[int, str]]:
        self.sync()
        return self._view.digest()

    def ingest(self, snap: EnsembleSnapshot) -> bool:
        # interface compat (a chain node may be warmed from a plain
        # registry's window); the chain fold supersedes anything ingested
        return self._view.ingest(snap)

    def replace_latest(self, tenant: str, snap: EnsembleSnapshot
                       ) -> EnsembleSnapshot:
        return self._view.replace_latest(tenant, snap)

    def subscribe(self, fn):
        return self._view.subscribe(fn)

    def rebase_clock(self, clock: float = 0.0) -> None:
        self._view.rebase_clock(clock)

    def close(self) -> None:
        """This node leaves the committee (crash or drain); its view dies
        with it — the chain keeps every byte needed to rebuild."""
        if self.participant:
            self.chain.leave(self.node_id)


def _owner_runs(owners: Optional[Sequence[int]], base: int, end: int
                ) -> List[Tuple[int, int]]:
    """Split ``[base, end)`` into contiguous same-owner runs (one commit
    per run keeps per-client attribution without reordering entries)."""
    if base >= end:
        return []
    if owners is None:
        return [(base, end)]
    runs = []
    lo = base
    for i in range(base + 1, end):
        if owners[i] != owners[lo]:
            runs.append((lo, i))
            lo = i
    runs.append((lo, end))
    return runs
