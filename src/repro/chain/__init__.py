# repro.chain — the server-less FLchain mode (arXiv:2112.07938): client
# model deltas commit to a hash-linked chain whose confirmation times come
# from the BlockchainLedger slot model; a rotating rendezvous committee
# stamps blocks; every serving node folds the confirmed prefix into
# bit-identical EnsembleSnapshots.  ChainRegistry quacks as the central
# EnsembleRegistry so the training/publish hooks and the sharded serving
# fleet run unchanged — minus the single point of failure.
from repro.chain.core import (  # noqa: F401
    Block, Chain, ChainCommit, GENESIS_HASH, block_hash)
from repro.chain.registry import ChainRegistry  # noqa: F401
from repro.chain.cluster import ChainCluster  # noqa: F401
