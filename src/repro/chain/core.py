"""Hash-linked chain of committed model deltas: the FLchain record.

The blockchain scenarios used to treat the chain as a *delay model* —
:class:`~repro.sim.behavior.BlockchainLedger` priced a commit's inclusion
wait and the payload still landed in a central registry.  Here the ledger
becomes load-bearing (the server-less design of arXiv:2112.07938): every
publish is cut into per-client :class:`ChainCommit` deltas (stump rows +
vote weights + ``cid``/round metadata), each commit reserves a slot on the
*shared* ledger and confirms when its block is mined, and the serving
ensemble is a pure fold over the confirmed prefix — any node replaying the
chain from genesis reconstructs byte-identical snapshots, so there is no
registry instance whose death loses state.

Three structural guarantees the property suite pins:

* **hash-link integrity** — every block's ``prev_hash`` is its parent's
  content hash (same blake2b construction as the snapshot fingerprint);
  mutating any commit breaks every descendant link.
* **deterministic replay** — block hashes are a pure function of the
  (height, parent, mined_at, commits) sequence: re-minting the recorded
  sequence from genesis reproduces the hash chain exactly.
* **confirmed-prefix monotonicity** — with ``reorg_prob > 0`` only the
  *unconfirmed tip* can be orphaned (its commits re-mint into the next
  block), so the confirmed prefix only ever extends.

A rotating committee (rendezvous rank over the joined participants,
reusing :func:`repro.serve.shard.rendezvous_rank`) selects the miner that
stamps each block; mining is deterministic given the commit sequence, so
the leader dying mid-run only rotates the stamp — the fold is unchanged.
"""
from __future__ import annotations

import functools
import hashlib
import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.serve.shard import rendezvous_rank
from repro.sim.behavior import BlockchainLedger

GENESIS_HASH = "0" * 24        # blake2b(digest_size=12) hexdigest width


@dataclass(frozen=True)
class ChainCommit:
    """One client's model delta as committed on chain.

    ``stump_params`` carries the packed ``(k, 4)`` stump rows (the fed_mesh
    wire format); non-stump families ship their parameter pytrees in
    ``learners`` instead.  ``rounds`` are the client-local boosting rounds
    the entries were trained at — together with ``cid`` this is the
    provenance record ``provenance(tenant, version)`` answers from.
    """
    tenant: str
    cid: int                          # committing client (-1 = host/mesh)
    seq: int                          # global submission sequence number
    rounds: Tuple[int, ...]           # client-local round per entry
    alphas: Tuple[float, ...]         # compensated vote weights per entry
    stump_rows: Optional[Tuple[Tuple[float, ...], ...]] = None
    learners: Tuple = ()              # generic params pytrees (non-stump)
    weak_name: str = "stump"
    train_progress: int = 0           # publisher's merged count at submit
    submitted_at: float = 0.0         # publisher clock at submission
    # trace context of the publishing node's chain.commit span.  Pure
    # observability metadata: excluded from equality and — critically —
    # from :attr:`fingerprint`, so traced and untraced replays mint
    # bit-identical hash chains.
    ctx: Optional[object] = field(default=None, compare=False, repr=False)

    @property
    def n_entries(self) -> int:
        return len(self.alphas)

    @functools.cached_property
    def fingerprint(self) -> str:
        """Content digest — the same blake2b construction as
        :attr:`EnsembleSnapshot.fingerprint`, extended with the commit
        identity (tenant/cid/seq/rounds) so two clients committing equal
        deltas still hash apart."""
        h = hashlib.blake2b(digest_size=12)
        h.update(self.tenant.encode())
        h.update(np.int64(self.cid).tobytes())
        h.update(np.int64(self.seq).tobytes())
        h.update(np.asarray(self.rounds, np.int64).tobytes())
        h.update(self.weak_name.encode())
        h.update(np.int64(self.train_progress).tobytes())
        h.update(np.asarray(self.alphas, np.float32).tobytes())
        if self.stump_rows is not None:
            h.update(np.asarray(self.stump_rows, np.float32).tobytes())
        for leaf in _tree_leaves(self.learners):
            h.update(np.ascontiguousarray(leaf).tobytes())
        return h.hexdigest()


def _tree_leaves(learners) -> List:
    if not learners:
        return []
    import jax
    return jax.tree_util.tree_leaves(learners)


def block_hash(height: int, prev_hash: str, mined_at: float,
               commits: Sequence[ChainCommit]) -> str:
    """Content hash of one block — a pure function of (height, parent,
    mined time, commit fingerprints), so replaying the recorded sequence
    from genesis reproduces the chain bit for bit.  The miner stamp is
    deliberately *outside* the hash: committee membership at replay time
    (who re-mints) must not change what was recorded."""
    h = hashlib.blake2b(digest_size=12)
    h.update(np.int64(height).tobytes())
    h.update(prev_hash.encode())
    h.update(np.float64(mined_at).tobytes())
    for c in commits:
        h.update(c.fingerprint.encode())
    return h.hexdigest()


@dataclass(frozen=True)
class Block:
    """One mined block: hash-linked to its parent, carrying the commits
    confirmed at ``mined_at``."""
    height: int
    prev_hash: str
    mined_at: float
    commits: Tuple[ChainCommit, ...] = ()
    miner: str = ""                   # committee leader at mint (metadata)

    @functools.cached_property
    def hash(self) -> str:
        return block_hash(self.height, self.prev_hash, self.mined_at,
                          self.commits)


class Chain:
    """The shared chain of record.

    Commits queue on the :class:`BlockchainLedger` slot model — the same
    capacity serialization the behavior layer prices — and mint in
    confirmation order when :meth:`advance` moves the chain clock.  With
    ``reorg_prob > 0`` a freshly due block may orphan the unconfirmed tip
    (depth-1 fork): the tip's commits re-mint into the new block, nothing
    is lost, and the confirmed prefix (everything except the tip) only
    extends.  :meth:`finalize` settles the tail once training ends.
    """

    def __init__(self, ledger: Optional[BlockchainLedger] = None, *,
                 confirmations: int = 2, reorg_prob: float = 0.0,
                 committee_size: int = 3, epoch_blocks: int = 4,
                 seed: int = 0):
        self.ledger = ledger or BlockchainLedger(
            np.random.RandomState(seed * 7919 + 977))
        self.confirmations = int(confirmations)
        self.reorg_prob = float(reorg_prob)
        self.committee_size = int(committee_size)
        self.epoch_blocks = max(1, int(epoch_blocks))
        self._rng = np.random.RandomState(seed * 7919 + 978)
        self.blocks: List[Block] = [Block(0, GENESIS_HASH, 0.0)]
        self._pending: List[Tuple[float, int, ChainCommit]] = []
        self._seq = 0
        self._finalized = False
        self._participants: Dict[str, None] = {}   # ordered set
        self.reorgs = 0

    # -------------------------------------------------------- participants
    def join(self, node_id: str) -> None:
        self._participants[node_id] = None

    def leave(self, node_id: str) -> None:
        self._participants.pop(node_id, None)

    def participants(self) -> List[str]:
        return list(self._participants)

    def committee(self, height: Optional[int] = None) -> List[str]:
        """The aggregation committee for the epoch containing ``height``
        (default: the next block to be mined) — rendezvous rank over the
        joined participants, rotating every ``epoch_blocks`` blocks."""
        if not self._participants:
            return []
        h = self.height if height is None else int(height)
        epoch = h // self.epoch_blocks
        ranked = rendezvous_rank(f"committee|{epoch}", self._participants)
        return ranked[:self.committee_size]

    def leader(self, height: Optional[int] = None) -> Optional[str]:
        com = self.committee(height)
        return com[0] if com else None

    # ------------------------------------------------------------- commits
    def submit(self, commit: ChainCommit, t: float) -> float:
        """Queue a commit at publisher time ``t``: reserve the next free
        ledger slot (commits serialize on chain capacity) and wait the
        configured confirmation depth.  Returns the seconds until the
        commit is confirmed."""
        wait = (self.ledger.commit(t, cursor=self._cursor())
                + (self.confirmations - 1) * self.ledger.block_interval_s)
        heapq.heappush(self._pending, (t + wait, commit.seq, commit))
        obs.count("chain.pending")
        return wait

    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _cursor(self):
        # the chain registers one ledger cursor lazily: submission times
        # from the event-driven engine are non-decreasing, which lets a
        # shared ledger prune slots the chain can no longer collide with
        cur = getattr(self, "_cursor_id", None)
        if cur is None:
            cur = self._cursor_id = self.ledger.register()
        return cur

    # -------------------------------------------------------------- mining
    @property
    def height(self) -> int:
        return self.blocks[-1].height

    def advance(self, now: float) -> List[Block]:
        """Mint every block whose confirmation time has passed, in
        confirmation order; returns the newly minted blocks."""
        minted: List[Block] = []
        while self._pending and self._pending[0][0] <= now:
            due, _, commit = heapq.heappop(self._pending)
            minted.append(self._mint(due, (commit,)))
        return minted

    def finalize(self) -> List[Block]:
        """Settle the chain: mint everything still pending at its recorded
        confirmation time (training is over; the mempool drains without
        further forks) and confirm the tip — after this the confirmed
        prefix is the whole chain."""
        self._finalized = True
        return self.advance(float("inf"))

    def _mint(self, mined_at: float, commits: Tuple[ChainCommit, ...]
              ) -> Block:
        parent = self.blocks[-1]
        if (self.reorg_prob > 0.0 and parent.height > 0
                and not self._finalized
                and self._rng.rand() < self.reorg_prob):
            # depth-1 fork: orphan the unconfirmed tip; its commits ride
            # along in the replacing block, so no delta is ever lost and
            # the confirmed prefix (blocks[:-1]) is untouched
            orphan = self.blocks.pop()
            commits = orphan.commits + commits
            parent = self.blocks[-1]
            self.reorgs += 1
            obs.count("chain.reorgs")
            if obs.enabled():
                obs.point("chain.reorg", sim_t0=mined_at, sim_t1=mined_at,
                          orphaned=orphan.hash, height=orphan.height,
                          commits=len(orphan.commits))
        block = Block(parent.height + 1, parent.hash, float(mined_at),
                      commits, miner=self.leader(parent.height + 1) or "")
        self.blocks.append(block)
        obs.count("chain.blocks")
        if obs.enabled():
            # the mint event links every included commit's publish trace:
            # commit -> mint -> registry fold stitches into one tree
            obs.point("chain.mint", sim_t0=mined_at, sim_t1=mined_at,
                      host=block.miner,
                      link=[c.ctx for c in commits if c.ctx is not None],
                      height=block.height, block=block.hash,
                      commits=len(commits))
        return block

    # ------------------------------------------------------------ reading
    @property
    def tail_depth(self) -> int:
        """Blocks held back from the confirmed prefix: with forks possible
        the tip is not final until a descendant (or finalize) lands."""
        return 0 if (self._finalized or self.reorg_prob == 0.0) else 1

    def confirmed_blocks(self) -> List[Block]:
        """The confirmed prefix (genesis excluded), oldest first."""
        end = len(self.blocks) - self.tail_depth
        return self.blocks[1:max(1, end)]

    def confirmed_hashes(self) -> List[str]:
        return [b.hash for b in self.confirmed_blocks()]

    # ---------------------------------------------------------- integrity
    def verify(self) -> bool:
        """Hash-link integrity of the whole chain: contiguous heights and
        every ``prev_hash`` equal to the parent's content hash."""
        if self.blocks[0].prev_hash != GENESIS_HASH:
            return False
        for i in range(1, len(self.blocks)):
            b, parent = self.blocks[i], self.blocks[i - 1]
            if b.height != parent.height + 1 or b.prev_hash != parent.hash:
                return False
        return True

    def replay_hashes(self) -> List[str]:
        """Re-mint the recorded (mined_at, commits) sequence from genesis
        with fresh :class:`Block` objects and return the resulting hash
        chain — deterministic replay means it equals the live chain's."""
        prev = self.blocks[0].hash      # the genesis block's content hash
        out = []
        for i, b in enumerate(self.blocks[1:], start=1):
            fresh = Block(i, prev, b.mined_at, b.commits)
            out.append(fresh.hash)
            prev = fresh.hash
        return out
