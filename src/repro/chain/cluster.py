"""ChainCluster: a serving fleet whose hosts sync from the chain of
record instead of gossiping registry windows.

Drop-in for :class:`~repro.serve.shard.ShardCluster` (the harness and
:class:`~repro.serve.service.ShardedEnsembleServer` drive it unchanged),
with the central-registry assumptions removed:

* ``publish``/``publish_packed`` do not route to an owning host — the
  trainer commits deltas straight to the shared :class:`Chain` through a
  non-voting committer node, so no host is a publish target that can be
  lost.
* a "gossip round" is each up host folding newly confirmed blocks; hosts
  agree by construction (the fold is deterministic), so the cluster
  converges in one round.
* ``add_host`` warms a brand-new node entirely from chain history —
  including the total-loss case where every previous host died.
* ``kill`` models an abrupt host death: the node leaves the committee
  (rotating the leader) and routing skips it; nothing is handed off
  because nothing needs to be.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.chain.core import Chain
from repro.chain.registry import ChainRegistry
from repro.serve.registry import EnsembleSnapshot
from repro.serve.shard import GossipConfig, ShardCluster, ShardHost
from repro.sim.behavior import BlockchainLedger


class ChainCluster(ShardCluster):
    """N chain-backed hosts; quacks as a ShardCluster + registry."""

    def __init__(self, n_hosts: int = 3, cfg: Optional[GossipConfig] = None,
                 host_ids: Optional[Sequence[str]] = None, *,
                 chain: Optional[Chain] = None,
                 block_interval_s: float = 0.05,
                 confirmations: int = 2, reorg_prob: float = 0.0,
                 committee_size: int = 3):
        cfg = cfg or GossipConfig()
        self.chain = chain or Chain(
            BlockchainLedger(np.random.RandomState(cfg.seed * 7919 + 977),
                             block_interval_s=block_interval_s),
            confirmations=confirmations, reorg_prob=reorg_prob,
            committee_size=committee_size, seed=cfg.seed)
        self._clock_epoch: Optional[float] = None
        super().__init__(n_hosts, cfg, host_ids)
        # the training side commits through a non-voting node: it holds no
        # state the chain cannot rebuild, and it never joins the committee
        self._committer = ChainRegistry(self.chain, node_id="trainer",
                                        history=self.cfg.history,
                                        participant=False)

    def _make_registry(self, host_id: str) -> ChainRegistry:
        return ChainRegistry(self.chain, node_id=host_id,
                             history=self.cfg.history)

    # ------------------------------------- registry facade (training side)
    def publish(self, tenant: str, learners, alphas, **kw
                ) -> Optional[EnsembleSnapshot]:
        snap = self._committer.publish(tenant, learners, alphas, **kw)
        self._sync_up_hosts()
        return snap

    def publish_packed(self, tenant: str, stump_params, alphas, **kw
                       ) -> Optional[EnsembleSnapshot]:
        snap = self._committer.publish_packed(tenant, stump_params, alphas,
                                              **kw)
        self._sync_up_hosts()
        return snap

    def provenance(self, tenant: str, version: Optional[int] = None
                   ) -> Tuple[Tuple[int, int, str], ...]:
        """Lineage of a served version, answerable from any node (they all
        fold the same confirmed prefix)."""
        host = self.route(tenant)
        node = host.registry if host is not None else self._committer
        return node.provenance(tenant, version)

    def _sync_up_hosts(self) -> int:
        pulled = 0
        for h in self.hosts.values():
            if h.up:
                pulled += h.registry.sync()
        return pulled

    # -------------------------------------------------------------- gossip
    def gossip_round(self, now: float = 0.0):
        """Chain-mode anti-entropy: every up host folds the blocks the
        chain confirmed by ``now``.  One round always converges."""
        up = self.host_ids()
        self.stats.rounds += 1
        with obs.span("gossip.round", sim_t=now, hosts=len(up)) as sp:
            self.chain.advance(float(now))
            pulled = self._sync_up_hosts()
            self.stats.pulled += pulled
            self.stats.exchanges += len(up)
            sp.set(pulled=pulled, reconciled=0)
            sp.end_sim(now)
        obs.count("gossip.rounds")
        obs.count("gossip.pulled", pulled)
        return self.stats

    def run_until_quiescent(self, now: float = 0.0, max_rounds: int = 64
                            ) -> int:
        """Settle the chain (mint everything pending at its recorded
        confirmation time) and fold it everywhere."""
        self.chain.finalize()
        self.gossip_round(now)
        return 1

    # ------------------------------------------------- elastic membership
    def add_host(self, host_id: str, now: float = 0.0) -> ShardHost:
        """Scale-out: the new node warms from chain history alone — no
        peer needed, even after a total fleet loss."""
        if host_id in self.hosts:
            raise ValueError(f"host {host_id!r} already in cluster")
        host = ShardHost(host_id, self._make_registry(host_id), up=False)
        self.hosts[host_id] = host
        host.registry.sync(now)
        if self._clock_epoch is not None:
            host.registry.rebase_clock(self._clock_epoch)
        host.up = True
        return host

    def remove_host(self, host_id: str, now: float = 0.0) -> None:
        """Remove a host permanently.  No survivor handoff: the chain is
        the durable copy, so even the last host may leave."""
        victim = self.hosts.pop(host_id)
        victim.up = False
        victim.registry.close()

    def kill(self, host_id: str) -> None:
        """Abrupt host death (no drain): routing skips it immediately and
        the committee rotates past it — aggregation, being a deterministic
        fold, continues identically under the next leader."""
        self.mark_down(host_id)
        self.hosts[host_id].registry.close()
        obs.count("chain.host_kills")

    def leader(self) -> Optional[str]:
        """The current committee leader (the node that stamps the next
        block) — the harness kills exactly this host mid-replay."""
        return self.chain.leader()

    def rebase_clock(self, clock: float = 0.0) -> None:
        self._sync_up_hosts()
        self._clock_epoch = float(clock)
        for h in self.hosts.values():
            h.registry.rebase_clock(clock)
        self._committer.rebase_clock(clock)
