"""Non-IID client partitioners for federated experiments."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(x: np.ndarray, y: np.ndarray, n_clients: int,
                        alpha: float, rng: np.random.RandomState
                        ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Label-skew partition: for each class, client shares ~ Dir(alpha).
    Lower alpha -> more skew.  Every client gets >= 8 samples (top-up from a
    shuffled pool so stumps always have something to fit)."""
    classes = np.unique(y)
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            client_idx[cid].extend(part.tolist())
    pool = rng.permutation(len(y)).tolist()
    for cid in range(n_clients):
        while len(client_idx[cid]) < 8:
            client_idx[cid].append(pool.pop())
    out = []
    for cid in range(n_clients):
        sel = np.asarray(client_idx[cid])
        out.append((x[sel], y[sel]))
    return out


def label_shard_partition(x: np.ndarray, y: np.ndarray, n_clients: int,
                          shards_per_client: int,
                          rng: np.random.RandomState
                          ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """McMahan-style pathological split: sort by label, deal out shards."""
    order = np.argsort(y, kind="stable")
    shards = np.array_split(order, n_clients * shards_per_client)
    rng.shuffle(shards)
    out = []
    for cid in range(n_clients):
        sel = np.concatenate(shards[cid * shards_per_client:
                                    (cid + 1) * shards_per_client])
        out.append((x[sel], y[sel]))
    return out


def iid_partition(x: np.ndarray, y: np.ndarray, n_clients: int,
                  rng: np.random.RandomState
                  ) -> List[Tuple[np.ndarray, np.ndarray]]:
    idx = rng.permutation(len(y))
    out = []
    for part in np.array_split(idx, n_clients):
        out.append((x[part], y[part]))
    return out
