"""Non-IID client partitioners for federated experiments."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def dirichlet_partition(x: np.ndarray, y: np.ndarray, n_clients: int,
                        alpha: float, rng: np.random.RandomState
                        ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Label-skew partition: for each class, client shares ~ Dir(alpha).
    Lower alpha -> more skew.  Every client gets >= 8 samples (top-up from a
    shuffled pool so stumps always have something to fit)."""
    classes = np.unique(y)
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            client_idx[cid].extend(part.tolist())
    # top up only with indices the client does not already hold — a client
    # must never see the same sample twice (it would double that sample's
    # boosting-distribution mass); cross-client overlap from topping up is
    # fine and unavoidable.  The floor is min(8, n): with fewer than 8
    # distinct samples in the whole dataset 8 distinct ones don't exist.
    floor = min(8, len(y))
    pool = rng.permutation(len(y)).tolist()
    for cid in range(n_clients):
        have = set(client_idx[cid])
        while len(client_idx[cid]) < floor:
            if not pool:
                pool = rng.permutation(len(y)).tolist()
            cand = pool.pop()
            if cand in have:
                continue
            client_idx[cid].append(cand)
            have.add(cand)
    out = []
    for cid in range(n_clients):
        sel = np.asarray(client_idx[cid])
        out.append((x[sel], y[sel]))
    return out


def label_shard_partition(x: np.ndarray, y: np.ndarray, n_clients: int,
                          shards_per_client: int,
                          rng: np.random.RandomState
                          ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """McMahan-style pathological split: sort by label, deal out shards."""
    order = np.argsort(y, kind="stable")
    shards = np.array_split(order, n_clients * shards_per_client)
    rng.shuffle(shards)
    out = []
    for cid in range(n_clients):
        sel = np.concatenate(shards[cid * shards_per_client:
                                    (cid + 1) * shards_per_client])
        out.append((x[sel], y[sel]))
    return out


def iid_partition(x: np.ndarray, y: np.ndarray, n_clients: int,
                  rng: np.random.RandomState
                  ) -> List[Tuple[np.ndarray, np.ndarray]]:
    idx = rng.permutation(len(y))
    out = []
    for part in np.array_split(idx, n_clients):
        out.append((x[part], y[part]))
    return out
