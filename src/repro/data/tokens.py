"""Synthetic token pipeline for LM training examples/benchmarks.

Generates a first-order Markov token stream with a low-entropy transition
structure, so a model that trains correctly shows a clearly decreasing loss
(unlike uniform-random tokens whose loss floor is log V).  Deterministic
per seed; streaming batch iterator with optional sharding placement.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class MarkovTokens:
    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        rng = np.random.RandomState(seed)
        self.vocab = vocab
        # each token transitions to one of `branching` successors
        self.next_tokens = rng.randint(0, vocab, size=(vocab, branching))
        self.rng = rng

    def stream(self, n: int) -> np.ndarray:
        out = np.empty(n + 1, np.int32)
        out[0] = self.rng.randint(self.vocab)
        choices = self.rng.randint(0, self.next_tokens.shape[1], size=n)
        for i in range(n):
            out[i + 1] = self.next_tokens[out[i], choices[i]]
        return out

    def batches(self, batch: int, seq: int, n_steps: int
                ) -> Iterator[Dict[str, np.ndarray]]:
        for _ in range(n_steps):
            toks = np.stack([self.stream(seq) for _ in range(batch)])
            yield {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}


def lm_batches(vocab: int, batch: int, seq: int, n_steps: int,
               seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    return MarkovTokens(vocab, seed).batches(batch, seq, n_steps)
