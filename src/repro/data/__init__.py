from repro.data.synthetic import make_domain_data  # noqa: F401
from repro.data.partition import (  # noqa: F401
    dirichlet_partition, label_shard_partition, iid_partition)
