"""Sharding-aware batching helpers: place host numpy batches onto the mesh
with the right PartitionSpec (batch over data/pod axes)."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def batch_sharding(mesh, batch_axes=("data",)) -> NamedSharding:
    return NamedSharding(mesh, P(batch_axes))


def place(batch: Dict[str, np.ndarray], mesh=None,
          batch_axes=("data",)) -> Dict[str, jnp.ndarray]:
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    sh = batch_sharding(mesh, batch_axes)
    return {k: jax.device_put(jnp.asarray(v), sh) for k, v in batch.items()}


def sharded_iterator(it: Iterator[Dict[str, np.ndarray]], mesh=None,
                     batch_axes=("data",)) -> Iterator[Dict[str, jnp.ndarray]]:
    for b in it:
        yield place(b, mesh, batch_axes)
