"""Synthetic dataset generators for the paper's five application domains.

The paper publishes no datasets; each generator below produces a binary
classification problem whose statistical character matches the published
description of its domain (feature count, class balance, noise level,
non-IID client skew).  All generators are deterministic given a seed.

Labels are in {-1,+1}.  Features are float32 (N,F).
"""
from __future__ import annotations

import zlib
from typing import Dict, Tuple

import numpy as np

from repro.configs.paper_fedboost import DomainConfig
from repro.data.partition import (
    dirichlet_partition, iid_partition, label_shard_partition)


def _base_problem(rng: np.random.RandomState, n: int, f: int,
                  pos_frac: float, noise: float,
                  n_clusters: int = 6) -> Tuple[np.ndarray, np.ndarray]:
    """Cluster-structured binary problem: each cluster has a class bias;
    decision surface is non-linear (union of clusters), which stumps can
    only fit as an ensemble — the regime AdaBoost is designed for."""
    centers = rng.randn(n_clusters, f) * 2.0
    cluster_label = np.where(
        rng.rand(n_clusters) < pos_frac, 1.0, -1.0)
    # guarantee both classes exist
    cluster_label[0], cluster_label[1] = 1.0, -1.0
    assign = rng.randint(0, n_clusters, size=n)
    x = centers[assign] + rng.randn(n, f)
    y = cluster_label[assign].copy()
    flip = rng.rand(n) < noise
    y[flip] *= -1.0
    return x.astype(np.float32), y.astype(np.float32)


def make_domain_data(cfg: DomainConfig, seed: int = 0,
                     val_frac: float = 0.15, test_frac: float = 0.15,
                     partitioner: str = "dirichlet",
                     shards_per_client: int = 2,
                     as_numpy: bool = False) -> Dict:
    """Returns {"clients": [(x,y)...], "val": (x,y), "test": (x,y)}.

    ``partitioner`` selects the client split (scenario registry binding):
    ``dirichlet`` (default, skew from ``cfg.noniid_alpha``), ``iid``, or
    ``label_shard`` (McMahan-style pathological split with
    ``shards_per_client`` shards per client).

    ``as_numpy=True`` keeps every array as numpy — the fleet-profile
    engine stacks shards itself, and converting 100k+ client shards to
    individual device arrays would cost one dispatch each."""
    # stable across processes (python's hash() is salted per-interpreter)
    name_tag = zlib.crc32(cfg.name.encode()) % 997
    rng = np.random.RandomState(seed * 1000 + name_tag)
    x, y = _base_problem(rng, cfg.n_samples, cfg.n_features,
                         cfg.label_imbalance, cfg.noise)

    # domain flavour adjustments
    if cfg.name == "iot":
        # sensor drift: add a per-feature slow bias (distribution shift)
        x += np.linspace(0, 0.5, cfg.n_features)[None, :]
    if cfg.name == "healthcare":
        # rare positives with higher-dimensional signal overlap
        pos = y > 0
        x[pos] += rng.randn(int(pos.sum()), cfg.n_features) * 0.3
    if cfg.name == "mobile":
        # sparse activations (next-word-ish features)
        mask = rng.rand(*x.shape) < 0.5
        x = np.where(mask, x, 0.0).astype(np.float32)

    n = x.shape[0]
    idx = rng.permutation(n)
    n_val, n_test = int(n * val_frac), int(n * test_frac)
    val_idx, test_idx, train_idx = (
        idx[:n_val], idx[n_val:n_val + n_test], idx[n_val + n_test:])

    if partitioner == "dirichlet":
        clients = dirichlet_partition(
            x[train_idx], y[train_idx], cfg.n_clients, cfg.noniid_alpha, rng)
    elif partitioner == "iid":
        clients = iid_partition(x[train_idx], y[train_idx],
                                cfg.n_clients, rng)
    elif partitioner == "label_shard":
        clients = label_shard_partition(x[train_idx], y[train_idx],
                                        cfg.n_clients, shards_per_client, rng)
    else:
        raise ValueError(f"unknown partitioner {partitioner!r}; choose "
                         "from dirichlet | iid | label_shard")
    if as_numpy:
        to_a = lambda a, b: (np.ascontiguousarray(a),
                             np.ascontiguousarray(b))
    else:
        import jax.numpy as jnp
        to_a = lambda a, b: (jnp.asarray(a), jnp.asarray(b))
    return {
        "clients": [to_a(cx, cy) for cx, cy in clients],
        "val": to_a(x[val_idx], y[val_idx]),
        "test": to_a(x[test_idx], y[test_idx]),
    }
