"""Buffer-based synchronization (paper §Methodology).

Each client accumulates ``(weak-learner params, local error eps, vote weight
alpha, local round stamp)`` between synchronization events; at sync the
whole buffer crosses the network once and the server applies delayed weight
compensation to each entry based on its staleness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List


@dataclass
class BufferEntry:
    params: Dict
    eps: float
    alpha: float
    round_stamp: int          # client-local boosting round when trained


@dataclass
class ClientBuffer:
    client_id: int
    entries: List[BufferEntry] = field(default_factory=list)

    def add(self, params: Dict, eps: float, alpha: float, stamp: int) -> None:
        self.entries.append(BufferEntry(params, eps, alpha, stamp))

    def flush(self) -> List[BufferEntry]:
        out, self.entries = self.entries, []
        return out

    def __len__(self) -> int:
        return len(self.entries)

    def nbytes(self, param_bytes: Callable) -> int:
        """Wire size of the buffered payload (params + eps/alpha/stamp)."""
        return sum(int(param_bytes(e.params)) + 12 for e in self.entries)
