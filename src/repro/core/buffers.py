"""Buffer-based synchronization (paper §Methodology).

Each client accumulates ``(weak-learner params, local error eps, vote weight
alpha, local round stamp)`` between synchronization events; at sync the
whole buffer crosses the network once and the server applies delayed weight
compensation to each entry based on its staleness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List

# Per-entry wire overhead beyond the learner params themselves:
# eps (f32) + alpha (f32) + round stamp (i32).  This is THE single source
# for the constant — the engine's accounting and ClientBuffer.nbytes both
# route through entry_wire_bytes so they cannot drift apart.
ENTRY_OVERHEAD_BYTES = 12


@dataclass
class BufferEntry:
    params: Dict
    eps: float
    alpha: float
    round_stamp: int          # client-local boosting round when trained


def entry_wire_bytes(entry: "BufferEntry", param_bytes: Callable) -> int:
    """Bytes one buffered entry occupies on the wire."""
    return int(param_bytes(entry.params)) + ENTRY_OVERHEAD_BYTES


def payload_wire_bytes(entries: Iterable["BufferEntry"],
                       param_bytes: Callable) -> int:
    """Wire size of a sync payload (sans message header)."""
    return sum(entry_wire_bytes(e, param_bytes) for e in entries)


@dataclass
class ClientBuffer:
    client_id: int
    entries: List[BufferEntry] = field(default_factory=list)

    def add(self, params: Dict, eps: float, alpha: float, stamp: int) -> None:
        self.entries.append(BufferEntry(params, eps, alpha, stamp))

    def flush(self) -> List[BufferEntry]:
        out, self.entries = self.entries, []
        return out

    def __len__(self) -> int:
        return len(self.entries)

    def nbytes(self, param_bytes: Callable) -> int:
        """Wire size of the buffered payload (params + eps/alpha/stamp)."""
        return payload_wire_bytes(self.entries, param_bytes)
