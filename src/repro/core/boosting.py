"""AdaBoost core in JAX: weighted errors, vote weights, the sample
distribution update, ensemble evaluation, and a centralized reference loop.

Binary labels live in {-1,+1}; weak-learner outputs are margins in [-1,1]
(stumps emit exactly +-1).  The multiclass extension (SAMME) is provided for
the domain datasets that need it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.compensation import adaboost_alpha
from repro.models.weak import WeakLearnerSpec

Array = jnp.ndarray


def weighted_error(D: Array, y: Array, margins: Array) -> Array:
    """eps = sum_i D_i [sign(h(x_i)) != y_i]; ties (h==0) count as errors."""
    pred = jnp.where(margins > 0, 1.0, -1.0)
    miss = (pred != y).astype(jnp.float32)
    return jnp.sum(D * miss)


def update_distribution(D: Array, alpha_tilde, y: Array, margins: Array
                        ) -> Tuple[Array, Array]:
    """D_{t+1}(i) = D_t(i) exp(-alpha~ y_i h_t(x_i)) / Z_t  (paper eq. 4).

    Returns (D_new, Z_t)."""
    w = D * jnp.exp(-alpha_tilde * y * margins)
    Z = jnp.sum(w)
    return w / (Z + 1e-30), Z


def ensemble_margin(margins_stack: Array, alphas: Array) -> Array:
    """H(x) = sum_t alpha~_t h_t(x).  margins_stack: (T,N); alphas: (T,)."""
    return jnp.einsum("t,tn->n", alphas.astype(jnp.float32),
                      margins_stack.astype(jnp.float32))


def ensemble_predict(margins_stack: Array, alphas: Array) -> Array:
    """H_T(x) = sign(sum alpha~ h) (paper eq. 3)."""
    return jnp.where(ensemble_margin(margins_stack, alphas) > 0, 1.0, -1.0)


def accuracy(margins_stack: Array, alphas: Array, y: Array) -> Array:
    return jnp.mean(ensemble_predict(margins_stack, alphas) == y)


# ---------------------------------------------------------------------------
# centralized AdaBoost (the non-federated reference the paper compares to)
# ---------------------------------------------------------------------------

@dataclass
class Ensemble:
    """A grown ensemble: learner params + compensated weights."""
    learners: List[Dict] = field(default_factory=list)
    alphas: List[float] = field(default_factory=list)

    def add(self, params: Dict, alpha: float) -> None:
        self.learners.append(params)
        self.alphas.append(float(alpha))

    def margins(self, predict: Callable, x: Array) -> Array:
        if not self.learners:
            return jnp.zeros((1, x.shape[0]), jnp.float32)
        return jnp.stack([predict(p, x) for p in self.learners])

    def predict(self, predict_fn: Callable, x: Array) -> Array:
        m = self.margins(predict_fn, x)
        return jnp.where(
            ensemble_margin(m, jnp.asarray(self.alphas)) > 0, 1.0, -1.0)

    def error(self, predict_fn: Callable, x: Array, y: Array) -> float:
        return float(jnp.mean(self.predict(predict_fn, x) != y))


def fit_adaboost(x: Array, y: Array, n_rounds: int, weak: WeakLearnerSpec,
                 key=None) -> Tuple[Ensemble, List[float]]:
    """Classical (centralized, synchronous) AdaBoost.  Returns the ensemble
    and the per-round training-error-bound factors Z_t (prod Z_t bounds the
    training error — asserted by property tests)."""
    key = key if key is not None else jax.random.key(0)
    N = x.shape[0]
    D = jnp.full((N,), 1.0 / N)
    ens = Ensemble()
    zs: List[float] = []
    for t in range(n_rounds):
        key, sub = jax.random.split(key)
        params = weak.fit(x, y, D, sub)
        h = weak.predict(params, x)
        eps = weighted_error(D, y, h)
        if float(eps) >= 0.5:      # weak learner no better than chance: stop
            break
        alpha = adaboost_alpha(eps)
        D, Z = update_distribution(D, alpha, y, h)
        ens.add(params, float(alpha))
        zs.append(float(Z))
    return ens, zs


# ---------------------------------------------------------------------------
# SAMME multiclass extension
# ---------------------------------------------------------------------------

def samme_alpha(eps, n_classes: int):
    eps = jnp.clip(jnp.asarray(eps, jnp.float32), 1e-6, 1.0 - 1e-6)
    return jnp.log((1.0 - eps) / eps) + jnp.log(n_classes - 1.0)


def samme_update_distribution(D: Array, alpha, y_idx: Array, pred_idx: Array
                              ) -> Tuple[Array, Array]:
    miss = (pred_idx != y_idx).astype(jnp.float32)
    w = D * jnp.exp(alpha * miss)
    Z = jnp.sum(w)
    return w / (Z + 1e-30), Z
