"""Vectorized fleet profile of the event-driven engine core.

:class:`~repro.core.async_engine.FederatedBoostEngine` delegates here when
``fleet=True`` (auto-enabled at ``FLEET_AUTO_CLIENTS``+ clients).  The
reference profile runs one device dispatch per client fit and one python
merge per learner — fine at 32 clients, hopeless at 100 000.  The fleet
profile keeps the *same event-queue semantics* but restructures the math:

* **Stacked shards.**  Client shards are padded to the fleet's max rows and
  stacked into one ``(B, N, F)`` array; padding rows carry zero distribution
  mass, which every batched kernel treats as "contributes nothing".  The
  per-client quantile threshold grids come from one
  ``stump_thresholds_batched`` launch at construction.
* **Deferred, batched fits.**  A client leg between syncs is causally
  closed, so its *timing* walk (availability/compute/stall/link draws — the
  behavior calls, in the reference call order) runs eagerly while the stump
  fits it implies are queued.  Pending fits resolve in dependency *waves* —
  wave ``j`` fits round ``j`` of every pending leg in one bucketed
  ``fit_stump_batched`` launch (batch padded to a power of two so the jit
  cache stays small) — and each wave's local eps/alpha/distribution updates
  run vectorized in numpy.
* **Vectorized server math.**  Server-side re-weighting, margin folds, and
  the capped catch-up replay are numpy matrix ops (chunked so a
  100k-learner round never materializes more than ``SERVER_CHUNK`` columns
  at once).

Communication/time accounting is identical integer/float bookkeeping to the
reference profile — byte counts, message counts, and simulated clocks match
exactly at equal seeds.  Floating-point *learning* results (errors, alphas)
match up to summation order: the fleet profile sums in numpy float32 where
the reference reduces on the device, and folds a sync's distribution
updates in one exponential rather than entry-by-entry (equal up to the
``1e-30`` normalization epsilon).  ``cfg.catch_up_cap`` is how fleet-scale
scenarios bound catch-up work per sync; ``None`` replays the whole window
exactly like the reference.

Only the ``stump`` weak learner is supported — the batched launch path is
stump-specific (the other learners never run at fleet scale).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core import events
from repro.core.buffers import BufferEntry, ENTRY_OVERHEAD_BYTES
from repro.core.compensation import staleness_scale

# threshold-grid launches are chunked to this many clients (padded to the
# chunk size, so the jit cache holds exactly one entry per fleet dtype)
THRESHOLD_CHUNK = 16384
# server-side re-weighting materializes at most (n_val x SERVER_CHUNK)
SERVER_CHUNK = 4096
_F32 = np.float32


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class FleetCore:
    """One engine run in the vectorized fleet profile."""

    def __init__(self, eng) -> None:
        if eng.weak.name != "stump":
            raise ValueError(
                "the fleet profile batches stump fits; weak learner "
                f"{eng.weak.name!r} has no batched launch path")
        self.eng = eng
        self.cfg = eng.cfg
        self.m = eng.metrics
        self.clients = eng.clients
        B = len(self.clients)

        # ---- stacked, padded shards (pad rows: x=0, y=0, D=0) ----
        self.n_valid = np.array([c.x.shape[0] for c in self.clients],
                                np.int32)
        N = int(self.n_valid.max())
        F = int(np.asarray(self.clients[0].x).shape[1])
        self.X = np.zeros((B, N, F), _F32)
        self.Y = np.zeros((B, N), _F32)
        self.D = np.zeros((B, N), _F32)
        for b, c in enumerate(self.clients):
            n = int(self.n_valid[b])
            self.X[b, :n] = np.asarray(c.x, _F32)
            self.Y[b, :n] = np.asarray(c.y, _F32)
            yb = self.Y[b, :n]
            if self.cfg.balanced_init:
                pos = (yb > 0).astype(_F32)
                npos = max(float(pos.sum()), 1.0)
                nneg = max(n - float(pos.sum()), 1.0)
                self.D[b, :n] = pos / (2 * npos) + (1 - pos) / (2 * nneg)
            else:
                self.D[b, :n] = 1.0 / n
        self.THR = self._build_thresholds()                    # (B, F, T)

        # ---- server state mirrors (numpy-side ensemble view) ----
        xv, yv = eng.data["val"]
        xt, yt = eng.data["test"]
        self.xv = np.asarray(xv, _F32)
        self.yv = np.asarray(yv, _F32)
        self.xt = np.asarray(xt, _F32)
        self.yt = np.asarray(yt, _F32)
        self.Mval = np.zeros(self.xv.shape[0], _F32)
        self.Mtest = np.zeros(self.xt.shape[0], _F32)
        # merged-learner columns, merge order (the catch-up window source)
        self._lf: List[int] = []       # feature
        self._lt: List[float] = []     # threshold
        self._lp: List[float] = []     # polarity
        self._la: List[float] = []     # compensated server alpha
        # deferred fits: cid -> FIFO of unresolved BufferEntry (insertion
        # order over cids is the wave's batch order)
        self._pending: Dict[int, List[BufferEntry]] = {}
        # stump wire size is params-independent, so accounting never needs
        # the (possibly still unresolved) params
        self._entry_bytes = (int(eng.weak.param_bytes(None))
                             + ENTRY_OVERHEAD_BYTES)

    # ------------------------------------------------------------ batched fits
    def _build_thresholds(self) -> np.ndarray:
        import jax.numpy as jnp
        from repro.models.weak import stump_thresholds_batched
        B = self.X.shape[0]
        chunk = min(THRESHOLD_CHUNK, _next_pow2(B))
        grids = []
        for lo in range(0, B, chunk):
            xb = self.X[lo:lo + chunk]
            nb = self.n_valid[lo:lo + chunk]
            pad = chunk - xb.shape[0]
            if pad:
                xb = np.concatenate([xb, np.zeros(
                    (pad,) + xb.shape[1:], _F32)])
                nb = np.concatenate([nb, np.ones(pad, np.int32)])
            g = stump_thresholds_batched(jnp.asarray(xb), jnp.asarray(nb))
            grids.append(np.asarray(g, _F32)[:xb.shape[0] - pad
                                             if pad else None])
        return np.concatenate(grids)[:B]

    def _fit_backend(self, xb) -> Optional[str]:
        """Resolve the batched-fit backend.  No policy keeps the jnp
        oracle (a single vmapped XLA launch — the right default off-TPU);
        a policy resolves normally except that the *interpret* substrate is
        swapped for ``xla`` at fleet batch sizes, where a vmapped
        interpreter launch is pathological."""
        policy = self.eng.kernel_policy
        if policy is None:
            return None
        from repro.kernels import dispatch as kdispatch
        name = policy.resolve_name(
            "stump_scan_batched",
            kdispatch.bucket_of("stump_scan_batched", xb))
        if name == "interpret" and xb[0].shape[0] >= 64:
            return "xla"
        return name

    def _fit_wave(self, slots: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One bucketed batched-fit launch over ``slots`` (client rows),
        padded to a power of two with zero-weight slots."""
        import jax.numpy as jnp
        from repro.models.weak import fit_stump_batched
        Bw = len(slots)
        BP = max(8, _next_pow2(Bw))
        pad = BP - Bw
        xb, yb = self.X[slots], self.Y[slots]
        wb, tb = self.D[slots], self.THR[slots]
        if pad:
            z = lambda a: np.concatenate(
                [a, np.zeros((pad,) + a.shape[1:], a.dtype)])
            xb, yb, wb, tb = z(xb), z(yb), z(wb), z(tb)
        with obs.span("train.fit_batch", n_slots=Bw, padded=BP):
            args = (jnp.asarray(xb), jnp.asarray(yb),
                    jnp.asarray(wb), jnp.asarray(tb))
            params = fit_stump_batched(*args,
                                       backend=self._fit_backend(args))
        obs.count("train.fit_batches")
        obs.count("train.fits", Bw)
        f = np.asarray(params["feature"])[:Bw].astype(np.int64)
        thr = np.asarray(params["threshold"], _F32)[:Bw]
        pol = np.asarray(params["polarity"], _F32)[:Bw]
        return f, thr, pol

    def _local_update(self, slots: np.ndarray, f: np.ndarray,
                      thr: np.ndarray, pol: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized mirror of the reference ``_train_one`` tail: eps on
        the pre-update distribution, the local alpha, and the eq.-(4)
        distribution update, for every fitted slot at once."""
        xsel = np.take_along_axis(
            self.X[slots], f[:, None, None], axis=2)[:, :, 0]
        h = pol[:, None] * np.sign(xsel - thr[:, None] + 1e-12)
        yb, Db = self.Y[slots], self.D[slots]
        pred = np.where(h > 0, 1.0, -1.0).astype(_F32)
        eps = np.sum(Db * (pred != yb), axis=1, dtype=_F32)
        epsc = np.clip(eps, 1e-6, 1.0 - 1e-6)
        alpha = (0.5 * np.log((1.0 - epsc) / epsc)).astype(_F32)
        w = Db * np.exp(-alpha[:, None] * yb * h)
        Z = np.sum(w, axis=1, dtype=_F32)
        self.D[slots] = w / (Z[:, None] + 1e-30)
        return eps, alpha

    def _defer_fit(self, c) -> BufferEntry:
        """Queue one deferred stump fit for client ``c``'s current round;
        the placeholder entry is filled in by the next resolution wave."""
        e = BufferEntry(None, 0.0, 0.0, c.local_round)
        c.local_round += 1
        self._pending.setdefault(c.cid, []).append(e)
        return e

    def _resolve_pending(self) -> None:
        """Drain every queued fit, one dependency wave at a time: wave j
        fits the j-th unresolved round of each pending client (all waves
        are single bucketed launches)."""
        while self._pending:
            obs.get_registry().gauge("train.pending_fits").set(
                sum(len(v) for v in self._pending.values()))
            slots = np.fromiter(self._pending.keys(), np.int64,
                                len(self._pending))
            f, thr, pol = self._fit_wave(slots)
            eps, alpha = self._local_update(slots, f, thr, pol)
            for j, cid in enumerate(slots.tolist()):
                fifo = self._pending[cid]
                e = fifo.pop(0)
                e.params = {"feature": int(f[j]),
                            "threshold": float(thr[j]),
                            "polarity": float(pol[j])}
                e.eps = float(eps[j])
                e.alpha = float(alpha[j])
                if not fifo:
                    del self._pending[cid]
        obs.get_registry().gauge("train.pending_fits").set(0)

    # --------------------------------------------------------- server math
    def _merge_window(self, entries: List[BufferEntry], owners: List[int],
                      sync_round: int, compensated: bool) -> None:
        """Fold ``entries`` into the global ensemble: vectorized server
        re-weighting + margin folds, then the bookkeeping the reference
        ``_merge`` does per entry."""
        if not entries:
            return
        eng, K = self.eng, len(entries)
        f = np.array([e.params["feature"] for e in entries], np.int64)
        thr = np.array([e.params["threshold"] for e in entries], _F32)
        pol = np.array([e.params["polarity"] for e in entries], _F32)
        a = np.empty(K, _F32)
        for lo in range(0, K, SERVER_CHUNK):
            s = slice(lo, min(lo + SERVER_CHUNK, K))
            a[s] = self._server_alphas(f[s], thr[s], pol[s])
        if compensated:
            scale = np.array(
                [staleness_scale(max(0, sync_round - e.round_stamp),
                                 self.cfg.compensation) for e in entries],
                _F32)
            a = a * scale
        for lo in range(0, K, SERVER_CHUNK):
            s = slice(lo, min(lo + SERVER_CHUNK, K))
            hv = pol[s] * np.sign(self.xv[:, f[s]] - thr[s] + 1e-12)
            ht = pol[s] * np.sign(self.xt[:, f[s]] - thr[s] + 1e-12)
            self.Mval += hv @ a[s]
            self.Mtest += ht @ a[s]
        for e, owner, ai in zip(entries, owners, a.tolist()):
            eng.ensemble.add(e.params, ai)
            eng._owners.append(owner)
            eng._round_stamps.append(e.round_stamp)
            self._lf.append(e.params["feature"])
            self._lt.append(e.params["threshold"])
            self._lp.append(e.params["polarity"])
            self._la.append(ai)
        self.m.learners_merged += K

    def _server_alphas(self, f: np.ndarray, thr: np.ndarray,
                       pol: np.ndarray) -> np.ndarray:
        """Vectorized ``_server_alpha``: validation-set re-weighting for a
        window of stump columns at once."""
        h = pol[None, :] * np.sign(self.xv[:, f] - thr[None, :] + 1e-12)
        pred = np.where(h > 0, 1.0, -1.0).astype(_F32)
        yv = self.yv[:, None]
        miss = pred != yv
        if self.cfg.balanced_init:
            pos, neg = yv > 0, yv < 0
            ep = np.sum(miss & pos, axis=0) / max(float(pos.sum()), 1.0)
            en = np.sum(miss & neg, axis=0) / max(float(neg.sum()), 1.0)
            eps = np.clip(0.5 * (ep + en), 0.02, 0.98)
        else:
            eps = np.clip(miss.mean(axis=0), 0.02, 0.98)
        return (0.5 * np.log((1.0 - eps) / eps)).astype(_F32)

    def _val_err(self) -> float:
        pred = np.where(self.Mval > 0, 1.0, -1.0)
        return float(np.mean(pred != self.yv))

    # ----------------------------------------------------------- catch-up
    def _catch_up_fleet(self, w0: int, w1: int) -> None:
        """Every client replays the newest ``catch_up_cap`` foreign
        learners of window [w0, w1) into its local distribution — the
        whole fleet at once, one folded exponential per client (the
        baseline's per-round catch-up).  An owner-aware mask reproduces
        the reference reverse scan: the window is extended by the largest
        per-owner multiplicity so every client finds ``cap`` foreign
        entries even when its own sit inside the candidate tail."""
        K = w1 - w0
        if K <= 0:
            return
        B = self.X.shape[0]
        cap = self.cfg.catch_up_cap
        owners = np.asarray(self.eng._owners[w0:w1], np.int64)
        if cap is None:
            W = K
        else:
            maxdup = int(np.bincount(owners - owners.min()).max()) if K else 0
            W = min(K, cap + maxdup)
        cand = slice(w1 - W, w1)              # oldest -> newest candidates
        co = np.asarray(self.eng._owners[cand.start:cand.stop], np.int64)
        foreign = co[None, :] != np.arange(B)[:, None]          # (B, W)
        if cap is None:
            sel = foreign
        else:
            rev = foreign[:, ::-1]
            sel = (rev & (np.cumsum(rev, axis=1) <= cap))[:, ::-1]
        cf = np.asarray(self._lf[cand.start:cand.stop], np.int64)
        ct = np.asarray(self._lt[cand.start:cand.stop], _F32)
        cp = np.asarray(self._lp[cand.start:cand.stop], _F32)
        ca = np.asarray(self._la[cand.start:cand.stop], _F32)
        Macc = np.zeros_like(self.D)
        for w in range(W):
            h = cp[w] * np.sign(self.X[:, :, cf[w]] - ct[w] + 1e-12)
            Macc += (ca[w] * sel[:, w].astype(_F32))[:, None] * h
        wgt = self.D * np.exp(-self.Y * Macc)
        Z = np.sum(wgt, axis=1, dtype=_F32)
        self.D = wgt / (Z[:, None] + 1e-30)
        for c in self.clients:
            c.last_merged_idx = w1

    def _catch_up_client(self, c) -> None:
        """Per-client capped catch-up at its own sync (enhanced mode):
        the reference reverse scan over [last_merged_idx, hi) skipping the
        client's own entries, folded into one exponential."""
        lo, hi = c.last_merged_idx, len(self._lf)
        cap = self.cfg.catch_up_cap
        owners = self.eng._owners
        if cap is None:
            idxs = [i for i in range(lo, hi) if owners[i] != c.cid]
        else:
            idxs = []
            i = hi - 1
            while i >= lo and len(idxs) < cap:
                if owners[i] != c.cid:
                    idxs.append(i)
                i -= 1
            idxs.reverse()
        c.last_merged_idx = hi
        if not idxs:
            return
        b = c.cid
        f = np.array([self._lf[i] for i in idxs], np.int64)
        thr = np.array([self._lt[i] for i in idxs], _F32)
        pol = np.array([self._lp[i] for i in idxs], _F32)
        a = np.array([self._la[i] for i in idxs], _F32)
        h = pol[None, :] * np.sign(self.X[b][:, f] - thr[None, :] + 1e-12)
        wgt = self.D[b] * np.exp(-self.Y[b] * (h @ a))
        Z = float(np.sum(wgt, dtype=_F32))
        self.D[b] = wgt / (Z + 1e-30)

    # ---------------------------------------------------------------- run
    def run(self) -> None:
        if self.m.mode == "baseline":
            self._run_baseline()
        else:
            self._run_enhanced()
        # hand the accumulated margins back so the engine's _finalize /
        # _val_error see the fleet-computed state
        import jax.numpy as jnp
        self.eng._val_margin = jnp.asarray(self.Mval)
        self.eng._test_margin = jnp.asarray(self.Mtest)

    def _run_baseline(self) -> None:
        """Synchronous baseline, fleet profile.  Same TRIGGER/BARRIER
        event structure as the reference event core; per-message ARRIVAL
        events are folded into the barrier payload — the barrier consumes
        the round's messages in client order regardless, and a heap push
        per message at 100k clients buys nothing."""
        cfg, m, eng = self.cfg, self.m, self.eng
        vc = events.VirtualClock()
        B = self.X.shape[0]
        all_slots = np.arange(B)
        pending_late: List[Tuple[int, BufferEntry]] = []
        t = 0.0
        vc.push(0.0, events.TRIGGER, payload=0)
        while vc:
            ev = vc.pop()
            if ev.kind == events.TRIGGER:
                r, t0 = ev.payload, ev.t
                f, thr, pol = self._fit_wave(all_slots)
                eps, alpha = self._local_update(all_slots, f, thr, pol)
                late, pending_late = pending_late, []
                on_time: List[Tuple[int, BufferEntry]] = []
                durations: List[float] = []
                for b, c in enumerate(self.clients):
                    dropped = not c.behavior.availability(t0)
                    dur = c.behavior.compute_time(eng.BASE_ROUND_S, t0)
                    e = BufferEntry(
                        {"feature": int(f[b]), "threshold": float(thr[b]),
                         "polarity": float(pol[b])},
                        float(eps[b]), float(alpha[b]), c.local_round)
                    c.local_round += 1
                    if dropped:
                        m.rounds_unavailable += 1
                        pending_late.append((b, e))
                        continue
                    up = self._entry_bytes + cfg.header_bytes
                    m.uplink_bytes += up
                    m.n_messages += 1
                    durations.append(
                        dur + c.behavior.link(t0).tx_time(up))
                    on_time.append((b, e))
                close = t0 + (max(durations) if durations
                              else eng.BASE_ROUND_S)
                vc.push(close, events.BARRIER, payload=(r, late, on_time))
            elif ev.kind == events.BARRIER:
                r, late, on_time = ev.payload
                t = ev.t
                for cid, e in late:
                    m.uplink_bytes += self._entry_bytes + cfg.header_bytes
                    m.n_messages += 1
                w0 = len(self._lf)
                batch = late + on_time
                self._merge_window([e for _, e in batch],
                                   [cid for cid, _ in batch],
                                   sync_round=r, compensated=False)
                delta = len(self._lf) - w0
                pkg = delta * 16 + cfg.header_bytes
                m.downlink_bytes += B * pkg
                m.n_messages += B
                self._catch_up_fleet(w0, len(self._lf))
                m.n_syncs += 1
                obs.count("train.syncs")
                obs.count("train.learners_merged", delta)
                eng._maybe_publish(t)
                eng._record(t, err=self._val_err())
                if r + 1 < cfg.n_rounds:
                    vc.push(t, events.TRIGGER, payload=r + 1)
        obs.count("train.events", vc.n_popped)
        m.sim_time_s = self._flush_late(pending_late, t)

    def _flush_late(self, pending_late: List[Tuple[int, BufferEntry]],
                    t: float) -> float:
        """Fleet mirror of the engine's ``_flush_late``: deliver + charge
        the final round's dropped messages, merge them stale-by-one at
        full weight, no downlink/sync tick."""
        cfg, m = self.cfg, self.m
        if not pending_late:
            return t
        t_flush = t
        for cid, e in pending_late:
            c = self.clients[cid]
            up = self._entry_bytes + cfg.header_bytes
            m.uplink_bytes += up
            m.n_messages += 1
            t_flush = max(t_flush, t + c.behavior.link(t).tx_time(up))
        self._merge_window([e for _, e in pending_late],
                           [cid for cid, _ in pending_late],
                           sync_round=cfg.n_rounds, compensated=False)
        if obs.enabled():
            obs.point("train.late_flush", sim_t0=t_flush,
                      n=len(pending_late))
        self.eng._record(t_flush, err=self._val_err())
        return t_flush

    def _run_enhanced(self) -> None:
        """The paper's algorithm, fleet profile: the reference event loop
        with eager per-client timing walks and deferred, wave-batched
        fits.  Arrivals pop in the same (t, kind, cid) order; a payload
        still holding unresolved fits triggers a resolution sweep over
        *every* pending leg — at fleet scale many legs are in flight at
        once, so the sweep's waves stay large."""
        cfg, m, eng = self.cfg, self.m, self.eng
        vc = events.VirtualClock()
        for c in self.clients:
            c.known_interval = eng.scheduler.current
        finished = [False] * len(self.clients)

        def advance(c) -> None:
            trace = obs.enabled()
            while c.local_round < cfg.n_rounds:
                dropped = not c.behavior.availability(c.clock)
                e = self._defer_fit(c)
                c.clock += c.behavior.compute_time(eng.BASE_ROUND_S,
                                                   c.clock)
                if trace:
                    vc.push(c.clock, events.ROUND, c.cid)
                c.buffer.entries.append(e)
                if dropped:
                    m.rounds_unavailable += 1
                    c.clock += c.behavior.stall_time(eng.BASE_ROUND_S,
                                                     c.clock)
                    if trace:
                        vc.push(c.clock, events.STALL, c.cid)
                if len(c.buffer) >= c.known_interval:
                    if trace:
                        vc.push(c.clock, events.TRIGGER, c.cid)
                    arrival, payload = self._prepare_sync(c)
                    vc.push(arrival, events.ARRIVAL, c.cid, payload)
                    return
            finished[c.cid] = True
            if len(c.buffer):             # flush the tail buffer
                arrival, payload = self._prepare_sync(c)
                vc.push(arrival, events.ARRIVAL, c.cid, payload)

        for c in self.clients:
            advance(c)
        t = 0.0
        interval_gauge = obs.get_registry().gauge("train.interval")
        while vc:
            ev = vc.pop()
            if ev.kind == events.ROUND:
                obs.point("train.client_round", sim_t0=ev.t, cid=ev.cid)
                continue
            if ev.kind == events.STALL:
                obs.point("train.stall", sim_t0=ev.t, cid=ev.cid)
                continue
            if ev.kind == events.TRIGGER:
                obs.point("train.trigger", sim_t0=ev.t, cid=ev.cid)
                continue
            t, cid, payload = ev.t, ev.cid, ev.payload
            if any(e.params is None for e in payload):
                self._resolve_pending()
            c = self.clients[cid]
            sync_round = c.local_round - 1
            self._merge_window(payload, [cid] * len(payload),
                               sync_round=sync_round, compensated=True)
            m.n_syncs += 1
            obs.count("train.syncs")
            obs.count("train.learners_merged", len(payload))
            err = self._val_err()
            eng.scheduler.observe(err)
            delta = len(self._lf) - c.last_merged_idx
            pkg = delta * 16 + cfg.header_bytes
            m.downlink_bytes += pkg
            m.n_messages += 1
            self._catch_up_client(c)
            c.known_interval = eng.scheduler.current
            interval_gauge.set(eng.scheduler.current)
            eng._maybe_publish(t)
            eng._record(t, err=err)
            if not finished[cid]:
                advance(c)
        obs.count("train.events", vc.n_popped)
        m.sim_time_s = max(t, max(c.clock for c in self.clients))

    def _prepare_sync(self, c) -> Tuple[float, List[BufferEntry]]:
        """Fleet mirror of the engine's ``_prepare_sync``.  The relevance
        filter needs the buffered alphas, so an enabled filter forces the
        pending fits to resolve first (the filter is off in the shipped
        fleet scenarios — it would serialize the waves)."""
        cfg, m = self.cfg, self.m
        if cfg.relevance_filter > 0 and len(c.buffer) > 1:
            if any(e.params is None for e in c.buffer.entries):
                self._resolve_pending()
            now = c.local_round - 1
            entries = c.buffer.entries
            w = [abs(e.alpha) * staleness_scale(
                    max(0, now - e.round_stamp), cfg.compensation)
                 for e in entries]
            cut = cfg.relevance_filter * max(w)
            kept = [e for e, wi in zip(entries, w) if wi >= cut]
            c.buffer.entries = kept if kept else entries[-1:]
        nbytes = (len(c.buffer) * self._entry_bytes + cfg.header_bytes)
        payload = c.buffer.flush()
        arrival = c.clock + c.behavior.link(c.clock).tx_time(nbytes)
        m.uplink_bytes += nbytes
        m.n_messages += 1
        return arrival, payload
