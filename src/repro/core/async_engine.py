"""Event-driven asynchronous federated AdaBoost simulator.

This is the *faithful* implementation of the paper's algorithm and of the
baseline it compares against, with byte-accurate communication accounting
and a simulated wall-clock that models heterogeneous client compute rates,
link bandwidths, and dropouts.  EXPERIMENTS.md §Paper validates the five
domain scenarios against Table 1 with this engine.

Modes
-----
* ``baseline``  — synchronous distributed AdaBoost: every global round every
  (non-dropped) client trains one weak learner and synchronizes; the round
  completes at the pace of the slowest participant (straggler barrier); no
  weight compensation (stale learners from recovered dropouts enter at full
  vote weight).
* ``enhanced``  — the paper's algorithm: clients proceed at their own pace,
  buffer learners locally, synchronize every I_t rounds where I_t follows
  the adaptive rule (eq. 1), and the server folds buffered learners in with
  delayed weight compensation alpha~ = alpha * exp(-lambda * tau) (eq. 2).

Cost model
----------
Every per-round cost is asked of the client's
:class:`~repro.sim.behavior.ClientBehavior` (the ``behavior_for`` hook):

* compute: ``behavior.compute_time(BASE_ROUND_S, t)`` simulated seconds per
  boosting round; the default :class:`~repro.sim.behavior.LegacyBehavior`
  shim reproduces ``base_round_s * speed_k`` with
  speed_k ~ LogUniform[1, straggler_factor] bit-for-bit.
* uplink: ``bytes / (bandwidth/8 * 1e6) + latency`` per message with
  ``(latency, bandwidth) = behavior.link(t)``; one message per
  synchronization carrying the whole buffer (+ header).
* downlink: ensemble delta (learners merged since the client's last sync)
  broadcast back at sync; the synchronous baseline pays this every round
  for every client.
* availability: a round where ``behavior.availability(t)`` is False is
  missed (legacy shim: i.i.d. dropout with probability p); in baseline its
  learner arrives one round late (stale, uncompensated); in enhanced the
  buffer grows (stale, compensated) and the client stalls by
  ``behavior.stall_time`` — one compute round for the legacy shim, the
  rest of the window for an outage model.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.paper_fedboost import FedBoostConfig
from repro.core.boosting import (
    Ensemble, update_distribution, weighted_error)
from repro.core.buffers import BufferEntry, ClientBuffer
from repro.core.compensation import adaboost_alpha, compensate
from repro.core.scheduling import HostScheduler
from repro.models.weak import WeakLearnerSpec, get_weak_learner
from repro.sim.behavior import ClientBehavior, legacy_behaviors


@dataclass
class RunMetrics:
    mode: str
    sim_time_s: float = 0.0
    uplink_bytes: int = 0
    downlink_bytes: int = 0
    n_messages: int = 0
    n_syncs: int = 0
    learners_merged: int = 0
    rounds_to_target: Optional[int] = None
    time_to_target: Optional[float] = None
    snapshots_published: int = 0
    rounds_unavailable: int = 0   # rounds lost to dropout/outage/deep fade
    val_error_curve: List[Tuple[float, int, float]] = field(default_factory=list)
    final_val_error: float = 1.0
    final_test_error: float = 1.0
    final_test_recall: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.downlink_bytes


@dataclass
class _Client:
    cid: int
    x: jnp.ndarray
    y: jnp.ndarray
    D: jnp.ndarray
    behavior: ClientBehavior      # availability/compute/link model
    clock: float = 0.0
    local_round: int = 0
    buffer: ClientBuffer = None
    known_interval: int = 1
    last_merged_idx: int = 0      # ensemble size at client's last sync


class FederatedBoostEngine:
    """Runs one (mode, domain-dataset) federated boosting experiment."""

    BASE_ROUND_S = 1.0            # nominal compute seconds per boosting round
    LATENCY_S = 0.05

    def __init__(self, cfg: FedBoostConfig, data: Dict, mode: str,
                 weak: Optional[WeakLearnerSpec] = None,
                 kernel_policy=None,
                 behavior_for: Optional[
                     Callable[[int], ClientBehavior]] = None):
        assert mode in ("baseline", "enhanced")
        self.cfg = cfg
        self.mode = mode
        # behavior_for: cid -> ClientBehavior, the client-heterogeneity
        # hook (repro.sim).  None builds the LegacyBehavior shim from the
        # cfg scalars — same RNG draws in the same order, so results at
        # equal seeds are bit-for-bit identical to the pre-behavior engine.
        # kernel_policy: optional repro.kernels.KernelPolicy routing the
        # weak-learner fit through the backend dispatcher (re-resolved per
        # fit, so env/calibration changes apply mid-run); None keeps the
        # jnp oracle.  Ignored when an explicit `weak` spec is supplied.
        self.weak = weak or get_weak_learner(cfg.weak_learner,
                                             policy=kernel_policy)
        self.rng = np.random.RandomState(cfg.seed)
        self.data = data              # {clients: [(x,y)...], val:(x,y), test:(x,y)}
        self.scheduler = HostScheduler(cfg.scheduler)
        self.ensemble = Ensemble()
        self._owners: List[int] = []
        self.metrics = RunMetrics(mode=mode)
        self._val_margin = None       # running sum alpha~*h over val set
        self._test_margin = None
        self._key = jax.random.key(cfg.seed)
        # serving hook (attach_registry): snapshot publication mid-training
        self._registry = None
        self._tenant: Optional[str] = None
        self._publish_every = 1
        self._syncs_since_publish = 0

        n = len(data["clients"])
        if behavior_for is None:
            shims = legacy_behaviors(cfg, n, self.rng,
                                     latency_s=self.LATENCY_S)
            behavior_for = lambda cid: shims[cid]
        self.behavior_for = behavior_for
        self.clients = []
        for cid, (x, y) in enumerate(data["clients"]):
            n = x.shape[0]
            if cfg.balanced_init:
                # class-balanced D_0: standard boosting practice for rare-
                # positive domains (IoT anomaly / healthcare diagnosis) —
                # each class carries half the initial distribution mass
                pos = (y > 0).astype(jnp.float32)
                npos = jnp.maximum(jnp.sum(pos), 1.0)
                nneg = jnp.maximum(n - npos, 1.0)
                D = pos / (2 * npos) + (1 - pos) / (2 * nneg)
            else:
                D = jnp.full((n,), 1.0 / n)
            self.clients.append(_Client(
                cid=cid, x=x, y=y, D=D,
                behavior=behavior_for(cid),
                buffer=ClientBuffer(cid)))

    # ------------------------------------------------------- serving hook
    def attach_registry(self, registry, tenant: str,
                        publish_every: int = 1) -> None:
        """Publish an immutable ensemble snapshot after every
        ``publish_every``-th synchronization, stamped with the simulated
        clock — serving hot-swaps versions while training keeps running.

        ``registry`` is either a single-host
        :class:`~repro.serve.registry.EnsembleRegistry` or a sharded
        :class:`~repro.serve.shard.ShardCluster`: the cluster exposes the
        same ``publish`` surface and routes every snapshot to the tenant's
        rendezvous-owning shard, whose subscribers (result-cache
        invalidation, gossip digests) observe it immediately."""
        assert publish_every >= 1
        self._registry = registry
        self._tenant = tenant
        self._publish_every = publish_every
        self._syncs_since_publish = 0

    def publish(self, clock: float):
        """The publish() hook: snapshot the current global ensemble into
        the attached registry/cluster (the owning shard is notified via
        the routed publish); returns the published snapshot, or None when
        there is nothing to publish yet."""
        if self._registry is None or not self.ensemble.learners:
            return None
        with obs.span("train.publish", sim_t=clock, tenant=self._tenant,
                      n_learners=len(self.ensemble.learners)) as sp:
            snap = self._registry.publish(
                self._tenant, list(self.ensemble.learners),
                list(self.ensemble.alphas), clock=float(clock),
                train_progress=self.metrics.learners_merged,
                weak_name=self.weak.name)
            sp.set(version=getattr(snap, "version", None))
            sp.end_sim(clock)
        obs.count("train.publishes")
        self.metrics.snapshots_published += 1
        return snap

    def _maybe_publish(self, clock: float) -> None:
        if self._registry is None:
            return
        self._syncs_since_publish += 1
        if self._syncs_since_publish >= self._publish_every:
            self._syncs_since_publish = 0
            self.publish(clock)

    # ------------------------------------------------------------ helpers
    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _train_one(self, c: _Client) -> BufferEntry:
        with obs.span("train.fit", sim_t=c.clock, cid=c.cid,
                      round=c.local_round):
            params = self.weak.fit(c.x, c.y, c.D, self._next_key())
        obs.count("train.fits")
        h = self.weak.predict(params, c.x)
        eps = float(weighted_error(c.D, c.y, h))
        alpha = float(adaboost_alpha(eps))
        # local distribution update with the local (uncompensated) alpha
        c.D, _ = update_distribution(c.D, alpha, c.y, h)
        entry = BufferEntry(params, eps, alpha, c.local_round)
        c.local_round += 1
        return entry

    def _entry_bytes(self, e: BufferEntry) -> int:
        return int(self.weak.param_bytes(e.params)) + 12

    def _server_alpha(self, params) -> float:
        """Global vote weight from the learner's error on the server's
        validation distribution.  Local alphas are computed against heavily
        skewed client shards — a near-single-class shard yields eps ~ 0 and
        an unbounded alpha, letting degenerate learners dominate.  Server-
        side re-weighting is the standard distributed-AdaBoost remedy
        (cf. ref [4]'s scalable distributed AdaBoost); both modes use it, so
        the baseline/enhanced comparison isolates the paper's delta."""
        xv, yv = self.data["val"]
        h = self.weak.predict(params, xv)
        pred = jnp.where(h > 0, 1.0, -1.0)
        if self.cfg.balanced_init:
            # balanced error for rare-positive domains: mean of per-class
            # error rates, so majority-voting stumps don't earn large alphas
            pos, neg = yv > 0, yv < 0
            ep = jnp.sum((pred != yv) & pos) / jnp.maximum(jnp.sum(pos), 1)
            en = jnp.sum((pred != yv) & neg) / jnp.maximum(jnp.sum(neg), 1)
            eps = float(jnp.clip(0.5 * (ep + en), 0.02, 0.98))
        else:
            eps = float(jnp.clip(jnp.mean(pred != yv), 0.02, 0.98))
        return float(adaboost_alpha(eps))

    def _merge(self, entries: List[BufferEntry], sync_round: int,
               compensated: bool, owner: int = -1) -> None:
        for e in entries:
            a = self._server_alpha(e.params)
            if compensated:
                tau = max(0, sync_round - e.round_stamp)
                raw = a
                a = float(compensate(a, tau, self.cfg.compensation))
                if obs.enabled():
                    obs.point("train.compensate", cid=owner, staleness=tau,
                              alpha_raw=raw, alpha=a)
            self.ensemble.add(e.params, a)
            self._owners.append(owner)
            self._fold_into_margins(e.params, a)
            self.metrics.learners_merged += 1

    def _fold_into_margins(self, params, alpha: float) -> None:
        xv, _ = self.data["val"]
        xt, _ = self.data["test"]
        hv = self.weak.predict(params, xv) * alpha
        ht = self.weak.predict(params, xt) * alpha
        self._val_margin = hv if self._val_margin is None else self._val_margin + hv
        self._test_margin = ht if self._test_margin is None else self._test_margin + ht

    def _val_error(self) -> float:
        _, yv = self.data["val"]
        if self._val_margin is None:
            return 1.0
        pred = jnp.where(self._val_margin > 0, 1.0, -1.0)
        return float(jnp.mean(pred != yv))

    def _client_catch_up(self, c: _Client) -> None:
        """Apply distribution updates for foreign learners received at sync.
        The client's own learners are skipped — it already applied them
        locally at training time."""
        lo = c.last_merged_idx
        for params, a, owner in zip(self.ensemble.learners[lo:],
                                    self.ensemble.alphas[lo:],
                                    self._owners[lo:]):
            if owner == c.cid:
                continue
            h = self.weak.predict(params, c.x)
            c.D, _ = update_distribution(c.D, a, c.y, h)
        c.last_merged_idx = len(self.ensemble.learners)

    def _record(self, t: float) -> None:
        err = self._val_error()
        m = self.metrics
        m.val_error_curve.append((t, m.learners_merged, err))
        if (self.cfg.target_error > 0 and err <= self.cfg.target_error
                and m.rounds_to_target is None):
            m.rounds_to_target = m.learners_merged
            m.time_to_target = t

    # ---------------------------------------------------------------- run
    def run(self) -> RunMetrics:
        if self.mode == "baseline":
            self._run_baseline()
        else:
            self._run_enhanced()
        self._finalize()
        return self.metrics

    # baseline: synchronous rounds with straggler barrier ------------------
    def _run_baseline(self) -> None:
        cfg, m = self.cfg, self.metrics
        t = 0.0
        pending_late: List[Tuple[int, BufferEntry]] = []
        for r in range(cfg.n_rounds):
            rsp = obs.span("train.round", sim_t=t, round=r)
            on_time: List[Tuple[int, BufferEntry]] = []
            durations: List[float] = []
            # learners that arrived late from last round's dropouts merge now
            late, pending_late = pending_late, []
            for c in self.clients:
                dropped = not c.behavior.availability(t)
                e = self._train_one(c)
                dur = c.behavior.compute_time(self.BASE_ROUND_S, t)
                if dropped:
                    # misses the barrier; arrives next round, stale by 1,
                    # merged at FULL weight (no compensation in baseline)
                    m.rounds_unavailable += 1
                    pending_late.append((c.cid, e))
                    continue
                up = self._entry_bytes(e) + cfg.header_bytes
                m.uplink_bytes += up
                m.n_messages += 1
                durations.append(dur + self._tx_time(up, c, t))
                on_time.append((c.cid, e))
            # barrier: the round closes at the slowest participant
            t += max(durations) if durations else self.BASE_ROUND_S
            merged_before = len(self.ensemble.learners)
            for cid, e in late + on_time:
                self._merge([e], r, compensated=False, owner=cid)
            # downlink: every client receives the merged delta every round
            delta = len(self.ensemble.learners) - merged_before
            pkg = delta * 16 + cfg.header_bytes
            for c in self.clients:
                m.downlink_bytes += pkg
                m.n_messages += 1
                self._client_catch_up(c)
            m.n_syncs += 1
            obs.count("train.syncs")
            obs.count("train.learners_merged", delta)
            self._maybe_publish(t)
            self._record(t)
            rsp.set(on_time=len(on_time), late=len(late),
                    merged=delta, val_error=m.val_error_curve[-1][2])
            rsp.end(sim_t=t)
        m.sim_time_s = t

    # enhanced: asynchronous with adaptive intervals + compensation --------
    def _run_enhanced(self) -> None:
        cfg, m = self.cfg, self.metrics
        # event queue of (arrival_time, cid) sync messages
        events: List[Tuple[float, int, List[BufferEntry]]] = []
        for c in self.clients:
            c.known_interval = self.scheduler.current
        finished = [False] * len(self.clients)

        def advance(c: _Client) -> None:
            """Run client c until its next sync, pushing the sync event."""
            while c.local_round < cfg.n_rounds:
                dropped = not c.behavior.availability(c.clock)
                e = self._train_one(c)
                c.clock += c.behavior.compute_time(self.BASE_ROUND_S, c.clock)
                c.buffer.add(e.params, e.eps, e.alpha, e.round_stamp)
                if dropped:
                    # stall: the client loses wall-clock, but the dropout
                    # stalls the *message*, not the interval rule — a drop
                    # whose buffered learner fills I_t still syncs (after
                    # the time penalty) rather than deferring the trigger
                    # by a whole extra round.  The behavior decides the
                    # penalty: legacy charges one compute round, an outage
                    # model waits the window out.
                    m.rounds_unavailable += 1
                    c.clock += c.behavior.stall_time(self.BASE_ROUND_S,
                                                     c.clock)
                if len(c.buffer) >= c.known_interval:
                    self._push_sync(events, c)
                    return
            finished[c.cid] = True
            if len(c.buffer):             # flush the tail buffer
                self._push_sync(events, c)

        for c in self.clients:
            advance(c)

        t = 0.0
        while events:
            t, cid, payload = heapq.heappop(events)
            c = self.clients[cid]
            sync_round = c.local_round - 1
            ssp = obs.span(
                "train.sync", sim_t=t, cid=cid, n_entries=len(payload),
                staleness=max((max(0, sync_round - e.round_stamp)
                               for e in payload), default=0))
            merged_before = len(self.ensemble.learners)
            # staleness: rounds the entry waited since it was trained
            # (the freshest entry has stamp == local_round-1 -> tau = 0)
            self._merge(payload, sync_round=sync_round,
                        compensated=True, owner=c.cid)
            m.n_syncs += 1
            obs.count("train.syncs")
            obs.count("train.learners_merged",
                      len(self.ensemble.learners) - merged_before)
            # server observes the new global error and adapts the interval
            self.scheduler.observe(self._val_error())
            # downlink: ensemble delta since this client's last sync
            delta = len(self.ensemble.learners) - c.last_merged_idx
            pkg = delta * 16 + cfg.header_bytes
            m.downlink_bytes += pkg
            m.n_messages += 1
            self._client_catch_up(c)
            c.known_interval = self.scheduler.current
            obs.get_registry().gauge("train.interval").set(
                self.scheduler.current)
            self._maybe_publish(t)
            self._record(t)
            ssp.set(interval=self.scheduler.current,
                    val_error=m.val_error_curve[-1][2])
            ssp.end(sim_t=t)
            if not finished[cid]:
                advance(c)
        m.sim_time_s = max(t, max(c.clock for c in self.clients))

    def _push_sync(self, events, c: _Client) -> None:
        cfg, m = self.cfg, self.metrics
        payload = c.buffer.flush()
        if cfg.relevance_filter > 0 and len(payload) > 1:
            # beyond-paper: don't ship learners whose compensated weight is
            # negligible — the client can compute this locally before uplink
            now = c.local_round - 1
            w = [abs(e.alpha) * math.exp(
                    -cfg.compensation.lam * max(0, now - e.round_stamp))
                 for e in payload]
            cut = cfg.relevance_filter * max(w)
            kept = [e for e, wi in zip(payload, w) if wi >= cut]
            payload = kept if kept else payload[-1:]
        nbytes = (sum(self._entry_bytes(x) for x in payload)
                  + cfg.header_bytes)
        arrival = c.clock + self._tx_time(nbytes, c, c.clock)
        m.uplink_bytes += nbytes
        m.n_messages += 1
        heapq.heappush(events, (arrival, c.cid, payload))

    def _tx_time(self, nbytes: int, c: _Client, t: float) -> float:
        return c.behavior.link(t).tx_time(nbytes)

    def _finalize(self) -> None:
        m = self.metrics
        m.final_val_error = self._val_error()
        xt, yt = self.data["test"]
        if self._test_margin is not None:
            pred = jnp.where(self._test_margin > 0, 1.0, -1.0)
            m.final_test_error = float(jnp.mean(pred != yt))
            pos = yt > 0
            m.final_test_recall = float(
                jnp.sum((pred > 0) & pos) / jnp.maximum(jnp.sum(pos), 1))
