"""Event-driven asynchronous federated AdaBoost simulator.

This is the *faithful* implementation of the paper's algorithm and of the
baseline it compares against, with byte-accurate communication accounting
and a simulated wall-clock that models heterogeneous client compute rates,
link bandwidths, and dropouts.  EXPERIMENTS.md §Paper validates the five
domain scenarios against Table 1 with this engine.

Modes
-----
* ``baseline``  — synchronous distributed AdaBoost: every global round every
  (non-dropped) client trains one weak learner and synchronizes; the round
  completes at the pace of the slowest participant (straggler barrier); no
  weight compensation (stale learners from recovered dropouts enter at full
  vote weight).
* ``enhanced``  — the paper's algorithm: clients proceed at their own pace,
  buffer learners locally, synchronize every I_t rounds where I_t follows
  the adaptive rule (eq. 1), and the server folds buffered learners in with
  delayed weight compensation alpha~ = alpha * exp(-lambda * tau) (eq. 2).

Cost model
----------
Every per-round cost is asked of the client's
:class:`~repro.sim.behavior.ClientBehavior` (the ``behavior_for`` hook):

* compute: ``behavior.compute_time(BASE_ROUND_S, t)`` simulated seconds per
  boosting round; the default :class:`~repro.sim.behavior.LegacyBehavior`
  shim reproduces ``base_round_s * speed_k`` with
  speed_k ~ LogUniform[1, straggler_factor] bit-for-bit.
* uplink: ``bytes / (bandwidth/8 * 1e6) + latency`` per message with
  ``(latency, bandwidth) = behavior.link(t)``; one message per
  synchronization carrying the whole buffer (+ header).
* downlink: ensemble delta (learners merged since the client's last sync)
  broadcast back at sync; the synchronous baseline pays this every round
  for every client.
* availability: a round where ``behavior.availability(t)`` is False is
  missed (legacy shim: i.i.d. dropout with probability p); in baseline its
  learner arrives one round late (stale, uncompensated); in enhanced the
  buffer grows (stale, compensated) and the client stalls by
  ``behavior.stall_time`` — one compute round for the legacy shim, the
  rest of the window for an outage model.

Execution engines
-----------------
``engine="events"`` (the default) runs both modes on the
:mod:`repro.core.events` priority-queue virtual clock: round completions,
stalls, sync triggers, message arrivals, and round barriers are all
events.  Client legs between syncs are *causally closed* — a client
observes server state only at its own sync, and its behavior draws depend
only on its own clock — so each leg's math is evaluated at schedule time
in exactly the legacy call order, which keeps results bit-for-bit
identical to ``engine="loop"`` (the retained client-at-a-time legacy
loops, kept as the golden parity oracle) at equal seeds.

``fleet=True`` (auto-enabled at >= ``FLEET_AUTO_CLIENTS`` clients)
switches the event core to the vectorized fleet profile
(:mod:`repro.core.fleet`): stump fits are deferred and batched into
bucketed ``stump_scan_batched`` launches, and per-sync server math runs
vectorized in numpy.  Communication accounting is integer math and stays
exact; floating-point results match the reference profile up to summation
order.  This is the profile that makes 100k+-client scenarios tractable.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.paper_fedboost import FedBoostConfig
from repro.core import events
from repro.core.boosting import (
    Ensemble, update_distribution, weighted_error)
from repro.core.buffers import BufferEntry, ClientBuffer, entry_wire_bytes
from repro.core.compensation import (
    adaboost_alpha, compensate, staleness_scale)
from repro.core.scheduling import HostScheduler
from repro.models.weak import WeakLearnerSpec, get_weak_learner
from repro.sim.behavior import ClientBehavior, legacy_behaviors


@dataclass
class RunMetrics:
    mode: str
    sim_time_s: float = 0.0
    uplink_bytes: int = 0
    downlink_bytes: int = 0
    n_messages: int = 0
    n_syncs: int = 0
    learners_merged: int = 0
    rounds_to_target: Optional[int] = None
    time_to_target: Optional[float] = None
    snapshots_published: int = 0
    rounds_unavailable: int = 0   # rounds lost to dropout/outage/deep fade
    val_error_curve: List[Tuple[float, int, float]] = field(default_factory=list)
    final_val_error: float = 1.0
    final_test_error: float = 1.0
    final_test_recall: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.downlink_bytes


@dataclass
class _Client:
    cid: int
    x: jnp.ndarray
    y: jnp.ndarray
    # D is None only under the fleet profile, which keeps the whole
    # fleet's distributions stacked in one array (repro.core.fleet)
    D: Optional[jnp.ndarray]
    behavior: ClientBehavior      # availability/compute/link model
    clock: float = 0.0
    local_round: int = 0
    buffer: Optional[ClientBuffer] = None
    known_interval: int = 1
    last_merged_idx: int = 0      # ensemble size at client's last sync

    def __post_init__(self) -> None:
        if self.buffer is None:
            self.buffer = ClientBuffer(self.cid)


class FederatedBoostEngine:
    """Runs one (mode, domain-dataset) federated boosting experiment."""

    BASE_ROUND_S = 1.0            # nominal compute seconds per boosting round
    LATENCY_S = 0.05
    # fleets at/above this size auto-select the vectorized fleet profile
    # (no legacy expectation exists up there — the loop engine never ran
    # fleets beyond a few hundred clients)
    FLEET_AUTO_CLIENTS = 4096

    def __init__(self, cfg: FedBoostConfig, data: Dict, mode: str,
                 weak: Optional[WeakLearnerSpec] = None,
                 kernel_policy=None,
                 behavior_for: Optional[
                     Callable[[int], ClientBehavior]] = None,
                 engine: str = "events",
                 fleet: Optional[bool] = None):
        assert mode in ("baseline", "enhanced")
        assert engine in ("events", "loop")
        self.cfg = cfg
        self.mode = mode
        # engine="events": the event-queue virtual-clock core (default);
        # engine="loop": the legacy client-at-a-time loops, kept as the
        # golden bit-for-bit parity oracle.  fleet=None auto-selects the
        # vectorized fleet profile at FLEET_AUTO_CLIENTS+ clients; the
        # fleet profile always runs on the event core.
        self.engine_kind = engine
        n_fleet = len(data["clients"])
        self._fleet = (bool(fleet) if fleet is not None
                       else n_fleet >= self.FLEET_AUTO_CLIENTS)
        if self._fleet:
            self.engine_kind = "events"
        self.kernel_policy = kernel_policy
        # behavior_for: cid -> ClientBehavior, the client-heterogeneity
        # hook (repro.sim).  None builds the LegacyBehavior shim from the
        # cfg scalars — same RNG draws in the same order, so results at
        # equal seeds are bit-for-bit identical to the pre-behavior engine.
        # kernel_policy: optional repro.kernels.KernelPolicy routing the
        # weak-learner fit through the backend dispatcher (re-resolved per
        # fit, so env/calibration changes apply mid-run); None keeps the
        # jnp oracle.  Ignored when an explicit `weak` spec is supplied.
        self.weak = weak or get_weak_learner(cfg.weak_learner,
                                             policy=kernel_policy)
        self.rng = np.random.RandomState(cfg.seed)
        self.data = data              # {clients: [(x,y)...], val:(x,y), test:(x,y)}
        self.scheduler = HostScheduler(cfg.scheduler)
        self.ensemble = Ensemble()
        self._owners: List[int] = []
        self._round_stamps: List[int] = []   # client-local round per learner
        self.metrics = RunMetrics(mode=mode)
        self._val_margin = None       # running sum alpha~*h over val set
        self._test_margin = None
        self._key = jax.random.key(cfg.seed)
        # serving hook (attach_registry): snapshot publication mid-training
        self._registry = None
        self._tenant: Optional[str] = None
        self._publish_every = 1
        self._syncs_since_publish = 0
        self.audit = None               # obs.ContributionAudit when attached

        n = len(data["clients"])
        if behavior_for is None:
            shims = legacy_behaviors(cfg, n, self.rng,
                                     latency_s=self.LATENCY_S)
            behavior_for = lambda cid: shims[cid]
        self.behavior_for = behavior_for
        self.clients = []
        for cid, (x, y) in enumerate(data["clients"]):
            if self._fleet:
                # the fleet profile owns the distributions as one stacked
                # array; per-client jnp construction at 100k+ clients would
                # cost one device dispatch per client
                self.clients.append(_Client(
                    cid=cid, x=x, y=y, D=None, behavior=behavior_for(cid)))
                continue
            n = x.shape[0]
            if cfg.balanced_init:
                # class-balanced D_0: standard boosting practice for rare-
                # positive domains (IoT anomaly / healthcare diagnosis) —
                # each class carries half the initial distribution mass
                pos = (y > 0).astype(jnp.float32)
                npos = jnp.maximum(jnp.sum(pos), 1.0)
                nneg = jnp.maximum(n - npos, 1.0)
                D = pos / (2 * npos) + (1 - pos) / (2 * nneg)
            else:
                D = jnp.full((n,), 1.0 / n)
            self.clients.append(_Client(
                cid=cid, x=x, y=y, D=D,
                behavior=behavior_for(cid),
                buffer=ClientBuffer(cid)))

    # ------------------------------------------------------- serving hook
    def attach_registry(self, registry, tenant: str,
                        publish_every: int = 1) -> None:
        """Publish an immutable ensemble snapshot after every
        ``publish_every``-th synchronization, stamped with the simulated
        clock — serving hot-swaps versions while training keeps running.

        ``registry`` is either a single-host
        :class:`~repro.serve.registry.EnsembleRegistry` or a sharded
        :class:`~repro.serve.shard.ShardCluster`: the cluster exposes the
        same ``publish`` surface and routes every snapshot to the tenant's
        rendezvous-owning shard, whose subscribers (result-cache
        invalidation, gossip digests) observe it immediately."""
        assert publish_every >= 1
        self._registry = registry
        self._tenant = tenant
        self._publish_every = publish_every
        self._syncs_since_publish = 0

    def attach_audit(self, audit=None):
        """Attach a :class:`repro.obs.ContributionAudit`: every merge in
        either mode/engine records the contributing client's update
        magnitude, validation-error delta, staleness, and outcome.  Pure
        measurement — merge results are bit-identical with or without it.
        The vectorized fleet profile merges whole windows in one launch
        (no per-client error deltas), so audits are refused there."""
        if self._fleet:
            raise ValueError(
                "contribution audits need per-entry merges; the fleet "
                "profile merges vectorized windows — run with "
                "fleet_profile=False to audit")
        if audit is None:
            from repro.obs.audit import ContributionAudit
            audit = ContributionAudit()
        self.audit = audit
        return audit

    @property
    def fleet_profile(self) -> bool:
        """Whether this engine runs the vectorized fleet path."""
        return self._fleet

    def publish(self, clock: float):
        """The publish() hook: snapshot the current global ensemble into
        the attached registry/cluster (the owning shard is notified via
        the routed publish); returns the published snapshot, or None when
        there is nothing to publish yet."""
        if self._registry is None or not self.ensemble.learners:
            return None
        with obs.span("train.publish", sim_t=clock, tenant=self._tenant,
                      n_learners=len(self.ensemble.learners)) as sp:
            snap = self._registry.publish(
                self._tenant, list(self.ensemble.learners),
                list(self.ensemble.alphas), clock=float(clock),
                train_progress=self.metrics.learners_merged,
                weak_name=self.weak.name,
                owners=list(self._owners),
                rounds=list(self._round_stamps))
            sp.set(version=getattr(snap, "version", None))
            sp.end_sim(clock)
        obs.count("train.publishes")
        self.metrics.snapshots_published += 1
        return snap

    def _maybe_publish(self, clock: float) -> None:
        if self._registry is None:
            return
        self._syncs_since_publish += 1
        if self._syncs_since_publish >= self._publish_every:
            self._syncs_since_publish = 0
            self.publish(clock)

    # ------------------------------------------------------------ helpers
    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _train_one(self, c: _Client) -> BufferEntry:
        with obs.span("train.fit", sim_t=c.clock, cid=c.cid,
                      round=c.local_round):
            params = self.weak.fit(c.x, c.y, c.D, self._next_key())
        obs.count("train.fits")
        h = self.weak.predict(params, c.x)
        eps = float(weighted_error(c.D, c.y, h))
        alpha = float(adaboost_alpha(eps))
        # local distribution update with the local (uncompensated) alpha
        c.D, _ = update_distribution(c.D, alpha, c.y, h)
        entry = BufferEntry(params, eps, alpha, c.local_round)
        c.local_round += 1
        return entry

    def _entry_bytes(self, e: BufferEntry) -> int:
        # single source: repro.core.buffers.entry_wire_bytes (the same
        # helper ClientBuffer.nbytes sums over)
        return entry_wire_bytes(e, self.weak.param_bytes)

    def _server_alpha(self, params) -> float:
        """Global vote weight from the learner's error on the server's
        validation distribution.  Local alphas are computed against heavily
        skewed client shards — a near-single-class shard yields eps ~ 0 and
        an unbounded alpha, letting degenerate learners dominate.  Server-
        side re-weighting is the standard distributed-AdaBoost remedy
        (cf. ref [4]'s scalable distributed AdaBoost); both modes use it, so
        the baseline/enhanced comparison isolates the paper's delta."""
        xv, yv = self.data["val"]
        h = self.weak.predict(params, xv)
        pred = jnp.where(h > 0, 1.0, -1.0)
        if self.cfg.balanced_init:
            # balanced error for rare-positive domains: mean of per-class
            # error rates, so majority-voting stumps don't earn large alphas
            pos, neg = yv > 0, yv < 0
            ep = jnp.sum((pred != yv) & pos) / jnp.maximum(jnp.sum(pos), 1)
            en = jnp.sum((pred != yv) & neg) / jnp.maximum(jnp.sum(neg), 1)
            eps = float(jnp.clip(0.5 * (ep + en), 0.02, 0.98))
        else:
            eps = float(jnp.clip(jnp.mean(pred != yv), 0.02, 0.98))
        return float(adaboost_alpha(eps))

    def _merge(self, entries: List[BufferEntry], sync_round: int,
               compensated: bool, owner: int = -1) -> None:
        audit = self.audit
        err_before = (self._val_error()
                      if (audit is not None and entries) else None)
        for e in entries:
            a = self._server_alpha(e.params)
            tau = max(0, sync_round - e.round_stamp)
            if compensated:
                raw = a
                a = float(compensate(a, tau, self.cfg.compensation))
                if obs.enabled():
                    obs.point("train.compensate", cid=owner, staleness=tau,
                              alpha_raw=raw, alpha=a)
            self.ensemble.add(e.params, a)
            self._owners.append(owner)
            self._round_stamps.append(e.round_stamp)
            self._fold_into_margins(e.params, a)
            self.metrics.learners_merged += 1
            if audit is not None:
                # _val_error is a pure read of the folded margins, so the
                # audited run merges bit-identically to the unaudited one
                err_after = self._val_error()
                audit.record(owner, magnitude=abs(a),
                             error_delta=err_before - err_after,
                             staleness=tau, outcome="merged")
                err_before = err_after

    def _fold_into_margins(self, params, alpha: float) -> None:
        xv, _ = self.data["val"]
        xt, _ = self.data["test"]
        hv = self.weak.predict(params, xv) * alpha
        ht = self.weak.predict(params, xt) * alpha
        self._val_margin = hv if self._val_margin is None else self._val_margin + hv
        self._test_margin = ht if self._test_margin is None else self._test_margin + ht

    def _val_error(self) -> float:
        _, yv = self.data["val"]
        if self._val_margin is None:
            return 1.0
        pred = jnp.where(self._val_margin > 0, 1.0, -1.0)
        return float(jnp.mean(pred != yv))

    def _client_catch_up(self, c: _Client) -> None:
        """Apply distribution updates for foreign learners received at sync.
        The client's own learners are skipped — it already applied them
        locally at training time.  ``cfg.catch_up_cap`` bounds the replay
        to the newest ``cap`` foreign learners (None = exact)."""
        lo = c.last_merged_idx
        hi = len(self.ensemble.learners)
        cap = self.cfg.catch_up_cap
        if cap is None:
            idxs = [i for i in range(lo, hi) if self._owners[i] != c.cid]
        else:
            # reverse scan: O(cap + own-entries), never O(window)
            idxs = []
            i = hi - 1
            while i >= lo and len(idxs) < cap:
                if self._owners[i] != c.cid:
                    idxs.append(i)
                i -= 1
            idxs.reverse()
        for i in idxs:
            h = self.weak.predict(self.ensemble.learners[i], c.x)
            c.D, _ = update_distribution(c.D, self.ensemble.alphas[i],
                                         c.y, h)
        c.last_merged_idx = hi

    def _record(self, t: float, err: Optional[float] = None) -> None:
        # the fleet profile passes its numpy-computed error to keep the
        # per-sync hot path off the device
        if err is None:
            err = self._val_error()
        m = self.metrics
        m.val_error_curve.append((t, m.learners_merged, err))
        if (self.cfg.target_error > 0 and err <= self.cfg.target_error
                and m.rounds_to_target is None):
            m.rounds_to_target = m.learners_merged
            m.time_to_target = t

    # ---------------------------------------------------------------- run
    def run(self) -> RunMetrics:
        if self._fleet:
            from repro.core.fleet import FleetCore
            FleetCore(self).run()
        elif self.engine_kind == "events":
            if self.mode == "baseline":
                self._run_baseline_events()
            else:
                self._run_enhanced_events()
        elif self.mode == "baseline":
            self._run_baseline()
        else:
            self._run_enhanced()
        self._finalize()
        return self.metrics

    # baseline: synchronous rounds with straggler barrier ------------------
    def _run_baseline(self) -> None:
        cfg, m = self.cfg, self.metrics
        t = 0.0
        pending_late: List[Tuple[int, BufferEntry]] = []
        for r in range(cfg.n_rounds):
            rsp = obs.span("train.round", sim_t=t, round=r)
            on_time: List[Tuple[int, BufferEntry]] = []
            durations: List[float] = []
            # learners that arrived late from last round's dropouts merge now
            late, pending_late = pending_late, []
            for c in self.clients:
                dropped = not c.behavior.availability(t)
                e = self._train_one(c)
                dur = c.behavior.compute_time(self.BASE_ROUND_S, t)
                if dropped:
                    # misses the barrier; arrives next round, stale by 1,
                    # merged at FULL weight (no compensation in baseline)
                    m.rounds_unavailable += 1
                    pending_late.append((c.cid, e))
                    continue
                up = self._entry_bytes(e) + cfg.header_bytes
                m.uplink_bytes += up
                m.n_messages += 1
                durations.append(dur + self._tx_time(up, c, t))
                on_time.append((c.cid, e))
            # barrier: the round closes at the slowest participant
            t += max(durations) if durations else self.BASE_ROUND_S
            # last round's dropped messages are delivered now: charge their
            # uplink at delivery time (their transfer rides outside the
            # barrier, which only on-time participants set)
            for cid, e in late:
                m.uplink_bytes += self._entry_bytes(e) + cfg.header_bytes
                m.n_messages += 1
            merged_before = len(self.ensemble.learners)
            for cid, e in late + on_time:
                self._merge([e], r, compensated=False, owner=cid)
            # downlink: every client receives the merged delta every round
            delta = len(self.ensemble.learners) - merged_before
            pkg = delta * 16 + cfg.header_bytes
            for c in self.clients:
                m.downlink_bytes += pkg
                m.n_messages += 1
                self._client_catch_up(c)
            m.n_syncs += 1
            obs.count("train.syncs")
            obs.count("train.learners_merged", delta)
            self._maybe_publish(t)
            self._record(t)
            rsp.set(on_time=len(on_time), late=len(late),
                    merged=delta, val_error=m.val_error_curve[-1][2])
            rsp.end(sim_t=t)
        m.sim_time_s = self._flush_late(pending_late, t)

    def _flush_late(self, pending_late: List[Tuple[int, BufferEntry]],
                    t: float) -> float:
        """Deliver the final round's dropped-client messages after the last
        barrier: charge their uplink and fold them into the ensemble (stale
        by one, uncompensated — baseline semantics) instead of silently
        discarding trained-and-counted work.  No downlink or sync tick:
        training is over, nothing is broadcast back.  Returns the simulated
        time the last flush message landed."""
        cfg, m = self.cfg, self.metrics
        if not pending_late:
            return t
        t_flush = t
        for cid, e in pending_late:
            c = self.clients[cid]
            up = self._entry_bytes(e) + cfg.header_bytes
            m.uplink_bytes += up
            m.n_messages += 1
            t_flush = max(t_flush, t + self._tx_time(up, c, t))
        for cid, e in pending_late:
            self._merge([e], cfg.n_rounds, compensated=False, owner=cid)
        if obs.enabled():
            obs.point("train.late_flush", sim_t0=t_flush,
                      n=len(pending_late))
        self._record(t_flush)
        return t_flush

    # enhanced: asynchronous with adaptive intervals + compensation --------
    def _run_enhanced(self) -> None:
        cfg, m = self.cfg, self.metrics
        # event queue of (arrival_time, cid) sync messages
        events: List[Tuple[float, int, List[BufferEntry]]] = []
        for c in self.clients:
            c.known_interval = self.scheduler.current
        finished = [False] * len(self.clients)

        def advance(c: _Client) -> None:
            """Run client c until its next sync, pushing the sync event."""
            while c.local_round < cfg.n_rounds:
                dropped = not c.behavior.availability(c.clock)
                e = self._train_one(c)
                c.clock += c.behavior.compute_time(self.BASE_ROUND_S, c.clock)
                c.buffer.add(e.params, e.eps, e.alpha, e.round_stamp)
                if dropped:
                    # stall: the client loses wall-clock, but the dropout
                    # stalls the *message*, not the interval rule — a drop
                    # whose buffered learner fills I_t still syncs (after
                    # the time penalty) rather than deferring the trigger
                    # by a whole extra round.  The behavior decides the
                    # penalty: legacy charges one compute round, an outage
                    # model waits the window out.
                    m.rounds_unavailable += 1
                    c.clock += c.behavior.stall_time(self.BASE_ROUND_S,
                                                     c.clock)
                if len(c.buffer) >= c.known_interval:
                    self._push_sync(events, c)
                    return
            finished[c.cid] = True
            if len(c.buffer):             # flush the tail buffer
                self._push_sync(events, c)

        for c in self.clients:
            advance(c)

        t = 0.0
        while events:
            t, cid, payload = heapq.heappop(events)
            c = self.clients[cid]
            sync_round = c.local_round - 1
            ssp = obs.span(
                "train.sync", sim_t=t, cid=cid, n_entries=len(payload),
                staleness=max((max(0, sync_round - e.round_stamp)
                               for e in payload), default=0))
            merged_before = len(self.ensemble.learners)
            # staleness: rounds the entry waited since it was trained
            # (the freshest entry has stamp == local_round-1 -> tau = 0)
            self._merge(payload, sync_round=sync_round,
                        compensated=True, owner=c.cid)
            m.n_syncs += 1
            obs.count("train.syncs")
            obs.count("train.learners_merged",
                      len(self.ensemble.learners) - merged_before)
            # server observes the new global error and adapts the interval
            self.scheduler.observe(self._val_error())
            # downlink: ensemble delta since this client's last sync
            delta = len(self.ensemble.learners) - c.last_merged_idx
            pkg = delta * 16 + cfg.header_bytes
            m.downlink_bytes += pkg
            m.n_messages += 1
            self._client_catch_up(c)
            c.known_interval = self.scheduler.current
            obs.get_registry().gauge("train.interval").set(
                self.scheduler.current)
            self._maybe_publish(t)
            self._record(t)
            ssp.set(interval=self.scheduler.current,
                    val_error=m.val_error_curve[-1][2])
            ssp.end(sim_t=t)
            if not finished[cid]:
                advance(c)
        m.sim_time_s = max(t, max(c.clock for c in self.clients))

    # event-queue virtual-clock core (engine="events", the default) -------
    def _run_baseline_events(self) -> None:
        """Synchronous baseline on the event queue: each round is a TRIGGER
        (schedule the fleet's round of work), a set of ARRIVAL events (the
        on-time messages), and a BARRIER (merge + broadcast).  Per-client
        math runs at schedule time in client order — the exact legacy call
        order — and the barrier folds messages in client order (a
        synchronous server treats the round as one batch), so results are
        bit-for-bit identical to the loop engine at equal seeds."""
        cfg, m = self.cfg, self.metrics
        vc = events.VirtualClock()
        pending_late: List[Tuple[int, BufferEntry]] = []
        late: List[Tuple[int, BufferEntry]] = []
        arrived: List[Tuple[int, BufferEntry]] = []
        rsp = None
        t = 0.0
        vc.push(0.0, events.TRIGGER, payload=0)
        while vc:
            ev = vc.pop()
            if ev.kind == events.TRIGGER:
                r, t0 = ev.payload, ev.t
                rsp = obs.span("train.round", sim_t=t0, round=r)
                late, pending_late = pending_late, []
                arrived = []
                durations: List[float] = []
                for c in self.clients:
                    dropped = not c.behavior.availability(t0)
                    e = self._train_one(c)
                    dur = c.behavior.compute_time(self.BASE_ROUND_S, t0)
                    if dropped:
                        # misses the barrier; arrives next round, stale by
                        # 1, merged at FULL weight (no compensation here)
                        m.rounds_unavailable += 1
                        pending_late.append((c.cid, e))
                        if obs.enabled():
                            obs.point("train.stall", sim_t0=t0, cid=c.cid)
                        continue
                    up = self._entry_bytes(e) + cfg.header_bytes
                    m.uplink_bytes += up
                    m.n_messages += 1
                    d = dur + self._tx_time(up, c, t0)
                    durations.append(d)
                    vc.push(t0 + d, events.ARRIVAL, c.cid, e)
                close = t0 + (max(durations) if durations
                              else self.BASE_ROUND_S)
                vc.push(close, events.BARRIER, payload=r)
            elif ev.kind == events.ARRIVAL:
                arrived.append((ev.cid, ev.payload))
            elif ev.kind == events.BARRIER:
                r, t = ev.payload, ev.t
                # delivery-time charge for last round's dropped messages
                for cid, e in late:
                    m.uplink_bytes += self._entry_bytes(e) + cfg.header_bytes
                    m.n_messages += 1
                # merge in client order (not arrival order): the
                # synchronous server folds the whole round as one batch —
                # exactly what the legacy loop does
                arrived.sort(key=lambda ce: ce[0])
                merged_before = len(self.ensemble.learners)
                for cid, e in late + arrived:
                    self._merge([e], r, compensated=False, owner=cid)
                delta = len(self.ensemble.learners) - merged_before
                pkg = delta * 16 + cfg.header_bytes
                for c in self.clients:
                    m.downlink_bytes += pkg
                    m.n_messages += 1
                    self._client_catch_up(c)
                m.n_syncs += 1
                obs.count("train.syncs")
                obs.count("train.learners_merged", delta)
                self._maybe_publish(t)
                self._record(t)
                rsp.set(on_time=len(arrived), late=len(late), merged=delta,
                        val_error=m.val_error_curve[-1][2])
                rsp.end(sim_t=t)
                if r + 1 < cfg.n_rounds:
                    vc.push(t, events.TRIGGER, payload=r + 1)
        obs.count("train.events", vc.n_popped)
        m.sim_time_s = self._flush_late(pending_late, t)

    def _run_enhanced_events(self) -> None:
        """The paper's algorithm on the event queue.  Client legs between
        syncs are causally closed — a client observes server state only at
        its own sync, and its behavior draws depend only on its own clock —
        so each leg's math runs eagerly at schedule time (the legacy call
        order, preserving bit-for-bit parity) while its round completions,
        stalls, triggers, and the sync-message arrival become events.
        Arrivals pop in (t, kind, cid) order: the legacy heap's
        ``(arrival, cid)`` order exactly."""
        cfg, m = self.cfg, self.metrics
        vc = events.VirtualClock()
        for c in self.clients:
            c.known_interval = self.scheduler.current
        finished = [False] * len(self.clients)

        def advance(c: _Client) -> None:
            trace = obs.enabled()
            while c.local_round < cfg.n_rounds:
                dropped = not c.behavior.availability(c.clock)
                e = self._train_one(c)
                c.clock += c.behavior.compute_time(self.BASE_ROUND_S,
                                                   c.clock)
                if trace:
                    vc.push(c.clock, events.ROUND, c.cid)
                c.buffer.add(e.params, e.eps, e.alpha, e.round_stamp)
                if dropped:
                    # see _run_enhanced: the dropout stalls the *message*,
                    # not the interval rule
                    m.rounds_unavailable += 1
                    c.clock += c.behavior.stall_time(self.BASE_ROUND_S,
                                                     c.clock)
                    if trace:
                        vc.push(c.clock, events.STALL, c.cid)
                if len(c.buffer) >= c.known_interval:
                    if trace:
                        vc.push(c.clock, events.TRIGGER, c.cid)
                    arrival, payload = self._prepare_sync(c)
                    vc.push(arrival, events.ARRIVAL, c.cid, payload)
                    return
            finished[c.cid] = True
            if len(c.buffer):             # flush the tail buffer
                arrival, payload = self._prepare_sync(c)
                vc.push(arrival, events.ARRIVAL, c.cid, payload)

        for c in self.clients:
            advance(c)
        t = 0.0
        while vc:
            ev = vc.pop()
            if ev.kind == events.ROUND:
                obs.point("train.client_round", sim_t0=ev.t, cid=ev.cid)
                continue
            if ev.kind == events.STALL:
                obs.point("train.stall", sim_t0=ev.t, cid=ev.cid)
                continue
            if ev.kind == events.TRIGGER:
                obs.point("train.trigger", sim_t0=ev.t, cid=ev.cid)
                continue
            t, cid, payload = ev.t, ev.cid, ev.payload
            c = self.clients[cid]
            sync_round = c.local_round - 1
            ssp = obs.span(
                "train.sync", sim_t=t, cid=cid, n_entries=len(payload),
                staleness=max((max(0, sync_round - e.round_stamp)
                               for e in payload), default=0))
            merged_before = len(self.ensemble.learners)
            self._merge(payload, sync_round=sync_round,
                        compensated=True, owner=c.cid)
            m.n_syncs += 1
            obs.count("train.syncs")
            obs.count("train.learners_merged",
                      len(self.ensemble.learners) - merged_before)
            self.scheduler.observe(self._val_error())
            delta = len(self.ensemble.learners) - c.last_merged_idx
            pkg = delta * 16 + cfg.header_bytes
            m.downlink_bytes += pkg
            m.n_messages += 1
            self._client_catch_up(c)
            c.known_interval = self.scheduler.current
            obs.get_registry().gauge("train.interval").set(
                self.scheduler.current)
            self._maybe_publish(t)
            self._record(t)
            ssp.set(interval=self.scheduler.current,
                    val_error=m.val_error_curve[-1][2])
            ssp.end(sim_t=t)
            if not finished[cid]:
                advance(c)
        obs.count("train.events", vc.n_popped)
        m.sim_time_s = max(t, max(c.clock for c in self.clients))

    def _prepare_sync(self, c: _Client) -> Tuple[float, List[BufferEntry]]:
        """Relevance-filter the buffer, charge the uplink (sized through
        ``ClientBuffer.nbytes`` — the single wire-size source), and return
        the sync message's ``(arrival_time, payload)``."""
        cfg, m = self.cfg, self.metrics
        if cfg.relevance_filter > 0 and len(c.buffer) > 1:
            # beyond-paper: don't ship learners whose compensated weight is
            # negligible — the client can compute this locally before uplink
            now = c.local_round - 1
            entries = c.buffer.entries
            w = [abs(e.alpha) * staleness_scale(
                    max(0, now - e.round_stamp), cfg.compensation)
                 for e in entries]
            cut = cfg.relevance_filter * max(w)
            kept = [e for e, wi in zip(entries, w) if wi >= cut]
            c.buffer.entries = kept if kept else entries[-1:]
        nbytes = c.buffer.nbytes(self.weak.param_bytes) + cfg.header_bytes
        payload = c.buffer.flush()
        arrival = c.clock + self._tx_time(nbytes, c, c.clock)
        m.uplink_bytes += nbytes
        m.n_messages += 1
        return arrival, payload

    def _push_sync(self, events_heap, c: _Client) -> None:
        arrival, payload = self._prepare_sync(c)
        heapq.heappush(events_heap, (arrival, c.cid, payload))

    def _tx_time(self, nbytes: int, c: _Client, t: float) -> float:
        return c.behavior.link(t).tx_time(nbytes)

    def _finalize(self) -> None:
        m = self.metrics
        m.final_val_error = self._val_error()
        xt, yt = self.data["test"]
        if self._test_margin is not None:
            pred = jnp.where(self._test_margin > 0, 1.0, -1.0)
            m.final_test_error = float(jnp.mean(pred != yt))
            pos = yt > 0
            m.final_test_recall = float(
                jnp.sum((pred > 0) & pos) / jnp.maximum(jnp.sum(pos), 1))
