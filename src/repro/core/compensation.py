"""Delayed weight compensation — the paper's eq. (2).

    alpha~_t = alpha_t * exp(-lambda * tau)

where alpha_t = 1/2 ln((1 - eps_t)/eps_t) is the classical AdaBoost vote
weight of weak learner h_t and tau is its staleness in rounds at the moment
the server folds it into the global ensemble.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.paper_fedboost import CompensationConfig

EPS_CLIP = 1e-6


def adaboost_alpha(eps):
    """alpha_t = 1/2 ln((1-eps)/eps), eps clipped away from {0, 1}."""
    eps = jnp.clip(jnp.asarray(eps, jnp.float32), EPS_CLIP, 1.0 - EPS_CLIP)
    return 0.5 * jnp.log((1.0 - eps) / eps)


def compensate(alpha, tau, cfg: CompensationConfig):
    """alpha~ = alpha * exp(-lambda * min(tau, tau_cap)); tau >= 0."""
    tau = jnp.minimum(jnp.asarray(tau, jnp.float32), float(cfg.tau_cap))
    tau = jnp.maximum(tau, 0.0)
    return jnp.asarray(alpha, jnp.float32) * jnp.exp(-cfg.lam * tau)


def compensated_alpha(eps, tau, cfg: CompensationConfig):
    return compensate(adaboost_alpha(eps), tau, cfg)
