"""Delayed weight compensation — the paper's eq. (2) plus the FedAsync
staleness-decay family.

The paper's rule is

    alpha~_t = alpha_t * exp(-lambda * tau)

where alpha_t = 1/2 ln((1 - eps_t)/eps_t) is the classical AdaBoost vote
weight of weak learner h_t and tau is its staleness in rounds at the moment
the server folds it into the global ensemble.  Continuous (per-message)
aggregation generalizes this to alpha~ = alpha * s(tau) with ``s`` drawn
from the FedAsync decay family (Xie et al.; the FLGo ``fedasync``
implementation is the reference):

* ``exp``       s(tau) = exp(-lambda * tau)          — paper eq. (2), default
* ``constant``  s(tau) = 1                           — no decay (FedAsync a=0)
* ``hinge``     s(tau) = 1 if tau <= b else 1/(a*(tau-b))
* ``poly``      s(tau) = (tau + 1)^(-a)

``tau`` is clamped to ``[0, tau_cap]`` for every family, so a pathological
delay can never zero a learner out entirely (nor divide by a huge hinge
denominator).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.configs.paper_fedboost import CompensationConfig

EPS_CLIP = 1e-6
DECAYS = ("exp", "constant", "hinge", "poly")


def adaboost_alpha(eps):
    """alpha_t = 1/2 ln((1-eps)/eps), eps clipped away from {0, 1}."""
    eps = jnp.clip(jnp.asarray(eps, jnp.float32), EPS_CLIP, 1.0 - EPS_CLIP)
    return 0.5 * jnp.log((1.0 - eps) / eps)


def staleness_scale(tau, cfg: CompensationConfig) -> float:
    """s(tau) as a python float — the scalar fast path the fleet-profile
    engine uses so a 100k-sync run never touches the device per merge.
    Matches :func:`compensate` (same clamp, same family)."""
    tau = max(0.0, min(float(tau), float(cfg.tau_cap)))
    decay = cfg.decay
    if decay == "exp":
        return math.exp(-cfg.lam * tau)
    if decay == "constant":
        return 1.0
    if decay == "hinge":
        if tau <= cfg.hinge_b:
            return 1.0
        return 1.0 / (cfg.hinge_a * max(tau - cfg.hinge_b, EPS_CLIP))
    if decay == "poly":
        return (tau + 1.0) ** (-cfg.poly_a)
    raise KeyError(f"unknown staleness decay {decay!r}; one of {DECAYS}")


def compensate(alpha, tau, cfg: CompensationConfig):
    """alpha~ = alpha * s(min(tau, tau_cap)); tau >= 0.

    The ``exp`` branch is kept op-for-op identical to the original eq.-(2)
    implementation, so default-config results stay bit-for-bit stable.
    """
    tau = jnp.minimum(jnp.asarray(tau, jnp.float32), float(cfg.tau_cap))
    tau = jnp.maximum(tau, 0.0)
    alpha = jnp.asarray(alpha, jnp.float32)
    decay = cfg.decay
    if decay == "exp":
        return alpha * jnp.exp(-cfg.lam * tau)
    if decay == "constant":
        return alpha * jnp.ones_like(tau)
    if decay == "hinge":
        scale = jnp.where(
            tau <= cfg.hinge_b, 1.0,
            1.0 / (cfg.hinge_a * jnp.maximum(tau - cfg.hinge_b, EPS_CLIP)))
        return alpha * scale
    if decay == "poly":
        return alpha * (tau + 1.0) ** (-cfg.poly_a)
    raise KeyError(f"unknown staleness decay {decay!r}; one of {DECAYS}")


def compensated_alpha(eps, tau, cfg: CompensationConfig):
    return compensate(adaboost_alpha(eps), tau, cfg)
