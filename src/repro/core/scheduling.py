"""Adaptive communication scheduling — the paper's eq. (1).

    I_{t+1} = I_t + alpha          if  de_t < theta1   (improving fast)
            = max(1, I_t - beta)   if  de_t > theta2   (regressing)
            = I_t                  otherwise
    I_{t+1} clipped to [I_min, I_max]

where de_t = eps_t - eps_{t-1} is the change of the global ensemble error.

Two implementations with identical semantics:

* :func:`adapt_interval` — pure ``jnp`` on scalars, traceable, used inside
  the compiled `fed_mesh` train step (the interval is jit-carried state).
* :class:`HostScheduler` — plain-python mirror for the event-driven
  simulator and for hypothesis property tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax.numpy as jnp

from repro.configs.paper_fedboost import SchedulerConfig


class SchedulerState(NamedTuple):
    interval: jnp.ndarray     # f32 scalar (fractional steps allowed; floor at use)
    prev_error: jnp.ndarray   # f32 scalar, eps_{t-1}
    initialized: jnp.ndarray  # bool scalar (first observation sets prev only)


def _clipped_init(cfg: SchedulerConfig) -> float:
    """i_init clipped into [i_min, i_max] — the invariant eq. (1) maintains
    must hold from construction, not only after the first observation."""
    return min(max(float(cfg.i_init), float(cfg.i_min)), float(cfg.i_max))


def init_state(cfg: SchedulerConfig) -> SchedulerState:
    return SchedulerState(
        interval=jnp.asarray(_clipped_init(cfg), jnp.float32),
        prev_error=jnp.asarray(1.0, jnp.float32),
        initialized=jnp.asarray(False),
    )


def adapt_interval(state: SchedulerState, error, cfg: SchedulerConfig
                   ) -> SchedulerState:
    """One application of eq. (1) given the newly observed global error."""
    error = jnp.asarray(error, jnp.float32)
    de = error - state.prev_error
    inc = state.interval + cfg.alpha
    dec = jnp.maximum(1.0, state.interval - cfg.beta)
    new = jnp.where(de < cfg.theta1, inc,
                    jnp.where(de > cfg.theta2, dec, state.interval))
    new = jnp.clip(new, float(cfg.i_min), float(cfg.i_max))
    # first observation only records eps_{t-1}
    new = jnp.where(state.initialized, new, state.interval)
    return SchedulerState(interval=new, prev_error=error,
                          initialized=jnp.asarray(True))


class HostScheduler:
    """Python mirror of :func:`adapt_interval` for the simulator."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.interval = _clipped_init(cfg)
        self.prev_error = None

    def observe(self, error: float) -> int:
        c = self.cfg
        if self.prev_error is not None:
            de = error - self.prev_error
            if de < c.theta1:
                self.interval += c.alpha
            elif de > c.theta2:
                self.interval = max(1.0, self.interval - c.beta)
            self.interval = min(max(self.interval, float(c.i_min)),
                                float(c.i_max))
        self.prev_error = error
        return int(self.interval)

    @property
    def current(self) -> int:
        return int(self.interval)
