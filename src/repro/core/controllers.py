"""BEYOND-PAPER interval controllers.

The paper's eq. (1) is a bang-bang rule on the raw error delta.  Two
alternatives with the same interface as ``HostScheduler`` (observe(err) ->
interval), compared in ``benchmarks/controller_compare.py``:

* :class:`TrendScheduler` — EMA-smoothed error slope drives a proportional
  interval update: I += g * (target_slope - slope).  Raw per-sync deltas
  are noisy (a single bad learner shrinks the paper rule's interval by
  beta); smoothing should avoid spurious shrinks and reach I_max faster on
  plateaus.
* :class:`BudgetScheduler` — pick the interval that spends a fixed
  communication budget per unit of simulated progress: doubles I whenever
  the (smoothed) error improvement per sync falls below a threshold.
"""
from __future__ import annotations

from repro.configs.paper_fedboost import SchedulerConfig


class TrendScheduler:
    """EMA-slope proportional controller."""

    def __init__(self, cfg: SchedulerConfig, gain: float = 200.0,
                 ema: float = 0.5, target_slope: float = 0.0):
        # target_slope=0 measured best (benchmarks/controller_compare.py):
        # a positive target (drift-up on plateau, like the paper rule's
        # theta_1) was tried and REGRESSED accuracy 0.182->0.253 — the
        # proportional form already widens on sustained improvement and the
        # extra drift over-starves late-stage syncs.
        self.cfg = cfg
        self.interval = float(cfg.i_init)
        self.prev_error = None
        self.slope = 0.0
        self.gain = gain
        self.ema = ema
        self.target = target_slope

    def observe(self, error: float) -> int:
        if self.prev_error is not None:
            de = error - self.prev_error
            self.slope = self.ema * self.slope + (1 - self.ema) * de
            self.interval += self.gain * (self.target - self.slope)
            # pull toward the bang-bang behaviour's bounds
            self.interval = min(max(self.interval, float(self.cfg.i_min)),
                                float(self.cfg.i_max))
        self.prev_error = error
        return int(self.interval)

    @property
    def current(self) -> int:
        return int(self.interval)


class BudgetScheduler:
    """Improvement-per-sync budget controller: if a sync bought less than
    ``min_gain`` error reduction (EMA), double the interval; if it bought a
    regression, halve it."""

    def __init__(self, cfg: SchedulerConfig, min_gain: float = 0.002,
                 ema: float = 0.5):
        self.cfg = cfg
        self.interval = float(cfg.i_init)
        self.prev_error = None
        self.gain_ema = min_gain
        self.min_gain = min_gain
        self.ema = ema

    def observe(self, error: float) -> int:
        if self.prev_error is not None:
            gain = self.prev_error - error          # positive = improved
            self.gain_ema = self.ema * self.gain_ema + (1 - self.ema) * gain
            if self.gain_ema < -self.min_gain:
                self.interval = max(float(self.cfg.i_min), self.interval / 2)
            elif self.gain_ema < self.min_gain:
                self.interval = min(float(self.cfg.i_max), self.interval * 2)
        self.prev_error = error
        return int(self.interval)

    @property
    def current(self) -> int:
        return int(self.interval)
