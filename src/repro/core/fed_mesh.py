"""Mesh-integrated federated async boosting: the paper's technique as a
first-class pjit/shard_map feature of the framework.

Clients are groups along a mesh axis (``data`` single-pod; ``pod`` is the
institution axis in multi-pod mode).  Everything — stump fitting, buffering,
the adaptive interval, compensation, the sync collective — runs *inside*
one compiled step:

* the synchronization interval I_t is jit-carried state; the sync fires via
  ``lax.cond(counter - last_sync >= floor(I_t), sync, local)``.  Because the
  interval/counter are replicated, the predicate is uniform across shards —
  the TPU-idiomatic realisation of "asynchrony" on a synchronous SPMD
  machine (DESIGN.md §4): scheduled skipping of the collective, with
  staleness handled by compensation exactly as in the paper.
* a sync is an ``all_gather`` of the fixed-capacity client buffers over the
  client axis — weak-learner traffic only, exactly the traffic the paper
  schedules.
* the global validation error that drives eq. (1) is a ``psum`` of local
  margin errors over the client axis.

Weak learners here are decision stumps (params = 4 floats), so a buffer of
B stumps from K clients is a (K, B, 4) gather — bytes visible in the HLO
and counted by the §Roofline collective parser.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.configs.paper_fedboost import FedBoostConfig
from repro.core import scheduling
from repro.core.compensation import adaboost_alpha, compensate

Array = jnp.ndarray

if hasattr(jax, "shard_map"):        # jax >= 0.6
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:                                # older jax: experimental home, check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}


class FedMeshState(NamedTuple):
    """Replicated-logical state; leaves with a leading client axis are
    sharded over the client mesh axis."""
    # per-client (leading axis = n_clients, sharded)
    D: Array                 # (K, n_local) sample distributions
    buf_params: Array        # (K, cap, 4) feature,thr,polarity,local_eps
    buf_stamp: Array         # (K, cap) round trained
    buf_count: Array         # (K,) entries in buffer
    # replicated ensemble
    ens_params: Array        # (T_cap, 4)
    ens_alpha: Array         # (T_cap,)
    ens_count: Array         # ()
    # replicated margins of the ensemble on the (sharded) validation slice
    val_margin: Array        # (K, n_val_local)
    # controller
    interval: Array          # () f32
    prev_err: Array          # ()
    counter: Array           # () rounds done
    last_sync: Array         # ()
    sync_count: Array        # ()
    key: Array


def _predict_stumps(params: Array, x: Array) -> Array:
    """params: (M,4); x: (n,F) -> margins (M,n) in {-1,+1}."""
    feat = params[:, 0].astype(jnp.int32)
    thr = params[:, 1]
    pol = params[:, 2]
    xv = x[:, feat]                               # (n, M)
    return (pol[None, :] * jnp.sign(xv - thr[None, :] + 1e-12)).T


def _fit_stump_local(x: Array, y: Array, D: Array, thresholds: Array
                     ) -> Tuple[Array, Array]:
    """Returns (params (4,), eps scalar).  Pure jnp so it shard_maps."""
    pred = jnp.where(x[:, :, None] > thresholds[None, :, :], 1.0, -1.0)
    miss = (pred != y[:, None, None]).astype(jnp.float32)
    err_pos = jnp.einsum("n,nft->ft", D, miss)
    err_neg = 1.0 - err_pos
    i_pos = jnp.argmin(err_pos)
    i_neg = jnp.argmin(err_neg)
    take_pos = err_pos.reshape(-1)[i_pos] <= err_neg.reshape(-1)[i_neg]
    idx = jnp.where(take_pos, i_pos, i_neg)
    f, t = jnp.unravel_index(idx, err_pos.shape)
    pol = jnp.where(take_pos, 1.0, -1.0)
    eps = jnp.where(take_pos, err_pos.reshape(-1)[i_pos],
                    err_neg.reshape(-1)[i_neg])
    return jnp.stack([f.astype(jnp.float32), thresholds[f, t], pol, eps]), eps


def init_state(cfg: FedBoostConfig, n_clients: int, n_local: int,
               n_val_local: int, buffer_cap: int, ens_cap: int,
               key) -> FedMeshState:
    return FedMeshState(
        D=jnp.full((n_clients, n_local), 1.0 / n_local),
        buf_params=jnp.zeros((n_clients, buffer_cap, 4)),
        buf_stamp=jnp.zeros((n_clients, buffer_cap), jnp.int32),
        buf_count=jnp.zeros((n_clients,), jnp.int32),
        ens_params=jnp.zeros((ens_cap, 4)),
        ens_alpha=jnp.zeros((ens_cap,)),
        ens_count=jnp.zeros((), jnp.int32),
        val_margin=jnp.zeros((n_clients, n_val_local)),
        interval=jnp.asarray(scheduling._clipped_init(cfg.scheduler),
                             jnp.float32),
        prev_err=jnp.asarray(1.0, jnp.float32),
        counter=jnp.zeros((), jnp.int32),
        last_sync=jnp.zeros((), jnp.int32),
        sync_count=jnp.zeros((), jnp.int32),
        key=key,
    )


def make_fed_boost_step(cfg: FedBoostConfig, mesh, client_axis: str,
                        thresholds: Array):
    """Builds the compiled federated-boosting round.

    Returns step(state, x, y, xv, yv) -> state where x,y are (K, n, F)/(K, n)
    client shards and xv, yv the sharded validation slices.  All five are
    sharded over `client_axis` on dim 0.
    """
    sch = cfg.scheduler
    comp = cfg.compensation

    def local_round(state: FedMeshState, x, y, xv, yv) -> FedMeshState:
        """One boosting round on every client (no communication)."""

        def per_client(D, x, y):
            params, eps = _fit_stump_local(x, y, D, thresholds)
            margins = _predict_stumps(params[None], x)[0]
            a = adaboost_alpha(eps)
            w = D * jnp.exp(-a * y * margins)
            return params, eps, w / (jnp.sum(w) + 1e-30)

        params, eps, D = jax.vmap(per_client)(state.D, x, y)
        # append to ring buffer
        slot = state.buf_count % state.buf_params.shape[1]

        def append(bufp, bufs, p, s):
            return (bufp.at[s].set(p),
                    bufs.at[s].set(state.counter))

        bufp, bufs = jax.vmap(append)(state.buf_params, state.buf_stamp,
                                      params, slot)
        return state._replace(
            D=D, buf_params=bufp, buf_stamp=bufs,
            buf_count=state.buf_count + 1,
            counter=state.counter + 1)

    def sync(state: FedMeshState, x, y, xv, yv) -> FedMeshState:
        """Synchronization event: gather buffers, compensate, merge, update
        distributions and the adaptive interval."""
        cap = state.buf_params.shape[1]
        K = state.D.shape[0]

        def gather_merge(bufp, bufs, bufc, D, x, y, val_margin, xv, yv):
            # one client per shard along the client axis: strip the local
            # leading dim of 1 (n_clients must equal the axis size)
            bufp, bufs = bufp[0], bufs[0]     # bufc stays (1,): gathers to (K,)
            D, x, y, val_margin, xv, yv = (
                D[0], x[0], y[0], val_margin[0], xv[0], yv[0])
            # ---- collective: buffers cross the client axis here ----
            all_p = jax.lax.all_gather(bufp, client_axis, tiled=True)
            all_s = jax.lax.all_gather(bufs, client_axis, tiled=True)
            all_c = jax.lax.all_gather(bufc, client_axis, tiled=True)
            # (K*cap, 4) / (K*cap,) / (K,)
            flat_p = all_p.reshape(K * cap, 4)
            flat_s = all_s.reshape(K * cap)
            idx_in_buf = jnp.tile(jnp.arange(cap), K)
            valid = idx_in_buf < jnp.repeat(all_c, cap)
            # ownership: this client's own learners were already applied to
            # its local distribution at training time (full local alpha) —
            # skip them in the merged D update or they count twice
            owner = jnp.repeat(jnp.arange(K), cap)
            own = owner == jax.lax.axis_index(client_axis)

            # server-side alpha on the *global* validation distribution:
            # margins on local val slice, errors psum'd over clients
            mv = _predict_stumps(flat_p, xv)              # (M, n_val_local)
            yv_b = yv[None, :]
            local_miss = jnp.sum((jnp.where(mv > 0, 1.0, -1.0) != yv_b)
                                 .astype(jnp.float32), axis=1)
            local_n = jnp.asarray(yv.shape[0], jnp.float32)
            miss = jax.lax.psum(local_miss, client_axis)
            n_val = jax.lax.psum(local_n, client_axis)
            eps_srv = jnp.clip(miss / n_val, 0.02, 0.98)
            alpha = adaboost_alpha(eps_srv)
            tau = (state.counter - flat_s).astype(jnp.float32)
            alpha_t = jnp.where(
                valid, compensate(alpha, tau, comp), 0.0)     # (M,)

            # fold into replicated ensemble arrays
            base = state.ens_count
            pos = base + jnp.cumsum(valid.astype(jnp.int32)) - 1
            # invalid entries -> out-of-range sentinel, dropped by scatter
            pos = jnp.where(valid, pos, state.ens_params.shape[0])
            ens_p = state.ens_params.at[pos].set(flat_p, mode="drop")
            ens_a = state.ens_alpha.at[pos].set(alpha_t, mode="drop")
            n_new = jnp.sum(valid.astype(jnp.int32))

            # distribution update on local shard with the FOREIGN merged
            # learners (own ones already applied locally at training time)
            mx = _predict_stumps(flat_p, x)                # (M, n)
            upd = jnp.exp(-(alpha_t[:, None]) * y[None, :] * mx)
            use = valid & ~own
            D = D * jnp.prod(jnp.where(use[:, None], upd, 1.0), axis=0)
            D = D / (jnp.sum(D) + 1e-30)

            # update the running validation margin + global error
            val_margin = val_margin + jnp.sum(
                jnp.where(valid[:, None], alpha_t[:, None] * mv, 0.0), axis=0)
            vm_pred = jnp.where(val_margin > 0, 1.0, -1.0)
            loc_err = jnp.sum((vm_pred != yv).astype(jnp.float32))
            g_err = jax.lax.psum(loc_err, client_axis) / n_val
            return (ens_p, ens_a, n_new, D[None], val_margin[None], g_err)

        specs_in = (P(client_axis), P(client_axis), P(client_axis),
                    P(client_axis), P(client_axis), P(client_axis),
                    P(client_axis), P(client_axis), P(client_axis))
        specs_out = (P(), P(), P(), P(client_axis), P(client_axis), P())
        ens_p, ens_a, n_new, D, val_margin, g_err = _shard_map(
            gather_merge, mesh=mesh, in_specs=specs_in, out_specs=specs_out,
            **_SHARD_MAP_KW)(
                state.buf_params, state.buf_stamp, state.buf_count,
                state.D, x, y, state.val_margin, xv, yv)

        # adaptive interval (eq. 1) on the new global error
        st = scheduling.SchedulerState(state.interval, state.prev_err,
                                       jnp.asarray(True))
        st = scheduling.adapt_interval(st, g_err, sch)

        return state._replace(
            D=D,
            buf_params=jnp.zeros_like(state.buf_params),
            buf_stamp=jnp.zeros_like(state.buf_stamp),
            buf_count=jnp.zeros_like(state.buf_count),
            ens_params=ens_p, ens_alpha=ens_a,
            ens_count=state.ens_count + n_new,
            val_margin=val_margin,
            interval=st.interval, prev_err=st.prev_error,
            last_sync=state.counter,
            sync_count=state.sync_count + 1)

    def step(state: FedMeshState, x, y, xv, yv) -> FedMeshState:
        state = local_round(state, x, y, xv, yv)
        due = (state.counter - state.last_sync) >= jnp.floor(state.interval
                                                             ).astype(jnp.int32)
        return jax.lax.cond(due, sync, lambda s, *a: s, state, x, y, xv, yv)

    return step


def publish_snapshot(state: FedMeshState, registry, tenant: str, *,
                     clock: float = 0.0):
    """Host-side publish() hook: snapshot the replicated ensemble arrays of
    a (possibly mid-training) :class:`FedMeshState` into a serving
    :class:`~repro.serve.registry.EnsembleRegistry` — or into a sharded
    :class:`~repro.serve.shard.ShardCluster`, whose ``publish_packed``
    routes the snapshot to the tenant's rendezvous-owning shard so that
    host's subscribers (cache invalidation, gossip digest) see the new
    version before any anti-entropy round runs.

    ``ens_params`` is already the packed ``(T, 4)`` stump wire format, so
    this is a device_get + slice — the compiled train step never blocks on
    serving, and readers only ever see the frozen snapshot."""
    n = int(jax.device_get(state.ens_count))
    params = jnp.asarray(jax.device_get(state.ens_params)[:n])
    alphas = jnp.asarray(jax.device_get(state.ens_alpha)[:n])
    with obs.span("train.publish", sim_t=clock, tenant=tenant,
                  n_learners=n) as sp:
        snap = registry.publish_packed(
            tenant, params, alphas, clock=float(clock),
            train_progress=int(jax.device_get(state.counter)))
        sp.set(version=getattr(snap, "version", None))
        sp.end_sim(clock)
    obs.count("train.publishes")
    return snap


def state_shardings(mesh, client_axis: str) -> FedMeshState:
    """PartitionSpecs for FedMeshState (client-axis leaves sharded)."""
    c = P(client_axis)
    r = P()
    return FedMeshState(
        D=c, buf_params=c, buf_stamp=c, buf_count=c,
        ens_params=r, ens_alpha=r, ens_count=r,
        val_margin=c, interval=r, prev_err=r, counter=r,
        last_sync=r, sync_count=r, key=r)
