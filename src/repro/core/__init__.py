# The paper's primary contribution: adaptive communication scheduling,
# delayed weight compensation, buffer-based sync — plus the async federated
# boosting engine and the mesh-integrated (pjit/shard_map) variant.
from repro.core.scheduling import (  # noqa: F401
    SchedulerState, adapt_interval, init_state, HostScheduler)
from repro.core.compensation import (  # noqa: F401
    adaboost_alpha, compensate, compensated_alpha)
from repro.core.boosting import (  # noqa: F401
    Ensemble, fit_adaboost, weighted_error, update_distribution,
    ensemble_margin, ensemble_predict, accuracy)
from repro.core.async_engine import FederatedBoostEngine, RunMetrics  # noqa: F401
