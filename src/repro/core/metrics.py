"""Metric helpers shared by the engine, benchmarks and tests."""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple


def time_to_error(curve: Sequence[Tuple[float, int, float]],
                  target: float) -> Optional[Tuple[float, int]]:
    """First (time, learners) at which the validation error <= target."""
    for t, n, e in curve:
        if e <= target:
            return t, n
    return None


def common_target(curves: Sequence[Sequence[Tuple[float, int, float]]],
                  slack: float = 1.05) -> float:
    """A target error both runs reach: slack x the worse final error."""
    finals = [c[-1][2] for c in curves if c]
    return max(finals) * slack


def pct_reduction(base: float, new: float) -> float:
    """Positive = improvement (reduction) relative to baseline."""
    if base == 0:
        return 0.0
    return 100.0 * (1.0 - new / base)
