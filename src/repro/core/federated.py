"""Gradient-averaging FL baselines the paper positions itself against:
FedAvg (McMahan et al., 2017) and FedAsync (Xie et al., 2019).

These train a shared neural model (tiny MLP by default) instead of a
boosted ensemble; the benchmark suite compares them against the enhanced
async AdaBoost on the same domain datasets (accuracy vs bytes-on-wire),
reproducing the paper's framing that *learner* traffic is far cheaper than
*gradient/weight* traffic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

Params = Dict[str, jnp.ndarray]


def mlp_init(key, n_features: int, hidden: int = 32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (n_features, hidden)) / math.sqrt(n_features),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, 1)) / math.sqrt(hidden),
        "b2": jnp.zeros((1,)),
    }


def mlp_forward(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return (h @ p["w2"] + p["b2"])[:, 0]


def bce_loss(p: Params, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logits = mlp_forward(p, x)
    y01 = (y + 1.0) / 2.0
    return jnp.mean(jnp.maximum(logits, 0) - logits * y01
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


import functools


@functools.partial(jax.jit, static_argnames=("lr", "steps"))
def local_sgd(params: Params, x, y, lr: float = 0.1, steps: int = 10):
    def step(p, _):
        g = jax.grad(bce_loss)(p, x, y)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), None
    out, _ = jax.lax.scan(step, params, None, length=steps)
    return out


def params_bytes(p: Params) -> int:
    return int(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(p)))


@dataclass
class FedAvgMetrics:
    mode: str
    sim_time_s: float = 0.0
    uplink_bytes: int = 0
    downlink_bytes: int = 0
    n_messages: int = 0
    final_test_error: float = 1.0
    error_curve: List[Tuple[float, float]] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.downlink_bytes


def run_fedavg(data: Dict, n_rounds: int = 30, lr: float = 0.1,
               local_steps: int = 10, seed: int = 0,
               straggler_factor: float = 4.0, link_mbps: float = 10.0,
               header_bytes: int = 256) -> FedAvgMetrics:
    """Synchronous FedAvg with the same cost model as the boosting engine."""
    rng = np.random.RandomState(seed)
    clients = data["clients"]
    K = len(clients)
    speeds = np.exp(rng.uniform(0, math.log(straggler_factor), size=K))
    key = jax.random.key(seed)
    params = mlp_init(key, clients[0][0].shape[1])
    pbytes = params_bytes(params)
    m = FedAvgMetrics(mode="fedavg")
    t = 0.0
    xt, yt = data["test"]
    for r in range(n_rounds):
        rsp = obs.span("train.round", sim_t=t, round=r, mode="fedavg")
        locs, durs = [], []
        for k, (x, y) in enumerate(clients):
            locs.append(local_sgd(params, x, y, lr, local_steps))
            tx = (pbytes + header_bytes) / (link_mbps / 8 * 1e6) + 0.05
            durs.append(1.0 * speeds[k] + tx)
            m.uplink_bytes += pbytes + header_bytes
            m.n_messages += 1
        t += max(durs)
        params = jax.tree.map(lambda *xs: sum(xs) / K, *locs)
        m.downlink_bytes += K * (pbytes + header_bytes)
        m.n_messages += K
        err = float(jnp.mean(jnp.sign(mlp_forward(params, xt)) != yt))
        m.error_curve.append((t, err))
        rsp.set(val_error=err)
        rsp.end(sim_t=t)
    m.sim_time_s = t
    m.final_test_error = m.error_curve[-1][1]
    return m


def run_fedasync(data: Dict, n_rounds: int = 30, lr: float = 0.1,
                 local_steps: int = 10, seed: int = 0, mix: float = 0.5,
                 staleness_decay: float = 0.3,
                 straggler_factor: float = 4.0, link_mbps: float = 10.0,
                 header_bytes: int = 256) -> FedAvgMetrics:
    """FedAsync (Xie et al., 2019): server mixes each arriving update with
    weight mix * s(tau), s polynomial in staleness."""
    import heapq
    rng = np.random.RandomState(seed)
    clients = data["clients"]
    K = len(clients)
    speeds = np.exp(rng.uniform(0, math.log(straggler_factor), size=K))
    key = jax.random.key(seed)
    params = mlp_init(key, clients[0][0].shape[1])
    pbytes = params_bytes(params)
    m = FedAvgMetrics(mode="fedasync")
    xt, yt = data["test"]

    server_version = 0
    events = []   # (arrival, client, version_at_start, local_params)
    clocks = np.zeros(K)

    def schedule(k: int, t0: float):
        x, y = clients[k]
        loc = local_sgd(params, x, y, lr, local_steps)
        tx = (pbytes + header_bytes) / (link_mbps / 8 * 1e6) + 0.05
        heapq.heappush(events, (t0 + speeds[k] + tx, k, server_version, loc))
        m.uplink_bytes += pbytes + header_bytes
        m.n_messages += 1

    for k in range(K):
        schedule(k, 0.0)
    merges, t = 0, 0.0
    while events and merges < n_rounds * K:
        t, k, v0, loc = heapq.heappop(events)
        tau = server_version - v0
        w = mix * (1.0 + tau) ** (-staleness_decay)
        params = jax.tree.map(lambda a, b: (1 - w) * a + w * b, params, loc)
        server_version += 1
        merges += 1
        if obs.enabled():
            obs.point("train.sync", sim_t0=t, sim_t1=t, cid=int(k),
                      staleness=int(tau), mode="fedasync")
        m.downlink_bytes += pbytes + header_bytes
        m.n_messages += 1
        if merges % K == 0:
            err = float(jnp.mean(jnp.sign(mlp_forward(params, xt)) != yt))
            m.error_curve.append((t, err))
        clocks[k] = t
        schedule(k, t)
    m.sim_time_s = t
    m.final_test_error = (m.error_curve[-1][1] if m.error_curve else 1.0)
    return m
