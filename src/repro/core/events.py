"""Priority-queue virtual clock for the event-driven engine core.

Everything that happens in a simulated federation is an :class:`Event` on
one :class:`VirtualClock` (FLGo's ``ElemClock`` is the shape we follow):
client round completions, dropout/outage stalls, sync-message arrivals at
the server, client-side sync triggers, and the synchronous baseline's
round barriers.  The clock is a heap ordered by the total key

    (t, kind, cid, seq)

which pins a *deterministic* pop order even when events tie on arrival
time: earlier virtual time first, then event kind (arrivals drain before
the barrier that closes over them), then client id (two sync messages
landing at the same instant merge in client order — exactly the legacy
engine's ``(arrival, cid)`` heap order), then push order as the final
tie-break.  Payloads never participate in comparisons, so they may be
arbitrary (and mutable) objects.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

# Event kinds, in pop-priority order at equal virtual time.  ROUND/STALL
# are trace markers (they carry no server state change); TRIGGER marks a
# client-side buffer-full decision; ARRIVAL is a sync message reaching the
# server; BARRIER closes a synchronous baseline round — it must pop after
# every arrival it closes over, hence the largest kind.
ROUND = 0       # a client finished one local boosting round
STALL = 1       # a dropout/outage stall ended
TRIGGER = 2     # client-side sync trigger (buffer reached I_t)
ARRIVAL = 3     # sync message arrived at the server
BARRIER = 4     # synchronous round barrier closed

KIND_NAMES = {ROUND: "round", STALL: "stall", TRIGGER: "trigger",
              ARRIVAL: "arrival", BARRIER: "barrier"}


@dataclass(frozen=True)
class Event:
    """One scheduled occurrence on the virtual clock."""
    t: float
    kind: int
    cid: int          # owning client, or -1 for server/global events
    seq: int          # monotonically increasing push counter
    payload: Any = None

    @property
    def kind_name(self) -> str:
        return KIND_NAMES.get(self.kind, str(self.kind))


class VirtualClock:
    """Min-heap of events with a monotone ``now`` and pinned tie-breaks."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, int, int, Any]] = []
        self._seq = 0
        self.now = 0.0
        self.n_pushed = 0
        self.n_popped = 0

    def push(self, t: float, kind: int, cid: int = -1,
             payload: Any = None) -> Event:
        """Schedule an event at virtual time ``t`` (>= now)."""
        seq = self._seq
        self._seq += 1
        heapq.heappush(self._heap, (float(t), kind, cid, seq, payload))
        self.n_pushed += 1
        return Event(float(t), kind, cid, seq, payload)

    def pop(self) -> Event:
        """Remove and return the next event; advances ``now`` monotonically."""
        t, kind, cid, seq, payload = heapq.heappop(self._heap)
        self.n_popped += 1
        if t > self.now:
            self.now = t
        return Event(t, kind, cid, seq, payload)

    def peek(self) -> Optional[Event]:
        if not self._heap:
            return None
        t, kind, cid, seq, payload = self._heap[0]
        return Event(t, kind, cid, seq, payload)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
