"""Activation-sharding context: lets model code pin activation shardings
(batch -> data axes, vocab/heads -> model axis) without a hard dependency
on the launch layer.

The launcher/dry-run installs a context (batch axes + model axis); model
forward passes call :func:`constrain` at anchor points (embedding output,
per-period carry, logits).  Without an installed context — unit tests,
single-device runs — constrain is a no-op, so the model code runs anywhere.

This is the standard fix for XLA SPMD propagation drift: with only
input/output shardings on a rematerialized scan-over-layers graph, the
partitioner can decide to gather the batch onto every device mid-graph
(observed: (256, 4096, vocab/16) all-gathers in the qwen1.5 train HLO —
global batch materialized per device).  Anchoring the carry kills that
family of solutions.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def current() -> Optional[dict]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def activation_sharding(batch_axes: Tuple[str, ...] = ("data",),
                        model_axis: str = "model",
                        batch_shardable: bool = True,
                        mesh=None, fsdp_axis: Optional[str] = "data"):
    prev = current()
    _STATE.ctx = {"batch": batch_axes if batch_shardable else None,
                  "model": model_axis, "mesh": mesh, "fsdp": fsdp_axis,
                  "all_batch_axes": batch_axes}
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_mesh():
    ctx = current()
    return ctx.get("mesh") if ctx else None


def constrain(x, kind: str):
    """kind: 'btd' (batch, seq, d_model) | 'btv' (batch, seq, vocab) |
    'bv' (batch, vocab) | 'bd' (batch, d_model)."""
    ctx = current()
    if ctx is None:
        return x
    b, m = ctx["batch"], ctx["model"]
    spec = {
        "btd": P(b, None, None),
        "btv": P(b, None, m),
        "bv": P(b, m),
        "bd": P(b, None),
        "b2": P(b, None),
        "b3": P(b, None, None),
        "b4": P(b, None, None, None),
    }[kind]
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x
