from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, adam, adamw, get_optimizer, clip_by_global_norm,
    global_norm, constant_schedule, cosine_schedule, linear_schedule)
