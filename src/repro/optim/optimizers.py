"""Optimizers from scratch (no optax): SGD, momentum, Adam, AdamW, with
global-norm clipping and LR schedules.  The optimizer-state dtype is
configurable — the dry-run uses bfloat16 moments so the 398B-parameter
hybrid fits the pod HBM budget (DESIGN.md §6); CPU training uses float32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], PyTree]
    update: Callable[[PyTree, Params, PyTree, jnp.ndarray], Tuple[Params, PyTree]]
    # update(grads, params, state, step) -> (new_params, new_state)


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return sched


def linear_schedule(lr: float, warmup: int, total: int) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        return jnp.where(step < warmup, warm, lr * (1 - frac))
    return sched


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def sgd(lr: Callable | float, momentum: float = 0.0,
        clip_norm: Optional[float] = None,
        state_dtype=jnp.float32) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, state_dtype), params)}

    def update(grads, params, state, step):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        lr_t = sched(step)
        if momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new, state
        m = jax.tree.map(
            lambda mm, g: (momentum * mm.astype(jnp.float32)
                           + g.astype(jnp.float32)).astype(state_dtype),
            state["m"], grads)
        new = jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32)
                           - lr_t * mm.astype(jnp.float32)).astype(p.dtype),
            params, m)
        return new, {"m": m}

    return Optimizer(init, update)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0,
          state_dtype=jnp.float32) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, params, state, step):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** step_f
        bc2 = 1.0 - b2 ** step_f

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m1 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v1 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mhat = m1 / bc1
            vhat = v1 / bc2
            p32 = p.astype(jnp.float32)
            step_d = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32
            return ((p32 - lr_t * step_d).astype(p.dtype),
                    m1.astype(state_dtype), v1.astype(state_dtype))

        flat, treedef = jax.tree.flatten(params)
        gflat = jax.tree.leaves(grads)
        mflat = jax.tree.leaves(state["m"])
        vflat = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat, gflat, mflat, vflat)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adam(lr, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "momentum":
        return sgd(lr, momentum=kw.pop("momentum", 0.9), **kw)
    if name == "adam":
        return adam(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise KeyError(name)
