"""Multi-tenant ensemble registry: immutable, versioned serving snapshots.

Training (the event-driven :class:`~repro.core.async_engine.FederatedBoostEngine`
or the compiled :mod:`~repro.core.fed_mesh` step) publishes a snapshot of the
current global ensemble whenever it merges learners; serving reads whatever
the latest snapshot is.  Because a snapshot is a frozen value built *before*
the registry pointer is swapped (under a lock), readers never observe a
half-merged ensemble, and training never blocks on serving traffic.

Stump ensembles — the paper's weak learner and the ``fed_mesh`` wire format —
are stored packed as a ``(T, 4)`` float array (feature, threshold, polarity,
spare), which feeds the fused ``stump_vote_batched`` Pallas kernel directly.
Generic weak learners (logistic / mlp) keep their parameter pytrees and go
through the per-learner-predict + ``ensemble_vote_batched`` path instead.
"""
from __future__ import annotations

import functools
import hashlib
import threading
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class EnsembleSnapshot:
    """One immutable published version of a tenant's ensemble."""
    tenant: str
    version: int               # monotonically increasing per tenant, from 1
    published_at: float        # publisher's clock (sim seconds or wall time)
    train_progress: int        # learners merged / rounds done when published
    weak_name: str             # weak-learner family ("stump" | "logistic" | ...)
    alphas: jnp.ndarray        # (T,) f32 compensated vote weights
    stump_params: Optional[jnp.ndarray] = None   # (T, 4) packed stump fast path
    learners: Tuple = ()       # generic params pytrees (non-stump families)

    @property
    def n_learners(self) -> int:
        return int(self.alphas.shape[0])

    @functools.cached_property
    def fingerprint(self) -> str:
        """Content digest (version/clock excluded): two concurrently gossiped
        snapshots claiming the same version number are 'the same' iff their
        fingerprints match — the shard reconciler compares these.  Cached
        per instance (cached_property writes straight into ``__dict__``,
        which the frozen dataclass allows) — gossip digests re-read it
        every exchange and the payload hash isn't free."""
        h = hashlib.blake2b(digest_size=12)
        h.update(self.weak_name.encode())
        h.update(np.int64(self.train_progress).tobytes())
        h.update(np.ascontiguousarray(self.alphas, np.float32).tobytes())
        if self.stump_params is not None:
            h.update(np.ascontiguousarray(self.stump_params,
                                          np.float32).tobytes())
        for leaf in jax.tree_util.tree_leaves(self.learners):
            h.update(np.ascontiguousarray(leaf).tobytes())
        return h.hexdigest()


def pack_stumps(learners: Sequence[Dict]) -> jnp.ndarray:
    """Pack stump param dicts {feature, threshold, polarity} -> (T, 4) f32."""
    if not learners:
        return jnp.zeros((0, 4), jnp.float32)
    rows = [jnp.stack([jnp.asarray(p["feature"], jnp.float32),
                       jnp.asarray(p["threshold"], jnp.float32),
                       jnp.asarray(p["polarity"], jnp.float32),
                       jnp.zeros((), jnp.float32)])
            for p in learners]
    return jnp.stack(rows)


class EnsembleRegistry:
    """Thread-safe tenant -> snapshot-history map (bounded history).

    ``publish*`` builds the immutable snapshot outside the lock and swaps it
    in atomically; ``latest``/``get`` return whatever version is current —
    serving hot-swaps ensembles without ever blocking a publisher.
    """

    def __init__(self, history: int = 4):
        assert history >= 1
        self._history = history
        self._lock = threading.Lock()
        self._snaps: Dict[str, List[EnsembleSnapshot]] = {}
        self._subscribers: List[Callable[[EnsembleSnapshot], None]] = []

    # ---------------------------------------------------------- subscribers
    def subscribe(self, fn: Callable[[EnsembleSnapshot], None]
                  ) -> Callable[[], None]:
        """Register ``fn(snapshot)`` to run after every snapshot that becomes
        a tenant's latest — local publishes, gossip ingests, and concurrent-
        version replacements alike.  Callbacks run outside the registry lock
        (a subscriber may read the registry), in subscription order; the
        result cache invalidates through exactly this hook.  Returns a
        zero-arg unsubscribe handle (idempotent) so short-lived servers
        don't pin their caches on a long-lived registry."""
        self._subscribers.append(fn)

        def unsubscribe() -> None:
            try:
                self._subscribers.remove(fn)
            except ValueError:
                pass
        return unsubscribe

    def _notify(self, snap: EnsembleSnapshot) -> None:
        for fn in self._subscribers:
            fn(snap)

    # ------------------------------------------------------------- publish
    def publish(self, tenant: str, learners: Sequence, alphas: Sequence[float],
                *, clock: float = 0.0, train_progress: int = 0,
                weak_name: str = "stump", owners: Optional[Sequence[int]] = None,
                rounds: Optional[Sequence[int]] = None) -> EnsembleSnapshot:
        """Publish from a list of weak-learner params + vote weights (the
        :class:`Ensemble` representation the async engine grows).

        ``owners``/``rounds`` are per-learner provenance metadata
        (contributing client id + client-local round).  The central
        registry ignores them — a snapshot here is already the aggregated
        truth — but the chain-of-record registry
        (:class:`repro.chain.registry.ChainRegistry`) exposes the same
        signature and commits them on chain for ``provenance()``."""
        learners = list(learners)
        alphas = jnp.asarray(list(alphas), jnp.float32)
        if len(learners) != alphas.shape[0]:
            raise ValueError(
                f"publish({tenant!r}): {len(learners)} learners vs "
                f"{alphas.shape[0]} alphas — refusing a mismatched snapshot")
        if weak_name == "stump":
            return self.publish_packed(
                tenant, pack_stumps(list(learners)), alphas, clock=clock,
                train_progress=train_progress)
        snap = self._stamp(tenant, EnsembleSnapshot(
            tenant=tenant, version=0, published_at=float(clock),
            train_progress=int(train_progress), weak_name=weak_name,
            alphas=alphas, stump_params=None, learners=tuple(learners)))
        return snap

    def publish_packed(self, tenant: str, stump_params: jnp.ndarray,
                       alphas: jnp.ndarray, *, clock: float = 0.0,
                       train_progress: int = 0,
                       owners: Optional[Sequence[int]] = None,
                       rounds: Optional[Sequence[int]] = None
                       ) -> EnsembleSnapshot:
        """Publish a packed (T, 4) stump ensemble — the fed_mesh wire format."""
        stump_params = jnp.asarray(stump_params, jnp.float32)
        alphas = jnp.asarray(alphas, jnp.float32)
        assert stump_params.shape == (alphas.shape[0], 4), (
            stump_params.shape, alphas.shape)
        return self._stamp(tenant, EnsembleSnapshot(
            tenant=tenant, version=0, published_at=float(clock),
            train_progress=int(train_progress), weak_name="stump",
            alphas=alphas, stump_params=stump_params))

    def _stamp(self, tenant: str, snap: EnsembleSnapshot) -> EnsembleSnapshot:
        with self._lock:
            hist = self._snaps.setdefault(tenant, [])
            snap = replace(snap, version=(hist[-1].version + 1 if hist else 1))
            hist.append(snap)
            del hist[:-self._history]
        self._notify(snap)
        return snap

    # ---------------------------------------------------- gossip interface
    def digest(self) -> Dict[str, Tuple[int, str]]:
        """Version vector: tenant -> (latest version, content fingerprint).
        Anti-entropy peers exchange digests and pull only what they miss."""
        with self._lock:
            latest = {t: h[-1] for t, h in self._snaps.items() if h}
        return {t: (s.version, s.fingerprint) for t, s in latest.items()}

    def ingest(self, snap: EnsembleSnapshot) -> bool:
        """Adopt a snapshot gossiped from another host, *keeping its version
        stamp* (unlike ``publish``, which assigns the next local version).
        Out-of-date or already-held versions are dropped; returns True iff
        the registry changed.  Subscribers fire only when the snapshot
        became the tenant's new latest."""
        with self._lock:
            hist = self._snaps.setdefault(snap.tenant, [])
            if any(s.version == snap.version for s in hist):
                return False
            if hist and snap.version < hist[-1].version - self._history + 1:
                return False            # older than the retained window
            hist.append(snap)
            hist.sort(key=lambda s: s.version)
            del hist[:-self._history]
            became_latest = hist[-1] is snap
        if became_latest:
            self._notify(snap)
        return True

    def replace_latest(self, tenant: str,
                       snap: EnsembleSnapshot) -> EnsembleSnapshot:
        """Swap the tenant's latest snapshot for a concurrent same-version
        snapshot the gossip reconciler ranked higher.  The version number
        must match the current latest (reconciliation never moves the
        version vector backwards)."""
        with self._lock:
            hist = self._snaps.get(tenant)
            assert hist and hist[-1].version == snap.version, (
                tenant, snap.version)
            hist[-1] = snap
        self._notify(snap)
        return snap

    # --------------------------------------------------------------- reads
    def latest(self, tenant: str) -> Optional[EnsembleSnapshot]:
        with self._lock:
            hist = self._snaps.get(tenant)
            return hist[-1] if hist else None

    def get(self, tenant: str, version: Optional[int] = None
            ) -> Optional[EnsembleSnapshot]:
        if version is None:
            return self.latest(tenant)
        with self._lock:
            for s in self._snaps.get(tenant, ()):
                if s.version == version:
                    return s
        return None

    def history(self, tenant: str) -> List[EnsembleSnapshot]:
        """The retained snapshot window, oldest first (gossip peers pull
        whole windows so cross-host ``get(tenant, version)`` works too)."""
        with self._lock:
            return list(self._snaps.get(tenant, ()))

    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._snaps)

    def version_count(self, tenant: str) -> int:
        """Total versions ever published for a tenant (not history length)."""
        s = self.latest(tenant)
        return s.version if s else 0

    def staleness(self, tenant: str, now: float) -> float:
        """Seconds since the tenant's serving snapshot was published (the
        snapshot-freshness analogue of the paper's staleness tau)."""
        s = self.latest(tenant)
        return max(0.0, float(now) - s.published_at) if s else float("inf")

    def rebase_clock(self, clock: float = 0.0) -> None:
        """Re-stamp publish times onto a new clock epoch.  Training
        simulators and serving load generators run separate simulated
        clocks; rebasing at the hand-off keeps the staleness metric
        meaningful without mutating any published snapshot (new frozen
        snapshots are swapped in).

        Every history entry shifts by the same per-tenant delta that lands
        the latest snapshot exactly at ``clock``, so relative snapshot ages
        — and therefore ``get(tenant, version)``-based staleness math —
        stay consistent across clock epochs instead of only the latest
        entry being moved."""
        with self._lock:
            for tenant, hist in self._snaps.items():
                if not hist:
                    continue
                delta = float(clock) - hist[-1].published_at
                hist[:] = [replace(s, published_at=s.published_at + delta)
                           for s in hist]
