"""Per-(tenant, host) serving policies with fleet-wide defaults.

One sharded fleet serves tenants with very different traffic shapes: a hot
dashboard tenant wants deep queues and big batches (throughput), a cold
alerting tenant wants minimum-latency single-request dispatch, and a host
with a TPU attached wants a different kernel backend than a CPU spot node.
:class:`PolicyTable` resolves both knob sets per ``(tenant, host)``:

* the :class:`~repro.serve.batching.BatchConfig` (queue budget, batch cap,
  window controller constants, cache capacity), and
* the :class:`~repro.kernels.dispatch.KernelPolicy` driving backend
  dispatch for that tenant's vote kernels.

Resolution layers partial overrides, least to most specific::

    fleet default  <  host override  <  tenant override  <  (tenant, host)

Batch overrides are *field-wise* merges onto the default ``BatchConfig``
(a tenant that only sets ``queue_budget`` inherits everything else), so
the table stays sparse.  Tenant and pair scopes accept only the knobs a
request actually resolves per tenant — ``queue_budget``/``max_batch``
(plus a kernel policy); window/cache/controller fields are host-server
state and are rejected there rather than silently ignored.  Kernel
resolution returns the most specific non-``None`` policy.
``batch_for``/``kernel_for`` are memoized per ``(tenant, host)`` — they
sit on the per-request admission path.

The JSON form (``--policy-table`` in the ``serve_ensemble`` driver)::

    {"default":        {"max_batch": 64},
     "default_kernel": {"backend": "xla"},
     "hosts":   {"host-0": {"batch": {"queue_budget": 1024}}},
     "tenants": {"iot":    {"batch": {"max_batch": 128},
                            "kernel": {"backend": "interpret"}}},
     "pairs":   {"iot@host-0": {"batch": {"max_batch": 32}}}}

``kernel`` specs take ``backend`` and/or ``calibration`` (a table written
by ``benchmarks/backend_matrix.py``), plus the optional boolean
``fused_fingerprint`` opting the tenant into the one-launch
``stump_vote_fp_batched`` serving path (kernel-computed cache keys, no
host-side feature hashing).
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from pathlib import Path
from typing import Dict, Optional, Tuple

from repro.kernels.dispatch import KernelPolicy
from repro.serve.batching import BatchConfig

_BATCH_FIELDS = {f.name for f in dataclasses.fields(BatchConfig)}
# the only BatchConfig knobs consulted per (tenant, host) request — the
# rest (window controller, cache, admission total) are host-server state
_PER_TENANT_FIELDS = {"queue_budget", "max_batch"}
_PAIR_SEP = "@"                 # "tenant@host" keys in the JSON form


def _checked(batch: Dict, scope: str = "host") -> Dict:
    unknown = sorted(set(batch) - _BATCH_FIELDS)
    if unknown:
        raise ValueError(f"unknown BatchConfig field(s) {unknown}; "
                         f"choose from {sorted(_BATCH_FIELDS)}")
    if "scheduler" in batch:
        raise ValueError("the eq.-(1) scheduler constants are fleet-wide; "
                         "override target_p99_s/adapt_every instead")
    if scope != "host":
        host_only = sorted(set(batch) - _PER_TENANT_FIELDS)
        if host_only:
            raise ValueError(
                f"{host_only} only take effect at host/default scope "
                f"(per-tenant resolution consults "
                f"{sorted(_PER_TENANT_FIELDS)}); refusing a silently "
                f"ignored override at {scope} scope")
    return dict(batch)


def _kernel_from_spec(spec: Optional[Dict]) -> Optional[KernelPolicy]:
    if spec is None:
        return None
    extra = sorted(set(spec) - {"backend", "calibration", "fused_fingerprint"})
    if extra:
        raise ValueError(f"unknown kernel-policy key(s) {extra}")
    fused = bool(spec.get("fused_fingerprint", False))
    if not fused and not any(spec.get(k) for k in ("backend", "calibration")):
        # an empty spec would masquerade as "the most specific layer" and
        # silently mask broader pins — reject it like any no-op override
        raise ValueError("kernel spec needs 'backend', 'calibration' and/or "
                         "'fused_fingerprint' (omit the key entirely to "
                         "inherit)")
    if spec.get("calibration"):
        policy = KernelPolicy.load(spec["calibration"])
        policy.fused_fingerprint = fused
        if spec.get("backend"):
            policy = KernelPolicy(backend=spec["backend"], table=policy.table,
                                  fused_fingerprint=fused)
        return policy
    return KernelPolicy(backend=spec.get("backend"), fused_fingerprint=fused)


class PolicyTable:
    """Layered ``(tenant, host) -> (BatchConfig, KernelPolicy)`` resolver."""

    def __init__(self, default: Optional[BatchConfig] = None,
                 default_kernel: Optional[KernelPolicy] = None):
        self.default = default or BatchConfig()
        self.default_kernel = default_kernel
        # scope -> key -> (batch field overrides, kernel policy or None)
        self._hosts: Dict[str, Tuple[Dict, Optional[KernelPolicy]]] = {}
        self._tenants: Dict[str, Tuple[Dict, Optional[KernelPolicy]]] = {}
        self._pairs: Dict[Tuple[str, str],
                          Tuple[Dict, Optional[KernelPolicy]]] = {}
        self._batch_cache: Dict[Tuple[Optional[str], Optional[str]],
                                BatchConfig] = {}
        self._kernel_cache: Dict[Tuple[Optional[str], Optional[str]],
                                 Optional[KernelPolicy]] = {}

    def with_default(self, default: BatchConfig,
                     default_kernel: Optional[KernelPolicy] = None
                     ) -> "PolicyTable":
        """A copy of this table with a different fleet default — how an
        explicitly passed ``BatchConfig`` composes with a table: the
        explicit config becomes the base every override layers onto."""
        out = PolicyTable(default, default_kernel or self.default_kernel)
        out._hosts = dict(self._hosts)
        out._tenants = dict(self._tenants)
        out._pairs = dict(self._pairs)
        return out

    # -------------------------------------------------------------- writes
    def _invalidate(self) -> None:
        self._batch_cache.clear()
        self._kernel_cache.clear()

    def set_host(self, host: str, *,
                 kernel: Optional[KernelPolicy] = None, **batch) -> None:
        self._hosts[host] = (_checked(batch), kernel)
        self._invalidate()

    def set_tenant(self, tenant: str, *,
                   kernel: Optional[KernelPolicy] = None, **batch) -> None:
        self._tenants[tenant] = (_checked(batch, "tenant"), kernel)
        self._invalidate()

    def set_pair(self, tenant: str, host: str, *,
                 kernel: Optional[KernelPolicy] = None, **batch) -> None:
        self._pairs[(tenant, host)] = (_checked(batch, "pair"), kernel)
        self._invalidate()

    # ------------------------------------------------------------- resolve
    def _layers(self, tenant: Optional[str], host: Optional[str]):
        """Applicable (batch, kernel) layers, least to most specific."""
        out = []
        if host is not None and host in self._hosts:
            out.append(self._hosts[host])
        if tenant is not None and tenant in self._tenants:
            out.append(self._tenants[tenant])
        if (tenant is not None and host is not None
                and (tenant, host) in self._pairs):
            out.append(self._pairs[(tenant, host)])
        return out

    def batch_for(self, tenant: Optional[str] = None,
                  host: Optional[str] = None) -> BatchConfig:
        """Effective BatchConfig for one scope (``None`` = any).  Host-level
        knobs (window controller, host queue budget) resolve with
        ``tenant=None``; per-request admission resolves the full pair."""
        key = (tenant, host)
        hit = self._batch_cache.get(key)
        if hit is None:
            merged: Dict = {}
            for batch, _ in self._layers(tenant, host):
                merged.update(batch)
            hit = (dataclasses.replace(self.default, **merged) if merged
                   else self.default)
            self._batch_cache[key] = hit
        return hit

    def kernel_for(self, tenant: Optional[str] = None,
                   host: Optional[str] = None) -> Optional[KernelPolicy]:
        """Most specific kernel policy for the scope, or the fleet default
        (which may be ``None`` — the caller's own policy then applies)."""
        key = (tenant, host)
        if key not in self._kernel_cache:
            hit = self.default_kernel
            for _, kernel in reversed(self._layers(tenant, host)):
                if kernel is not None:
                    hit = kernel
                    break
            self._kernel_cache[key] = hit
        return self._kernel_cache[key]

    # ---------------------------------------------------------------- JSON
    @staticmethod
    def _spec_pair(spec: Dict) -> Tuple[Dict, Optional[KernelPolicy]]:
        extra = sorted(set(spec) - {"batch", "kernel"})
        if extra:
            raise ValueError(f"unknown policy-entry key(s) {extra}; "
                             "expected 'batch' and/or 'kernel'")
        return _checked(spec.get("batch", {})), _kernel_from_spec(
            spec.get("kernel"))

    @classmethod
    def load(cls, path) -> "PolicyTable":
        raw = json.loads(Path(path).read_text())
        default = BatchConfig(**_checked(raw.get("default", {})))
        table = cls(default, _kernel_from_spec(raw.get("default_kernel")))
        for host, spec in raw.get("hosts", {}).items():
            batch, kernel = cls._spec_pair(spec)
            table.set_host(host, kernel=kernel, **batch)
        for tenant, spec in raw.get("tenants", {}).items():
            batch, kernel = cls._spec_pair(spec)
            table.set_tenant(tenant, kernel=kernel, **batch)
        for pair, spec in raw.get("pairs", {}).items():
            tenant, sep, host = pair.partition(_PAIR_SEP)
            if not sep or not tenant or not host:
                raise ValueError(f"pair key {pair!r} must be 'tenant@host'")
            batch, kernel = cls._spec_pair(spec)
            table.set_pair(tenant, host, kernel=kernel, **batch)
        return table

    def save(self, path) -> None:
        base = BatchConfig()
        if self.default.scheduler != base.scheduler:
            warnings.warn(
                "PolicyTable.save: the default BatchConfig carries "
                "non-default eq.-(1) scheduler constants, which the JSON "
                "form does not serialize — a reloaded table runs the "
                "stock SERVE_SCHEDULER window controller",
                RuntimeWarning, stacklevel=2)

        def diff(cfg: BatchConfig) -> Dict:
            return {f: getattr(cfg, f) for f in _BATCH_FIELDS
                    if f != "scheduler"
                    and getattr(cfg, f) != getattr(base, f)}

        def spec(batch: Dict, kernel: Optional[KernelPolicy]) -> Dict:
            out: Dict = {}
            if batch:
                out["batch"] = batch
            if kernel is not None:
                if kernel.table:
                    # a calibration table has no stable path to point back
                    # at; only the backend pin survives a save/load cycle
                    warnings.warn(
                        "PolicyTable.save: kernel policy carries a "
                        "calibration table, which is not serialized — "
                        "only the backend pin is kept; re-point the "
                        "'calibration' key at the table's JSON instead",
                        RuntimeWarning, stacklevel=3)
                kspec: Dict = {}
                if kernel.backend is not None:
                    kspec["backend"] = kernel.backend
                if getattr(kernel, "fused_fingerprint", False):
                    kspec["fused_fingerprint"] = True
                if kspec:
                    out["kernel"] = kspec
            return out

        doc: Dict = {"default": diff(self.default)}
        default_spec = spec({}, self.default_kernel)
        if "kernel" in default_spec:
            doc["default_kernel"] = default_spec["kernel"]
        doc["hosts"] = {h: spec(b, k) for h, (b, k) in self._hosts.items()}
        doc["tenants"] = {t: spec(b, k)
                          for t, (b, k) in self._tenants.items()}
        doc["pairs"] = {f"{t}{_PAIR_SEP}{h}": spec(b, k)
                        for (t, h), (b, k) in self._pairs.items()}
        Path(path).write_text(json.dumps(doc, indent=2, sort_keys=True))
