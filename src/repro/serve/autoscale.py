"""Queue-depth fleet autoscaling: the paper's eq.-(1) controller applied to
serving *capacity*.

The adaptive-interval rule already drives two control loops in this tree —
the training sync interval (:mod:`repro.core.scheduling`) and the serving
batch window (:mod:`repro.serve.batching`).  :class:`FleetAutoscaler` is
the third: the same :class:`~repro.core.scheduling.HostScheduler` with the
host count as its interval, clipped to ``[min_hosts, max_hosts]`` by the
same rule that clips ``[I_min, I_max]`` — so eq. (1)'s bounded-interval
property carries over to the fleet size.  The observed quantity is the
**negated integrated excess pressure**::

    pressure_t = max(mean queue depth per up host / target_queue,
                     p99 latency since the last observation / target_p99_s)
    signal_t   = -(sum_{i<=t} (pressure_i - release))

Training feeds eq. (1) the global *error*, which is naturally cumulative —
it keeps falling while things go well.  Queue pressure is instantaneous (a
saturated queue pins at the admission budget and stops moving), so the
fleet controller integrates it first; the per-step delta the controller
sees is then ``de_t = -(pressure_t - release)``, and the eq.-(1) branches
become a textbook high/low-water hysteresis on instantaneous pressure:

* ``de < theta1``  ⟺  pressure above the high water ``release - theta1``
  -> the interval grows -> **scale out** (one host per control period);
* ``de > theta2``  ⟺  pressure below the low water ``release - theta2``
  (burst over, backlog drained) -> **scale in**;
* pressure inside the band holds the fleet, so the relief a scale-out
  brings does not immediately read as a reason to scale back in.

Membership changes go through :class:`ShardedEnsembleServer` so they are
loss-free by construction (ASO-Fed-style capacity control under
heterogeneous load, churn-tolerant membership in the spirit of the async
FLchain analysis — arXiv:1911.02134, arXiv:2112.07938):

* **scale-out** spins up a host whose registry replica warms via a gossip
  pull *before* it enters the rendezvous ring (no cold-replica serving);
* **scale-in** picks the shallowest-queue victim, dispatches its due
  batches, reroutes its residual :class:`MicroBatchQueue` along rendezvous
  rank (admission bypassed — already-accepted requests are never dropped),
  hands its registry window to a survivor, then removes it.

The controller is clock-agnostic like everything else in ``repro.serve``:
``step(now)`` self-gates on ``adapt_every_s`` of *caller* time, so the same
loop runs under the simulated clock of ``benchmarks/autoscale_load`` and
the wall clock of the ``serve_ensemble`` driver.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro import obs
from repro.configs.paper_fedboost import SchedulerConfig
from repro.core.scheduling import HostScheduler
from repro.serve.engine import Response
from repro.serve.metrics import percentile
from repro.serve.service import ShardedEnsembleServer


@dataclass(frozen=True)
class AutoscaleConfig:
    """Fleet-capacity policy knobs (eq.-(1) constants on the pressure scale)."""
    min_hosts: int = 1
    max_hosts: int = 8
    target_queue: float = 32.0    # per-host queue depth normalizing pressure
    target_p99_s: float = 0.025   # latency scale normalizing pressure
    adapt_every_s: float = 0.05   # control period (caller-clock seconds)
    # asymmetric by default — scale out a whole host per over-pressure
    # period, but bleed capacity off at a quarter host per calm period:
    # shedding a host is cheap to regret during the next burst onset
    # (the queue refills before the re-add lands), so calm must persist
    # ~1/step_down periods before a host is actually removed
    step_up: float = 1.0          # eq.-(1) alpha: hosts added per step
    step_down: float = 0.25       # eq.-(1) beta: host fraction shed per step
    release: float = 0.4          # pressure the integrator bleeds per period
    theta1: float = -0.25         # high water: scale out above release-theta1
    theta2: float = 0.25          # low water: scale in below release-theta2
    # cost awareness (ROADMAP "cost-aware autoscaling"): each up host is
    # projected to cost `cost_per_host_hour` $/h; a scale-out that would
    # push the fleet's projected spend past `budget_per_hour` is skipped
    # (and counted in stats.budget_capped).  None = uncapped.  The budget
    # acts as a dynamic i_max — it never forces a scale-in below
    # min_hosts, it only refuses growth the operator can't pay for.
    cost_per_host_hour: float = 1.0
    budget_per_hour: Optional[float] = None

    def scheduler(self, init_hosts: int) -> SchedulerConfig:
        """The eq.-(1) constants with the host count as the interval."""
        return SchedulerConfig(alpha=self.step_up, beta=self.step_down,
                               theta1=self.theta1, theta2=self.theta2,
                               i_min=self.min_hosts, i_max=self.max_hosts,
                               i_init=init_hosts)


@dataclass
class AutoscaleStats:
    observations: int = 0
    scale_outs: int = 0
    scale_ins: int = 0
    rerouted: int = 0             # requests moved by scale-in drains
    budget_capped: int = 0        # scale-outs refused by the $/hour budget
    pressure_peak: float = 0.0
    # (now, "out"/"in", host_id, fleet size after the event)
    events: List[Tuple[float, str, str, int]] = field(default_factory=list)


class FleetAutoscaler:
    """Eq.-(1) control loop over a :class:`ShardedEnsembleServer`'s size.

    Drive it from the serving loop: call :meth:`step(now)` whenever
    convenient (every submit is fine — it self-gates on the control
    period) and collect any responses it returns; scale-in drains dispatch
    batches, so those completions belong to the caller's tally.
    """

    def __init__(self, server: ShardedEnsembleServer,
                 cfg: Optional[AutoscaleConfig] = None,
                 host_prefix: str = "scale", *,
                 budget_per_host: Optional[float] = None,
                 budget_per_hour: Optional[float] = None,
                 slo=None):
        # budget_per_host / budget_per_hour override the cfg cost knobs:
        # a host is projected to cost budget_per_host $/h and scale-out is
        # refused once (n+1) hosts would exceed budget_per_hour $/h
        self.server = server
        self.cfg = cfg or AutoscaleConfig()
        self.cost_per_host_hour = (self.cfg.cost_per_host_hour
                                   if budget_per_host is None
                                   else float(budget_per_host))
        self.budget_per_hour = (self.cfg.budget_per_hour
                                if budget_per_hour is None
                                else float(budget_per_hour))
        n0 = min(max(len(server.servers), self.cfg.min_hosts),
                 self.cfg.max_hosts)
        self.sched = HostScheduler(self.cfg.scheduler(n0))
        self.stats = AutoscaleStats()
        self._seq = itertools.count()
        self._prefix = host_prefix
        self._lat: List[float] = []   # completions since last observation
        self._next_obs: Optional[float] = None
        self._integral = 0.0          # summed excess pressure (see module doc)
        # optional obs.slo.SLOMonitor: its burn rate joins the pressure max
        self.slo = slo
        for s in server.servers.values():
            s.on_completion = self._lat.append

    # ------------------------------------------------------------- signal
    def _up(self, host_id: str) -> bool:
        host = self.server.cluster.hosts.get(host_id)
        return host is not None and host.up

    def _up_hosts(self) -> List[str]:
        return [hid for hid in self.server.servers if self._up(hid)]

    def pressure(self, now: Optional[float] = None) -> float:
        """Normalized fleet pressure: queue depth and latency, whichever is
        worse.  Queue depth is the total backlog averaged over *up* hosts
        (capacity-relative — a host that is marked down contributes its
        stuck queue to the numerator but no capacity to the denominator);
        p99 is over completions since the last observation so stale calm
        never masks a fresh spike.  With an attached SLO monitor (and a
        clock), the fleet's burn rate joins the max: crossing 1.0 exactly
        when some tenant burns its error budget at alerting speed, so the
        fleet grows *before* the p99 target itself is breached."""
        depth = sum(s.queue.depth for s in self.server.servers.values())
        p = depth / max(1, len(self._up_hosts())) / self.cfg.target_queue
        if self._lat:
            p = max(p, percentile(self._lat, 99.0) / self.cfg.target_p99_s)
        if self.slo is not None and now is not None:
            p = max(p, self.slo.burn_pressure(now))
        return p

    # --------------------------------------------------------------- cost
    def projected_cost(self, n_hosts: Optional[int] = None) -> float:
        """Projected fleet spend in $/hour for ``n_hosts`` (default: the
        current up count)."""
        n = len(self._up_hosts()) if n_hosts is None else n_hosts
        return n * self.cost_per_host_hour

    def max_affordable(self) -> int:
        """The largest fleet the $/hour budget pays for (never below
        ``min_hosts`` — the budget refuses growth, it does not force a
        scale-in under the floor)."""
        if self.budget_per_hour is None:
            return self.cfg.max_hosts
        # epsilon before flooring: a budget that exactly pays for N hosts
        # must afford N even when the division lands at N - 1ulp
        afford = int(self.budget_per_hour
                     / max(self.cost_per_host_hour, 1e-12) + 1e-9)
        return max(self.cfg.min_hosts, min(afford, self.cfg.max_hosts))

    # ------------------------------------------------------------ control
    def step(self, now: float) -> List[Response]:
        """One possible control action; self-gates on ``adapt_every_s``.
        Returns responses dispatched by a scale-in drain (usually empty)."""
        if self._next_obs is None:
            self._next_obs = now + self.cfg.adapt_every_s
            return []
        if now < self._next_obs:
            return []
        self._next_obs = now + self.cfg.adapt_every_s
        p = self.pressure(now)
        self._lat.clear()
        self.stats.observations += 1
        self.stats.pressure_peak = max(self.stats.pressure_peak, p)
        # eq. (1) on the negated integrated excess pressure: the step the
        # controller observes is de = -(p - release), i.e. the high/low-
        # water hysteresis derived in the module docstring
        self._integral += p - self.cfg.release
        self.sched.observe(-self._integral)
        return self._reconcile(now)

    def _reconcile(self, now: float) -> List[Response]:
        """Move the fleet one membership action toward the controller's
        target per control period — churn paced by the observation clock,
        never faster than gossip warm-up/drain can follow.  *Capacity* is
        the up-host count: a host marked down by failover is not capacity,
        so it is shed unconditionally (its accepted requests reroute to
        live hosts instead of starving behind a dead queue) and the
        controller replaces it rather than holding a dead fleet.

        Scale decisions compare the eq.-(1) state's *fractional* interval
        against the up count with a full unit of margin: a scale-out
        leaves the interval at an integer, and comparing ``int(interval)``
        would let a single epsilon of calm shed the newest host — the
        fractional comparison makes the first shed wait the same
        ``~1/step_down`` calm periods as every later one, while one
        over-pressure period (``step_up = 1``) still scales out
        immediately."""
        up = self._up_hosts()
        down = [hid for hid in self.server.servers if hid not in up]
        if down and up:
            return self._shed(down, now)
        current = len(up)
        target = self.sched.interval            # fractional eq.-(1) state
        if target >= current + 1:
            if current + 1 > self.max_affordable():
                # the budget binds: refuse the scale-out and clamp the
                # eq.-(1) state at the affordable fleet (a dynamic i_max)
                # so the integrator doesn't wind up unboundedly and make
                # the eventual scale-in sluggish
                self.stats.budget_capped += 1
                self.sched.interval = min(self.sched.interval,
                                          float(self.max_affordable()))
                return []
            return self._scale_out(now)
        if (target <= current - 1 and current > self.cfg.min_hosts
                and current > 1):
            return self._shed(up, now)
        return []

    def _scale_out(self, now: float) -> List[Response]:
        # probe past ids already taken (live or retired) — a rebuilt
        # autoscaler on the same server restarts its sequence at 0
        host_id = f"{self._prefix}-{next(self._seq)}"
        while self.server.host_id_taken(host_id):
            host_id = f"{self._prefix}-{next(self._seq)}"
        server = self.server.add_host(host_id, now=now)
        server.on_completion = self._lat.append
        self.stats.scale_outs += 1
        self.stats.events.append((now, "out", host_id,
                                  len(self.server.servers)))
        obs.count("autoscale.scale_outs")
        if obs.enabled():
            obs.point("autoscale.scale_out", sim_t0=now, sim_t1=now,
                      host=host_id, hosts=len(self.server.servers))
        return []

    def _shed(self, pool: List[str], now: float) -> List[Response]:
        # shallowest queue = cheapest drain; rendezvous hashing makes any
        # victim equally safe for ownership (only its tenants move)
        victim = min(pool,
                     key=lambda hid: self.server.servers[hid].queue.depth)
        responses, rerouted = self.server.remove_host(victim, now=now)
        self.stats.scale_ins += 1
        self.stats.rerouted += rerouted
        self.stats.events.append((now, "in", victim,
                                  len(self.server.servers)))
        obs.count("autoscale.scale_ins")
        obs.count("autoscale.rerouted", rerouted)
        if obs.enabled():
            obs.point("autoscale.scale_in", sim_t0=now, sim_t1=now,
                      host=victim, hosts=len(self.server.servers),
                      rerouted=rerouted)
        return responses
