"""Per-snapshot result cache for the serving hot path.

Hot feature vectors recur (dashboards re-scoring the same device, retries,
reference rows), and an ensemble's margin for a given input is a pure
function of ``(tenant, snapshot version, feature block)`` — so the batch
evaluator memoizes it.  The key is exactly that triple, with the feature
block keyed by a content hash of its float32 bytes:

* a **hit** returns the margin the kernel produced when the entry was
  filled — bit-identical to re-running the vote, because padded kernel
  slots contribute exact zeros (the ``ensemble_vote`` padding contract),
  so batch composition never perturbs a tenant's margins;
* a **miss** falls through to the packed Pallas kernel path and fills the
  cache after the vote.

Invalidation is subscription-driven: the cache registers on the registry's
(or sharded cluster's) publish hook, and when a newer version for a tenant
lands — local ``publish()`` or gossip ``ingest()`` alike — every entry of
*that tenant only* keyed below the new version is dropped atomically under
the cache lock.  Versioned keys already make stale hits impossible; the
invalidation sweep is what bounds memory and keeps the "exactly that
tenant" eviction property testable.  Capacity overflow evicts LRU.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np


def feature_hash(x) -> bytes:
    """Content hash of one feature vector (float32 canonical bytes)."""
    buf = np.ascontiguousarray(np.asarray(x), np.float32)
    return hashlib.blake2b(buf.tobytes(), digest_size=12).digest()


def fingerprint_key(f0, f1) -> bytes:
    """Cache key from the two uint32 xor-fold fingerprint lanes the fused
    ``stump_vote_fp_batched`` kernel emits per request column.  The ``fp``
    prefix keeps kernel-computed keys disjoint from :func:`feature_hash`
    keys (12 raw digest bytes), so a tenant toggling ``fused_fingerprint``
    mid-flight can never alias the two key spaces."""
    return (b"fp" + int(f0).to_bytes(4, "little")
            + int(f1).to_bytes(4, "little"))


CacheKey = Tuple[str, int, bytes]       # (tenant, snapshot version, x hash)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    fills: int = 0
    invalidated: int = 0     # entries dropped by newer-version publishes
    evicted: int = 0         # entries dropped by LRU capacity pressure
    per_tenant_hits: Dict[str, int] = field(default_factory=dict)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class ResultCache:
    """Thread-safe LRU of ``(tenant, version, feature-hash) -> margin``."""

    def __init__(self, capacity: int = 65536):
        assert capacity >= 1
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: "OrderedDict[CacheKey, float]" = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -------------------------------------------------------------- lookup
    def lookup(self, tenant: str, version: int, xh: bytes
               ) -> Optional[float]:
        key = (tenant, int(version), xh)
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                t = self.stats.per_tenant_hits
                t[tenant] = t.get(tenant, 0) + 1
                return self._entries[key]
            self.stats.misses += 1
            return None

    def put(self, tenant: str, version: int, xh: bytes, margin: float
            ) -> None:
        key = (tenant, int(version), xh)
        with self._lock:
            if key not in self._entries:
                self.stats.fills += 1
            self._entries[key] = float(margin)
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evicted += 1

    # -------------------------------------------------------- invalidation
    def invalidate_through(self, tenant: str, version: int) -> int:
        """Atomically drop every entry of ``tenant`` keyed at or below
        ``version`` (other tenants' entries are untouched); returns the
        drop count.  Inclusive on purpose: when gossip reconciliation
        *replaces* a tenant's latest snapshot at the same version number
        (two publishers raced), entries filled from the discarded snapshot
        share its version key and must go too.  On a normal publish the
        inclusive bound is vacuous — nothing can be cached under a version
        that has only just become latest."""
        with self._lock:
            dead = [k for k in self._entries
                    if k[0] == tenant and k[1] <= version]
            for k in dead:
                del self._entries[k]
            self.stats.invalidated += len(dead)
        return len(dead)

    def attach(self, registry):
        """Subscribe invalidation to a registry (or registry-like sharded
        host): any snapshot that becomes a tenant's latest — publish,
        gossip ingest, or same-version reconciliation — sweeps that
        tenant's entries up to that version.  Returns the unsubscribe
        handle."""
        return registry.subscribe(
            lambda snap: self.invalidate_through(snap.tenant, snap.version))

    def keys(self) -> Tuple[CacheKey, ...]:
        with self._lock:
            return tuple(self._entries)
