"""Serving observability: per-tenant latency quantiles, queue depth, batch
sizes, snapshot staleness — the operational counters the load benchmark and
the `serve_ensemble` driver report.

Latencies are kept in a bounded reservoir per tenant (uniform-ish by keeping
every k-th sample once full) so a long soak doesn't grow memory unboundedly.
"""
from __future__ import annotations

import math
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (no numpy dependency on the hot path).

    Explicit ceil form: the smallest sample value with at least ``q``\\ % of
    the sorted sample at or below it, i.e. rank ``ceil(q/100 * n)``
    (1-based).  An earlier ``int(round(...))`` formulation used banker's
    rounding, which can land an index off the nearest rank on even-length
    lists; the behavior is pinned by a table-driven test."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = math.ceil(q / 100.0 * len(s))          # 1-based nearest rank
    return s[min(len(s) - 1, max(0, rank - 1))]


@dataclass
class TenantMetrics:
    completed: int = 0
    rejected: int = 0
    latencies: List[float] = field(default_factory=list)
    staleness_sum: float = 0.0       # snapshot age summed at completion time
    last_version: int = 0
    _reservoir: int = 4096
    _skip: int = 0

    def record(self, latency_s: float, staleness_s: float, version: int
               ) -> None:
        self.completed += 1
        self.staleness_sum += max(0.0, staleness_s)
        self.last_version = version
        if len(self.latencies) < self._reservoir:
            self.latencies.append(latency_s)
        else:                        # thin the stream: keep every 8th sample
            self._skip += 1
            if self._skip % 8 == 0:
                # dedicated write cursor so successive writes sweep the whole
                # reservoir (completed % size would revisit only size/8 slots)
                self.latencies[(self._skip // 8) % self._reservoir] = latency_s

    @property
    def p50(self) -> float:
        return percentile(self.latencies, 50.0)

    @property
    def p99(self) -> float:
        return percentile(self.latencies, 99.0)

    @property
    def mean_staleness(self) -> float:
        return self.staleness_sum / self.completed if self.completed else 0.0


@dataclass
class ServeMetrics:
    """Aggregated serving counters (per tenant + fleet-wide)."""
    tenants: Dict[str, TenantMetrics] = field(default_factory=dict)
    batch_size_hist: Counter = field(default_factory=Counter)
    window_units_hist: Counter = field(default_factory=Counter)
    queue_depth_peak: int = 0
    n_batches: int = 0
    first_submit_t: Optional[float] = None
    last_finish_t: Optional[float] = None

    def tenant(self, name: str) -> TenantMetrics:
        return self.tenants.setdefault(name, TenantMetrics())

    # ------------------------------------------------------------- records
    def record_submit(self, now: float, depth: int) -> None:
        if self.first_submit_t is None:
            self.first_submit_t = now
        self.queue_depth_peak = max(self.queue_depth_peak, depth)

    def record_rejected(self, tenant: str) -> None:
        self.tenant(tenant).rejected += 1

    def record_batch(self, size: int, window_units: int, finish_t: float
                     ) -> None:
        self.n_batches += 1
        self.batch_size_hist[size] += 1
        self.window_units_hist[window_units] += 1
        self.last_finish_t = (finish_t if self.last_finish_t is None
                              else max(self.last_finish_t, finish_t))

    def record_completion(self, tenant: str, latency_s: float,
                          staleness_s: float, version: int) -> None:
        self.tenant(tenant).record(latency_s, staleness_s, version)

    # ------------------------------------------------------------- reports
    @property
    def completed(self) -> int:
        return sum(t.completed for t in self.tenants.values())

    @property
    def rejected(self) -> int:
        return sum(t.rejected for t in self.tenants.values())

    @property
    def mean_batch(self) -> float:
        n = sum(self.batch_size_hist.values())
        return (sum(k * v for k, v in self.batch_size_hist.items()) / n
                if n else 0.0)

    def throughput(self) -> float:
        """Completed requests per second of serving makespan."""
        if (self.first_submit_t is None or self.last_finish_t is None
                or self.last_finish_t <= self.first_submit_t):
            return 0.0
        return self.completed / (self.last_finish_t - self.first_submit_t)

    def all_latencies(self) -> List[float]:
        out: List[float] = []
        for t in self.tenants.values():
            out.extend(t.latencies)
        return out

    def report(self) -> Dict:
        lats = self.all_latencies()
        return {
            "completed": self.completed,
            "rejected": self.rejected,
            "throughput_rps": self.throughput(),
            "p50_ms": 1e3 * percentile(lats, 50.0),
            "p99_ms": 1e3 * percentile(lats, 99.0),
            "mean_batch": self.mean_batch,
            "n_batches": self.n_batches,
            "queue_depth_peak": self.queue_depth_peak,
            "tenants": {
                name: {
                    "completed": t.completed,
                    "rejected": t.rejected,
                    "p50_ms": 1e3 * t.p50,
                    "p99_ms": 1e3 * t.p99,
                    "mean_staleness_s": t.mean_staleness,
                    "snapshot_version": t.last_version,
                }
                for name, t in sorted(self.tenants.items())
            },
        }
