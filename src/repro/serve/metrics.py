"""Serving observability: per-tenant latency quantiles, queue depth, batch
sizes, snapshot staleness — the operational counters the load benchmark and
the `serve_ensemble` driver report.

Since the `repro.obs` layer landed, this module is a thin *view* over a
:class:`~repro.obs.registry.MetricsRegistry` rather than a parallel
implementation: every per-tenant counter is a registry ``Counter``/
``Gauge`` and every latency reservoir a registry ``Histogram`` (the single
bounded-reservoir estimator in the repo — keep every sample until full,
then every 8th under a sweeping cursor).  Each :class:`ServeMetrics` owns a
*private* registry, because per-host serving counters must merge per fleet
(``ShardedEnsembleServer.report``) rather than blending into the
process-wide namespace; pass ``registry=obs.get_registry()`` to publish a
single server's counters globally.

Fleet percentiles weight each tenant's retained samples by how many stream
observations they stand for (``Histogram.weight_per_sample``) — see
:meth:`ServeMetrics.fleet_percentile` for why plain concatenation is
biased.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Optional, Tuple

from repro.obs.registry import (MetricsRegistry, percentile,
                                weighted_percentile)

__all__ = ["percentile", "weighted_percentile", "TenantMetrics",
           "ServeMetrics"]


class TenantMetrics:
    """One tenant's serving counters — a view over registry instruments
    (``serve.completed{tenant=...}``, ``serve.latency_s{tenant=...}``, ...)
    that keeps the pre-obs read surface (``completed``, ``latencies``,
    ``p50``, ``mean_staleness``) intact for callers and tests."""

    __slots__ = ("_completed", "_rejected", "_staleness", "_lat", "_version")

    def __init__(self, registry: MetricsRegistry, tenant: str):
        self._completed = registry.counter("serve.completed", tenant=tenant)
        self._rejected = registry.counter("serve.rejected", tenant=tenant)
        self._staleness = registry.counter("serve.staleness_s_sum",
                                           tenant=tenant)
        self._lat = registry.histogram("serve.latency_s", tenant=tenant)
        self._version = registry.gauge("serve.snapshot_version",
                                       tenant=tenant)

    # ------------------------------------------------------------- records
    def record(self, latency_s: float, staleness_s: float, version: int
               ) -> None:
        self._completed.inc()
        self._staleness.inc(max(0.0, staleness_s))
        self._version.max(version)
        self._lat.observe(latency_s)

    def record_rejected(self) -> None:
        self._rejected.inc()

    def merge_from(self, other: "TenantMetrics") -> None:
        """Fold another host's counters for the *same* tenant in (fleet
        report merging): counters add, the latency histogram extends with
        retained samples + stream totals, version merges by max."""
        self._completed.inc(other._completed.value)
        self._rejected.inc(other._rejected.value)
        self._staleness.inc(other._staleness.value)
        self._lat.extend(other._lat)
        self._version.max(other._version.value)

    # --------------------------------------------------------------- reads
    @property
    def completed(self) -> int:
        return int(self._completed.value)

    @property
    def rejected(self) -> int:
        return int(self._rejected.value)

    @property
    def latencies(self) -> List[float]:
        """The retained latency reservoir (thinned once past capacity)."""
        return self._lat.values

    @property
    def latency_hist(self):
        return self._lat

    @property
    def staleness_sum(self) -> float:
        return self._staleness.value

    @property
    def last_version(self) -> int:
        return int(self._version.value)

    @property
    def p50(self) -> float:
        return self._lat.p50

    @property
    def p99(self) -> float:
        return self._lat.p99

    @property
    def mean_staleness(self) -> float:
        return self.staleness_sum / self.completed if self.completed else 0.0


class ServeMetrics:
    """Aggregated serving counters (per tenant + fleet-wide) over one
    private :class:`MetricsRegistry` (injectable for a global namespace)."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tenants: Dict[str, TenantMetrics] = {}
        self.batch_size_hist: Counter = Counter()
        self.window_units_hist: Counter = Counter()
        self.first_submit_t: Optional[float] = None
        self.last_finish_t: Optional[float] = None
        self._batches = self.registry.counter("serve.batches")
        self._depth_peak = self.registry.gauge("serve.queue_depth_peak")

    def tenant(self, name: str) -> TenantMetrics:
        t = self.tenants.get(name)
        if t is None:
            t = self.tenants[name] = TenantMetrics(self.registry, name)
        return t

    # ------------------------------------------------------------- records
    def record_submit(self, now: float, depth: int) -> None:
        if self.first_submit_t is None:
            self.first_submit_t = now
        self._depth_peak.max(depth)

    def record_rejected(self, tenant: str) -> None:
        self.tenant(tenant).record_rejected()

    def record_batch(self, size: int, window_units: int, finish_t: float
                     ) -> None:
        self._batches.inc()
        self.batch_size_hist[size] += 1
        self.window_units_hist[window_units] += 1
        self.last_finish_t = (finish_t if self.last_finish_t is None
                              else max(self.last_finish_t, finish_t))

    def record_completion(self, tenant: str, latency_s: float,
                          staleness_s: float, version: int) -> None:
        self.tenant(tenant).record(latency_s, staleness_s, version)

    # ------------------------------------------------------------- reports
    @property
    def completed(self) -> int:
        return sum(t.completed for t in self.tenants.values())

    @property
    def rejected(self) -> int:
        return sum(t.rejected for t in self.tenants.values())

    @property
    def n_batches(self) -> int:
        return int(self._batches.value)

    @property
    def queue_depth_peak(self) -> int:
        return int(self._depth_peak.value)

    @property
    def mean_batch(self) -> float:
        n = sum(self.batch_size_hist.values())
        return (sum(k * v for k, v in self.batch_size_hist.items()) / n
                if n else 0.0)

    def throughput(self) -> float:
        """Completed requests per second of serving makespan."""
        if (self.first_submit_t is None or self.last_finish_t is None
                or self.last_finish_t <= self.first_submit_t):
            return 0.0
        return self.completed / (self.last_finish_t - self.first_submit_t)

    def all_latencies(self) -> List[float]:
        """Every retained latency sample, concatenated across tenants.

        NOTE this concatenation is *biased* once any tenant's reservoir has
        thinned: a tenant with 100k completions holds the same ~4096
        samples as one with 4096 completions, so its traffic is undercounted
        ~25x in any quantile of the concatenation (fleet p99 skews toward
        low-traffic tenants).  Use :meth:`fleet_percentile` for fleet
        quantiles; this list remains for mean-style uses and debugging."""
        out: List[float] = []
        for t in self.tenants.values():
            out.extend(t.latencies)
        return out

    def latency_pairs(self) -> List[Tuple[float, float]]:
        """``(latency, weight)`` pairs across tenants, each retained sample
        weighted by the ``completed / len(reservoir)`` observations it
        stands for.  This is the *exact-weight* fleet sample: concatenate
        these across per-host metrics **before** any histogram merge and a
        fold of already-folded registries can never re-thin a reservoir and
        double-weight its survivors (``Histogram.extend`` keeps only every
        8th incoming sample once full, so a merge-of-merges would otherwise
        inflate the weight of whichever host folded first)."""
        pairs: List[Tuple[float, float]] = []
        for t in self.tenants.values():
            w = t.latency_hist.weight_per_sample
            pairs.extend((v, w) for v in t.latencies)
        return pairs

    def fleet_percentile(self, q: float) -> float:
        """Fleet-wide latency percentile with per-tenant sample weighting:
        each retained sample counts as ``completed / len(reservoir)`` stream
        observations, so tenants whose reservoirs thinned at different
        rates contribute in proportion to their true traffic.  With no
        thinning anywhere, this equals ``percentile(all_latencies(), q)``
        exactly."""
        return weighted_percentile(self.latency_pairs(), q)

    def report(self) -> Dict:
        return {
            "completed": self.completed,
            "rejected": self.rejected,
            "throughput_rps": self.throughput(),
            "p50_ms": 1e3 * self.fleet_percentile(50.0),
            "p99_ms": 1e3 * self.fleet_percentile(99.0),
            "mean_batch": self.mean_batch,
            "n_batches": self.n_batches,
            "queue_depth_peak": self.queue_depth_peak,
            "tenants": {
                name: {
                    "completed": t.completed,
                    "rejected": t.rejected,
                    "p50_ms": 1e3 * t.p50,
                    "p99_ms": 1e3 * t.p99,
                    "mean_staleness_s": t.mean_staleness,
                    "snapshot_version": t.last_version,
                }
                for name, t in sorted(self.tenants.items())
            },
        }

    def snapshot(self) -> Dict:
        """The underlying registry snapshot (obs export surface)."""
        return self.registry.snapshot()
