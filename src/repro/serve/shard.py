"""Sharded snapshot registry: rendezvous-hashed tenant ownership across N
simulated serving hosts, with anti-entropy gossip propagating publishes.

Topology
--------
Every host runs its own :class:`~repro.serve.registry.EnsembleRegistry`.
A tenant's *owner* is chosen by rendezvous (highest-random-weight) hashing
over the up hosts — adding or draining a host only moves the tenants that
hashed to it, never reshuffles the rest.  Training publishes route to the
owner; gossip then replicates the snapshot everywhere, so any host can
serve any tenant after convergence and routing falls over to the next host
in rendezvous rank when the owner is marked down.

Gossip (anti-entropy, pull-on-miss)
-----------------------------------
Each round every up host contacts ``fanout`` random up peers and the pair
exchanges *digests* — per-tenant ``(version, content fingerprint)`` vectors.
Whoever is behind on a tenant pulls the peer's retained snapshot window and
``ingest``-s it (version stamps preserved, duplicates dropped), the
FLchain-style serverless dissemination of arXiv:2112.07938.  When both
sides claim the *same* version with *different* content — two publishers
raced, or a failover host re-published during a partition — the tie breaks
by the FedAsync staleness rule (arXiv:1903.03934): each candidate scores
``(1 + train_progress) * s(Δτ)`` with ``s(Δτ) = exp(-lam * Δτ)`` and
``Δτ = now - published_at``; the higher score wins on both hosts (ties
fall back to publish time, then fingerprint), so reconciliation is
symmetric and the cluster converges regardless of exchange order.
"""
from __future__ import annotations

import hashlib
import math
import random
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro import obs
from repro.serve.registry import EnsembleRegistry, EnsembleSnapshot


# ------------------------------------------------------------- rendezvous
def _score(host_id: str, tenant: str) -> int:
    h = hashlib.blake2b(f"{host_id}|{tenant}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


def rendezvous_rank(tenant: str, host_ids: Iterable[str]) -> List[str]:
    """Hosts ordered by rendezvous score for ``tenant`` (owner first)."""
    return sorted(host_ids, key=lambda h: _score(h, tenant), reverse=True)


def rendezvous_owner(tenant: str, host_ids: Iterable[str]) -> str:
    return max(host_ids, key=lambda h: _score(h, tenant))


# ----------------------------------------------------------------- gossip
@dataclass(frozen=True)
class GossipConfig:
    fanout: int = 1           # peers each host contacts per gossip round
    lam: float = 0.5          # staleness decay in s(dt) = exp(-lam * dt)
    history: int = 4          # per-host retained snapshot window
    seed: int = 0             # peer-selection RNG


def staleness_weight(delta_tau: float, lam: float) -> float:
    """FedAsync-style ``s(Δτ)``: exponential decay in snapshot age."""
    return math.exp(-lam * max(0.0, float(delta_tau)))


def reconcile_score(snap: EnsembleSnapshot, now: float, lam: float) -> float:
    """Rank of one candidate among concurrent same-version snapshots."""
    return (1.0 + snap.train_progress) * staleness_weight(
        now - snap.published_at, lam)


@dataclass
class ShardHost:
    """One simulated serving host: its registry replica + liveness flag."""
    host_id: str
    registry: EnsembleRegistry
    up: bool = True


@dataclass
class GossipStats:
    rounds: int = 0
    exchanges: int = 0
    pulled: int = 0           # snapshots ingested via pull-on-miss
    reconciled: int = 0       # concurrent same-version conflicts resolved


class ShardCluster:
    """N rendezvous-sharded registry hosts joined by an anti-entropy loop.

    The cluster quacks like an :class:`EnsembleRegistry` on the training
    side (``publish`` / ``publish_packed`` route to the tenant's owner, so
    the async engine's and fed_mesh's publish hooks notify the owning
    shard unchanged) and exposes routing/failover + the gossip pump to the
    serving side.
    """

    def __init__(self, n_hosts: int = 3, cfg: Optional[GossipConfig] = None,
                 host_ids: Optional[Sequence[str]] = None):
        self.cfg = cfg or GossipConfig()
        ids = (list(host_ids) if host_ids is not None
               else [f"host-{i}" for i in range(n_hosts)])
        assert len(ids) == len(set(ids)) and ids
        self.hosts: Dict[str, ShardHost] = {
            hid: ShardHost(hid, self._make_registry(hid)) for hid in ids}
        self._rng = random.Random(self.cfg.seed)
        self.stats = GossipStats()

    def _make_registry(self, host_id: str) -> EnsembleRegistry:
        """Registry factory for one host replica — the hook subclasses
        override to back hosts with a different store (the chain-of-record
        :class:`~repro.chain.registry.ChainRegistry` swaps in here)."""
        return EnsembleRegistry(history=self.cfg.history)

    # ------------------------------------------------------------ topology
    def host_ids(self, up_only: bool = True) -> List[str]:
        return [h for h, s in self.hosts.items() if s.up or not up_only]

    def owner(self, tenant: str) -> str:
        """The owning host among *up* hosts (failover-aware)."""
        up = self.host_ids()
        if not up:
            raise RuntimeError("no up hosts in cluster")
        return rendezvous_owner(tenant, up)

    def route(self, tenant: str) -> Optional[ShardHost]:
        """First up host in rendezvous rank, or None if all are down."""
        for hid in rendezvous_rank(tenant, self.hosts):
            if self.hosts[hid].up:
                return self.hosts[hid]
        return None

    def mark_down(self, host_id: str) -> None:
        self.hosts[host_id].up = False

    def mark_up(self, host_id: str) -> None:
        self.hosts[host_id].up = True

    # ------------------------------------------------- elastic membership
    def add_host(self, host_id: str, now: float = 0.0) -> ShardHost:
        """Scale-out: create a replica and *warm* it with an anti-entropy
        pull before it enters the rendezvous ring.  The new host stays
        ``up=False`` while warming, so ownership/routing never select a
        cold replica; rendezvous hashing guarantees that flipping it up
        only moves the tenants that hash to it.  Warm-up prefers up peers;
        with none (total outage — the autoscaler replacing a dead fleet)
        it pulls from the down replicas' stores instead, so the first
        routable host is never an empty one."""
        if host_id in self.hosts:
            raise ValueError(f"host {host_id!r} already in cluster")
        host = ShardHost(host_id, self._make_registry(host_id), up=False)
        peers = self.host_ids() or list(self.hosts)
        self.hosts[host_id] = host
        for peer_id in peers:
            self._anti_entropy(host, self.hosts[peer_id], now)
            self.stats.exchanges += 1
        host.up = True
        return host

    def remove_host(self, host_id: str, now: float = 0.0) -> None:
        """Remove a host permanently.  Its retained snapshot window is
        handed to a survivor first (anti-entropy exchange), so a publish
        that had not gossiped out yet — the victim may own tenants — is
        not lost with the replica; gossip then spreads it.  An up survivor
        is preferred, but a down replica suffices (it rejoins the ring
        holding the data); removing the *last* host raises instead of
        silently discarding the only copy."""
        victim = self.hosts[host_id]
        victim.up = False                        # leave the ring first
        survivors = self.host_ids() or [h for h in self.hosts
                                        if h != host_id]
        if not survivors:
            raise ValueError(
                f"cannot remove {host_id!r}: it is the cluster's last "
                "host and its registry window would be discarded")
        self._anti_entropy(victim, self.hosts[survivors[0]], now)
        self.stats.exchanges += 1
        del self.hosts[host_id]

    # ------------------------------------- registry facade (training side)
    def publish(self, tenant: str, learners, alphas, **kw) -> EnsembleSnapshot:
        return self.hosts[self.owner(tenant)].registry.publish(
            tenant, learners, alphas, **kw)

    def publish_packed(self, tenant: str, stump_params, alphas,
                       **kw) -> EnsembleSnapshot:
        return self.hosts[self.owner(tenant)].registry.publish_packed(
            tenant, stump_params, alphas, **kw)

    def latest(self, tenant: str) -> Optional[EnsembleSnapshot]:
        host = self.route(tenant)
        return host.registry.latest(tenant) if host else None

    def get(self, tenant: str, version: Optional[int] = None
            ) -> Optional[EnsembleSnapshot]:
        host = self.route(tenant)
        return host.registry.get(tenant, version) if host else None

    def staleness(self, tenant: str, now: float) -> float:
        host = self.route(tenant)
        return host.registry.staleness(tenant, now) if host else float("inf")

    def tenants(self) -> List[str]:
        seen = set()
        for h in self.hosts.values():
            seen.update(h.registry.tenants())
        return sorted(seen)

    def version_count(self, tenant: str) -> int:
        s = self.latest(tenant)
        return s.version if s else 0

    def rebase_clock(self, clock: float = 0.0) -> None:
        for h in self.hosts.values():
            h.registry.rebase_clock(clock)

    def subscribe(self, fn):
        """Subscribe ``fn`` on every host replica (publishes *and* gossip
        ingests fire, whichever host they land on).  Returns one handle
        that unsubscribes from all of them."""
        handles = [h.registry.subscribe(fn) for h in self.hosts.values()]

        def unsubscribe() -> None:
            for h in handles:
                h()
        return unsubscribe

    # -------------------------------------------------------------- gossip
    def digests(self) -> Dict[str, Dict[str, Tuple[int, str]]]:
        return {hid: h.registry.digest() for hid, h in self.hosts.items()
                if h.up}

    def converged(self) -> bool:
        """True when every up host holds an identical version vector (and
        identical latest content) for every tenant."""
        vecs = list(self.digests().values())
        return all(v == vecs[0] for v in vecs[1:]) if vecs else True

    def gossip_round(self, now: float = 0.0) -> GossipStats:
        """One anti-entropy round: every up host pulls from ``fanout``
        random up peers.  Returns cumulative stats."""
        up = self.host_ids()
        self.stats.rounds += 1
        pulled0, rec0 = self.stats.pulled, self.stats.reconciled
        traced = obs.enabled()
        with obs.span("gossip.round", sim_t=now, hosts=len(up)) as sp:
            for hid in up:
                peers = [p for p in up if p != hid]
                self._rng.shuffle(peers)
                for pid in peers[:self.cfg.fanout]:
                    p0, r0 = self.stats.pulled, self.stats.reconciled
                    self._anti_entropy(self.hosts[hid], self.hosts[pid], now)
                    self.stats.exchanges += 1
                    if traced:
                        # per-exchange cross-host edge inside the round's
                        # trace: host= is the puller, peer the source
                        obs.point("gossip.exchange", sim_t0=now, sim_t1=now,
                                  host=hid, peer=pid,
                                  pulled=self.stats.pulled - p0,
                                  reconciled=self.stats.reconciled - r0)
            sp.set(pulled=self.stats.pulled - pulled0,
                   reconciled=self.stats.reconciled - rec0)
            sp.end_sim(now)
        obs.count("gossip.rounds")
        obs.count("gossip.pulled", self.stats.pulled - pulled0)
        obs.count("gossip.reconciled", self.stats.reconciled - rec0)
        return self.stats

    def run_until_quiescent(self, now: float = 0.0, max_rounds: int = 64
                            ) -> int:
        """Gossip until the version vectors stop moving; returns the number
        of rounds taken (the convergence lag the benchmark reports)."""
        for r in range(1, max_rounds + 1):
            self.gossip_round(now)
            if self.converged():
                return r
        return max_rounds

    def _anti_entropy(self, a: ShardHost, b: ShardHost, now: float) -> None:
        da, db = a.registry.digest(), b.registry.digest()
        for tenant in set(da) | set(db):
            va, fa = da.get(tenant, (0, ""))
            vb, fb = db.get(tenant, (0, ""))
            if va < vb:
                self._pull(a, b, tenant, now)
            elif vb < va:
                self._pull(b, a, tenant, now)
            elif va and fa != fb:       # concurrent: same version, new bytes
                self._reconcile(a, b, tenant, now)

    def _pull(self, behind: ShardHost, ahead: ShardHost, tenant: str,
              now: float) -> None:
        """Pull-on-miss: the behind host ingests the peer's whole retained
        window (ingest dedupes versions it already holds)."""
        for snap in ahead.registry.history(tenant):
            if behind.registry.ingest(snap):
                self.stats.pulled += 1
        # the pair may still disagree on the shared top version's content
        if (behind.registry.latest(tenant).fingerprint
                != ahead.registry.latest(tenant).fingerprint):
            self._reconcile(behind, ahead, tenant, now)

    def _reconcile(self, a: ShardHost, b: ShardHost, tenant: str,
                   now: float) -> None:
        sa, sb = a.registry.latest(tenant), b.registry.latest(tenant)
        ka = (reconcile_score(sa, now, self.cfg.lam), sa.published_at,
              sa.fingerprint)
        kb = (reconcile_score(sb, now, self.cfg.lam), sb.published_at,
              sb.fingerprint)
        winner, loser_host = (sa, b) if ka >= kb else (sb, a)
        loser_host.registry.replace_latest(tenant, winner)
        self.stats.reconciled += 1
