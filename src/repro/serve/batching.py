"""Adaptive micro-batching: request queue, batch window control, admission.

The batch window is driven by the paper's adaptive-interval rule (eq. 1,
:mod:`repro.core.scheduling`), transferred from communication scheduling to
serving:

* training: error improving/stable -> widen the sync interval (sync less);
  error regressing -> shrink it (sync more).
* serving: the observed signal is the *negated* normalized p99 latency
  ``-p99/target``.  Latency rising (queue building under load) reads as the
  signal dropping fast -> the ``de < theta1`` branch fires and the window
  *grows*, buying throughput through bigger batches.  Latency stable or
  improving reads as ``de > theta2`` -> the window *shrinks*, drifting back
  toward minimum-latency single-request dispatch when load is light.

The controller is literally :class:`~repro.core.scheduling.HostScheduler`
on that signal — same state, same clipping, same step rule — so every
property proven for eq. (1) (bounded interval, lockstep with the jit
variant) carries over to the batch window.

Admission control: a hard queue budget.  When the queue is at budget the
submit is rejected (backpressure to the caller) rather than growing an
unbounded backlog that would blow the latency SLO for everyone.
"""
from __future__ import annotations

from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Iterator, List, Optional

import jax.numpy as jnp

from repro.configs.paper_fedboost import SchedulerConfig
from repro.core.scheduling import HostScheduler
from repro.serve.metrics import percentile

# eq.-(1) constants for the serving controller, on the -p99/target scale:
# de < theta1  (latency worsened by >8% of target)  -> grow the window
# de > theta2  (latency stable within 2% or better) -> shrink the window
SERVE_SCHEDULER = SchedulerConfig(alpha=2.0, beta=1.0,
                                  theta1=-0.08, theta2=-0.02,
                                  i_min=1, i_max=32, i_init=2)


@dataclass(frozen=True)
class BatchConfig:
    """Micro-batching policy knobs."""
    max_batch: int = 64           # hard cap on requests per dispatched batch
    base_window_s: float = 1e-3   # seconds per window unit (interval tick)
    queue_budget: int = 512       # admission control: max queued requests
    target_p99_s: float = 0.025   # latency scale normalizing the signal
    adapt_every: int = 32         # completions per controller observation
    adaptive: bool = True         # False -> fixed window (ablation baseline)
    fixed_window_units: int = 8   # window when adaptive=False
    cache_capacity: int = 0       # per-snapshot result-cache entries (0 = off)
    scheduler: SchedulerConfig = field(default_factory=lambda: SERVE_SCHEDULER)


@dataclass
class Request:
    """One prediction request: a single feature vector for its tenant.
    ``ctx`` is the submitter's trace context (None when tracing is off);
    it rides the queue — surviving :meth:`MicroBatchQueue.requeue` across
    a scale-in reroute — so the completion span on whichever host finally
    serves the request links back into the submit trace."""
    rid: int
    tenant: str
    x: jnp.ndarray               # (F,) feature vector
    t_submit: float
    ctx: Optional[object] = None   # obs.TraceContext of the submit span


class AdaptiveWindow:
    """Batch-window controller: eq. (1) on the negated-latency signal."""

    def __init__(self, cfg: BatchConfig):
        self.cfg = cfg
        self.sched = HostScheduler(cfg.scheduler)
        self._lat: List[float] = []

    @property
    def units(self) -> int:
        if not self.cfg.adaptive:
            return self.cfg.fixed_window_units
        return self.sched.current

    @property
    def window_s(self) -> float:
        return self.units * self.cfg.base_window_s

    def record(self, latency_s: float) -> None:
        """Feed one completed-request latency; adapts every adapt_every."""
        self._lat.append(float(latency_s))
        if len(self._lat) >= self.cfg.adapt_every:
            self.observe_p99(percentile(self._lat, 99.0))
            self._lat.clear()

    def observe_p99(self, p99_s: float) -> int:
        """One controller step from an observed p99; returns window units."""
        if self.cfg.adaptive:
            self.sched.observe(-float(p99_s) / self.cfg.target_p99_s)
        return self.units


class MicroBatchQueue:
    """FIFO request queue with budget-based admission control.

    With a ``tenant_cfg`` resolver attached (the per-(tenant, host)
    :class:`~repro.serve.policy.PolicyTable` path), two per-tenant knobs
    apply on top of the host-level config, in both directions:

    * admission: a tenant's queued requests may not exceed *its* resolved
      ``queue_budget`` — a cold tenant with a small budget gets early
      backpressure instead of a deep backlog (its accepted requests stay
      near the queue head: minimum latency).  A hot tenant's budget
      *above* the host scope is honored too: its submits are admitted
      until the total queue reaches the larger of the two budgets, so
      raising a tenant is not a silent no-op;
    * batching: one dispatched batch carries at most the tenant's resolved
      ``max_batch`` of its requests; the overflow keeps its FIFO position
      for the next batch, so a hot tenant's burst cannot monopolize every
      slot of a shared batch beyond its policy's share.  A tenant cap
      above the host scope lifts the shared batch bound to match (its big
      batches ride with everyone else's policy-bounded shares).
    """

    def __init__(self, cfg: BatchConfig,
                 rid_counter: Optional[Iterator[int]] = None,
                 tenant_cfg: Optional[Callable[[str], BatchConfig]] = None):
        """``rid_counter`` lets several queues share one id space — the
        sharded fleet passes a common counter so a response's rid is unique
        across hosts, not just within one.  ``tenant_cfg`` resolves a
        tenant's effective :class:`BatchConfig` (None = host config for
        every tenant)."""
        self.cfg = cfg
        self._q: Deque[Request] = deque()
        self._rids = rid_counter
        self._next_rid = 0
        self._tenant_cfg = tenant_cfg
        self._depth: Counter = Counter()      # per-tenant queued counts
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def tenant_depth(self, tenant: str) -> int:
        return self._depth[tenant]

    def _cfg_for(self, tenant: str) -> BatchConfig:
        return self._tenant_cfg(tenant) if self._tenant_cfg else self.cfg

    def submit(self, tenant: str, x, now: float,
               ctx=None) -> Optional[Request]:
        """Enqueue; returns None (backpressure) when the tenant is at its
        resolved budget, or the total queue is at the larger of the host
        budget and the tenant's own (so a hot tenant's raised budget is
        real capacity, not a no-op behind the host cap)."""
        budget = self.cfg.queue_budget
        if self._tenant_cfg is not None:
            t_budget = self._cfg_for(tenant).queue_budget
            if self._depth[tenant] >= t_budget:
                self.rejected += 1
                return None
            budget = max(budget, t_budget)
        if len(self._q) >= budget:
            self.rejected += 1
            return None
        if self._rids is not None:
            rid = next(self._rids)
        else:
            rid = self._next_rid
            self._next_rid += 1
        req = Request(rid=rid, tenant=tenant,
                      x=jnp.asarray(x), t_submit=float(now), ctx=ctx)
        self._q.append(req)
        self._depth[tenant] += 1
        return req

    def requeue(self, req: Request) -> None:
        """Re-admit a request rerouted from a drained (scaled-in) host.
        Admission was already granted once, so the budget checks are
        skipped — dropping an accepted request is strictly worse than a
        transiently over-budget queue.  The request keeps its rid and
        original submit time (its latency keeps accruing across the move)."""
        self._q.append(req)
        self._depth[req.tenant] += 1

    def pop_all(self) -> List[Request]:
        """Drain every queued request (scale-in hand-off), FIFO order."""
        out = list(self._q)
        self._q.clear()
        self._depth.clear()
        return out

    def oldest_t(self) -> Optional[float]:
        return self._q[0].t_submit if self._q else None

    def full_batch_t(self) -> Optional[float]:
        """Submit time of the request that filled a max_batch — the earliest
        instant a size-capped batch existed — or None if under the cap."""
        if len(self._q) < self.cfg.max_batch:
            return None
        return self._q[self.cfg.max_batch - 1].t_submit

    def pop_batch(self) -> List[Request]:
        if self._tenant_cfg is None:
            n = min(len(self._q), self.cfg.max_batch)
            out = [self._q.popleft() for _ in range(n)]
        else:
            # honor per-tenant batch caps; skipped requests keep FIFO
            # order.  A queued tenant whose cap exceeds the host scope
            # lifts the shared bound — its policy promised batches that
            # big — while every tenant's own share stays policy-bounded.
            caps = {t: max(1, self._cfg_for(t).max_batch)
                    for t, d in self._depth.items() if d > 0}
            bound = max([self.cfg.max_batch] + list(caps.values()))
            # the batch can never exceed what the caps allow; stopping at
            # that bound keeps a drain against capped-out tenants linear
            bound = min(bound, sum(min(self._depth[t], c)
                                   for t, c in caps.items()))
            out, kept, taken = [], deque(), Counter()
            while self._q and len(out) < bound:
                req = self._q.popleft()
                if taken[req.tenant] >= caps[req.tenant]:
                    kept.append(req)
                    continue
                taken[req.tenant] += 1
                out.append(req)
            if kept:                   # skipped all predate the remainder
                kept.extend(self._q)
                self._q = kept
        self._depth.subtract(r.tenant for r in out)
        return out
