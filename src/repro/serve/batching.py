"""Adaptive micro-batching: request queue, batch window control, admission.

The batch window is driven by the paper's adaptive-interval rule (eq. 1,
:mod:`repro.core.scheduling`), transferred from communication scheduling to
serving:

* training: error improving/stable -> widen the sync interval (sync less);
  error regressing -> shrink it (sync more).
* serving: the observed signal is the *negated* normalized p99 latency
  ``-p99/target``.  Latency rising (queue building under load) reads as the
  signal dropping fast -> the ``de < theta1`` branch fires and the window
  *grows*, buying throughput through bigger batches.  Latency stable or
  improving reads as ``de > theta2`` -> the window *shrinks*, drifting back
  toward minimum-latency single-request dispatch when load is light.

The controller is literally :class:`~repro.core.scheduling.HostScheduler`
on that signal — same state, same clipping, same step rule — so every
property proven for eq. (1) (bounded interval, lockstep with the jit
variant) carries over to the batch window.

Admission control: a hard queue budget.  When the queue is at budget the
submit is rejected (backpressure to the caller) rather than growing an
unbounded backlog that would blow the latency SLO for everyone.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Iterator, List, Optional

import jax.numpy as jnp

from repro.configs.paper_fedboost import SchedulerConfig
from repro.core.scheduling import HostScheduler
from repro.serve.metrics import percentile

# eq.-(1) constants for the serving controller, on the -p99/target scale:
# de < theta1  (latency worsened by >8% of target)  -> grow the window
# de > theta2  (latency stable within 2% or better) -> shrink the window
SERVE_SCHEDULER = SchedulerConfig(alpha=2.0, beta=1.0,
                                  theta1=-0.08, theta2=-0.02,
                                  i_min=1, i_max=32, i_init=2)


@dataclass(frozen=True)
class BatchConfig:
    """Micro-batching policy knobs."""
    max_batch: int = 64           # hard cap on requests per dispatched batch
    base_window_s: float = 1e-3   # seconds per window unit (interval tick)
    queue_budget: int = 512       # admission control: max queued requests
    target_p99_s: float = 0.025   # latency scale normalizing the signal
    adapt_every: int = 32         # completions per controller observation
    adaptive: bool = True         # False -> fixed window (ablation baseline)
    fixed_window_units: int = 8   # window when adaptive=False
    cache_capacity: int = 0       # per-snapshot result-cache entries (0 = off)
    scheduler: SchedulerConfig = field(default_factory=lambda: SERVE_SCHEDULER)


@dataclass
class Request:
    """One prediction request: a single feature vector for its tenant."""
    rid: int
    tenant: str
    x: jnp.ndarray               # (F,) feature vector
    t_submit: float


class AdaptiveWindow:
    """Batch-window controller: eq. (1) on the negated-latency signal."""

    def __init__(self, cfg: BatchConfig):
        self.cfg = cfg
        self.sched = HostScheduler(cfg.scheduler)
        self._lat: List[float] = []

    @property
    def units(self) -> int:
        if not self.cfg.adaptive:
            return self.cfg.fixed_window_units
        return self.sched.current

    @property
    def window_s(self) -> float:
        return self.units * self.cfg.base_window_s

    def record(self, latency_s: float) -> None:
        """Feed one completed-request latency; adapts every adapt_every."""
        self._lat.append(float(latency_s))
        if len(self._lat) >= self.cfg.adapt_every:
            self.observe_p99(percentile(self._lat, 99.0))
            self._lat.clear()

    def observe_p99(self, p99_s: float) -> int:
        """One controller step from an observed p99; returns window units."""
        if self.cfg.adaptive:
            self.sched.observe(-float(p99_s) / self.cfg.target_p99_s)
        return self.units


class MicroBatchQueue:
    """FIFO request queue with budget-based admission control."""

    def __init__(self, cfg: BatchConfig,
                 rid_counter: Optional[Iterator[int]] = None):
        """``rid_counter`` lets several queues share one id space — the
        sharded fleet passes a common counter so a response's rid is unique
        across hosts, not just within one."""
        self.cfg = cfg
        self._q: Deque[Request] = deque()
        self._rids = rid_counter
        self._next_rid = 0
        self.rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def submit(self, tenant: str, x, now: float) -> Optional[Request]:
        """Enqueue; returns None (backpressure) when the queue is at budget."""
        if len(self._q) >= self.cfg.queue_budget:
            self.rejected += 1
            return None
        if self._rids is not None:
            rid = next(self._rids)
        else:
            rid = self._next_rid
            self._next_rid += 1
        req = Request(rid=rid, tenant=tenant,
                      x=jnp.asarray(x), t_submit=float(now))
        self._q.append(req)
        return req

    def oldest_t(self) -> Optional[float]:
        return self._q[0].t_submit if self._q else None

    def full_batch_t(self) -> Optional[float]:
        """Submit time of the request that filled a max_batch — the earliest
        instant a size-capped batch existed — or None if under the cap."""
        if len(self._q) < self.cfg.max_batch:
            return None
        return self._q[self.cfg.max_batch - 1].t_submit

    def pop_batch(self) -> List[Request]:
        n = min(len(self._q), self.cfg.max_batch)
        return [self._q.popleft() for _ in range(n)]
