"""Batched serving hot path: pack one micro-batch's requests across tenants
into padded (B, T, N) blocks and evaluate them in a single kernel launch.

Each slot b of the packed block is one tenant present in the batch: its
requests become the N sample columns, its ensemble the T learner rows, both
padded to the widest tenant (zero-alpha rows / dummy columns contribute
nothing — the same padding contract as the 2-D ``ensemble_vote`` wrapper).

Three paths:

* stump ensembles (the paper's weak learner, fed_mesh's wire format): one
  cheap host-side feature gather builds ``xsel[b,t,n] = x_b[n, feat_{b,t}]``
  and the fused ``stump_vote_batched`` Pallas kernel computes margins + vote
  in one VMEM-resident pass.
* stump ensembles under a ``fused_fingerprint`` kernel policy with a
  result cache attached: the one-launch ``stump_vote_fp_batched`` kernel
  additionally emits a per-request xor-fold feature fingerprint, which
  keys the result cache directly — no host-side ``feature_hash`` walk of
  any feature vector on the submit path.
* generic weak learners (logistic / mlp): per-learner predict builds the
  margin stack, then ``ensemble_vote_batched`` does the weighted vote.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels.dispatch import KernelPolicy
from repro.models.weak import get_weak_learner
from repro.serve.batching import Request
from repro.serve.cache import ResultCache, feature_hash, fingerprint_key
from repro.serve.registry import EnsembleRegistry, EnsembleSnapshot


@dataclass(frozen=True)
class Response:
    rid: int
    tenant: str
    margin: float               # ensemble margin H(x)
    label: float                # sign(H(x)) in {-1, +1}
    snapshot_version: int       # 0 = tenant had no published ensemble
    t_submit: float


@dataclass(frozen=True)
class EvalStats:
    """Per-batch split of where each request's margin came from."""
    kernel_requests: int = 0    # packed into the Pallas vote kernels
    cached_requests: int = 0    # answered from the result cache
    abstained_requests: int = 0  # cold tenants (no snapshot yet)
    deduped_requests: int = 0   # in-batch duplicates of a kernel request
    fp_hits: int = 0            # fused-path cache hits (kernel fingerprint)


class BatchEvaluator:
    """Evaluates micro-batches against the registry's latest snapshots.

    With a :class:`ResultCache` attached, each request is first looked up
    under ``(tenant, snapshot version, feature hash)`` *before* packing —
    hits skip the kernel entirely and misses fill the cache after the vote,
    so repeated hot feature vectors cost one hash instead of one kernel
    slot.  ``last_eval`` reports the kernel/cached/abstained split of the
    most recent batch (the dispatcher's simulated service-time input).

    The kernel backend is *not* captured at construction: every evaluate()
    re-resolves it through ``policy`` (or the process default), so an env
    or calibration-table change — or a TPU hot-attach — takes effect on
    the next batch without rebuilding the evaluator.  The deprecated
    ``interpret=`` bool is kept as a shim that pins the corresponding
    backend explicitly.
    """

    def __init__(self, registry: EnsembleRegistry, *,
                 policy: Optional[KernelPolicy] = None,
                 interpret: Optional[bool] = None,
                 cache: Optional[ResultCache] = None,
                 policy_for: Optional[
                     Callable[[str], Optional[KernelPolicy]]] = None):
        self.registry = registry
        self.policy = policy
        # per-tenant kernel-policy resolver (the PolicyTable path): tenants
        # resolving to distinct policies are packed into separate kernel
        # launches; a None resolution falls back to ``policy``.
        self.policy_for = policy_for
        self._backend_override: Optional[str] = None
        if interpret is not None:
            warnings.warn(
                "BatchEvaluator(interpret=...) is deprecated; pass "
                "policy=KernelPolicy(backend=...) instead",
                DeprecationWarning, stacklevel=2)
            self._backend_override = "interpret" if interpret else "mosaic"
        self.cache = cache
        self.last_eval = EvalStats()
        # cumulative launch/hash accounting (the fused-fingerprint path's
        # whole point is driving both down; tests pin the deltas)
        self.kernel_launches = 0
        self.host_hash_calls = 0
        self._fp_hits = 0
        self._predict_cache: Dict[str, object] = {}

    def evaluate(self, batch: Sequence[Request]) -> List[Response]:
        by_tenant: Dict[str, List[Request]] = {}
        for r in batch:
            by_tenant.setdefault(r.tenant, []).append(r)

        margins: Dict[int, float] = {}          # rid -> margin
        versions: Dict[str, int] = {}           # tenant -> snapshot served
        stump_group: List[Tuple[EnsembleSnapshot, List[Request]]] = []
        fused_group: List[Tuple[EnsembleSnapshot, List[Request]]] = []
        generic_group: List[Tuple[EnsembleSnapshot, List[Request]]] = []
        fills: List[Tuple[str, int, bytes, int]] = []  # cache misses to fill
        dupes: List[Tuple[int, int]] = []       # (dup rid, evaluated rid)
        n_cached = n_abstained = n_deduped = 0
        self._fp_hits = 0
        for tenant, reqs in by_tenant.items():
            snap = self.registry.latest(tenant)
            if snap is None or snap.n_learners == 0:
                versions[tenant] = 0
                n_abstained += len(reqs)
                for r in reqs:                  # cold tenant: abstain at 0
                    margins[r.rid] = 0.0
                continue
            versions[tenant] = snap.version
            fused = (self.cache is not None and snap.weak_name == "stump"
                     and getattr(self._resolved_policy(tenant),
                                 "fused_fingerprint", False))
            if fused:
                # the kernel computes the cache key in-launch: skip the
                # host-side hash walk entirely and pack every request
                fused_group.append((snap, reqs))
                continue
            if self.cache is not None:          # consult before packing
                pending: List[Request] = []
                first_rid: Dict[bytes, int] = {}
                for r in reqs:
                    xh = feature_hash(r.x)
                    self.host_hash_calls += 1
                    hit = self.cache.lookup(tenant, snap.version, xh)
                    if hit is not None:
                        margins[r.rid] = hit
                        n_cached += 1
                    elif xh in first_rid:       # in-batch duplicate: one
                        dupes.append((r.rid, first_rid[xh]))  # kernel slot
                        n_deduped += 1
                    else:
                        first_rid[xh] = r.rid
                        fills.append((tenant, snap.version, xh, r.rid))
                        pending.append(r)
                reqs = pending
            if reqs:
                (stump_group if snap.weak_name == "stump"
                 else generic_group).append((snap, reqs))

        for pol, sub in self._by_policy(fused_group):
            self._eval_stumps_fused(sub, margins, pol)
        for pol, sub in self._by_policy(stump_group):
            self._eval_stumps(sub, margins, pol)
        for pol, sub in self._by_policy(generic_group):
            self._eval_generic(sub, margins, pol)
        for rid, src_rid in dupes:              # fan the one margin out
            margins[rid] = margins[src_rid]
        if self.cache is not None:              # fill after the vote
            for tenant, version, xh, rid in fills:
                self.cache.put(tenant, version, xh, margins[rid])
        self.last_eval = EvalStats(
            kernel_requests=len(batch) - n_cached - n_abstained - n_deduped,
            cached_requests=n_cached, abstained_requests=n_abstained,
            deduped_requests=n_deduped, fp_hits=self._fp_hits)

        return [Response(
            rid=r.rid, tenant=r.tenant, margin=margins[r.rid],
            label=1.0 if margins[r.rid] > 0 else -1.0,
            snapshot_version=versions[r.tenant],
            t_submit=r.t_submit) for r in batch]

    # ------------------------------------------------------ policy grouping
    def _resolved_policy(self, tenant: str) -> Optional[KernelPolicy]:
        if self.policy_for is not None:
            p = self.policy_for(tenant)
            if p is not None:
                return p
        return self.policy

    @staticmethod
    def _policy_key(pol: Optional[KernelPolicy]):
        """Value key for launch grouping: two policies that would resolve
        identically share one packed launch — tenants loaded from a JSON
        table each get their own KernelPolicy instance, and partitioning
        by object identity would turn one cross-tenant batch into one
        kernel launch per tenant."""
        if pol is None:
            return None
        return (pol.backend, pol.env_var,
                getattr(pol, "fused_fingerprint", False),
                tuple(sorted(pol.table.items())))

    def _by_policy(self, group):
        """Partition one weak-learner group into per-kernel-policy launches.
        Without a resolver this is a single launch under ``self.policy`` —
        the pre-policy-table behavior, bit for bit."""
        if not group:
            return []
        if self.policy_for is None:
            return [(self.policy, group)]
        parts: Dict[object, Tuple[Optional[KernelPolicy], list]] = {}
        for snap, reqs in group:
            pol = self._resolved_policy(snap.tenant)
            parts.setdefault(self._policy_key(pol),
                             (pol, []))[1].append((snap, reqs))
        return list(parts.values())

    # ----------------------------------------------------------- stump path
    def _pack_stumps(self, group):
        """Pad one stump group into the (B, T, N) kernel block."""
        B = len(group)
        T = max(s.n_learners for s, _ in group)
        N = max(len(reqs) for _, reqs in group)
        xsel = np.zeros((B, T, N), np.float32)
        thr = np.zeros((B, T), np.float32)
        pol = np.ones((B, T), np.float32)
        alf = np.zeros((B, T), np.float32)
        for b, (snap, reqs) in enumerate(group):
            t_b, n_b = snap.n_learners, len(reqs)
            sp = np.asarray(snap.stump_params)                 # (t_b, 4)
            x = np.stack([np.asarray(r.x, np.float32) for r in reqs])
            feat = sp[:, 0].astype(np.int32)
            xsel[b, :t_b, :n_b] = x[:, feat].T                 # (t_b, n_b)
            thr[b, :t_b] = sp[:, 1]
            pol[b, :t_b] = sp[:, 2]
            alf[b, :t_b] = np.asarray(snap.alphas)
        return xsel, thr, pol, alf

    def _eval_stumps(self, group, margins: Dict[int, float],
                     policy: Optional[KernelPolicy]) -> None:
        xsel, thr, pol, alf = self._pack_stumps(group)
        self.kernel_launches += 1
        out = np.asarray(kops.stump_vote_batched(
            jnp.asarray(xsel), jnp.asarray(thr), jnp.asarray(pol),
            jnp.asarray(alf), policy=policy,
            backend=self._backend_override))
        for b, (_, reqs) in enumerate(group):
            for n, r in enumerate(reqs):
                margins[r.rid] = float(out[b, n])

    def _eval_stumps_fused(self, group, margins: Dict[int, float],
                           policy: Optional[KernelPolicy]) -> None:
        """One-launch path: the kernel emits margins *and* the cache key.

        Every request is packed (no pre-lookup — that would need a host
        hash); the fingerprint the kernel computed then answers hits from
        prior batches and fills misses.  A cached margin is bit-identical
        to the freshly computed one (the padding contract makes padded
        slots exact zeros), so serving the cache value on a hit keeps
        replay batches byte-stable."""
        xsel, thr, pol, alf = self._pack_stumps(group)
        self.kernel_launches += 1
        out, f0, f1 = kops.stump_vote_fp_batched(
            jnp.asarray(xsel), jnp.asarray(thr), jnp.asarray(pol),
            jnp.asarray(alf), policy=policy,
            backend=self._backend_override)
        out, f0, f1 = np.asarray(out), np.asarray(f0), np.asarray(f1)
        for b, (snap, reqs) in enumerate(group):
            tenant, version = snap.tenant, snap.version
            for n, r in enumerate(reqs):
                key = fingerprint_key(f0[b, n], f1[b, n])
                hit = self.cache.lookup(tenant, version, key)
                if hit is not None:             # prior batch or in-batch dup
                    self._fp_hits += 1
                    margins[r.rid] = hit
                else:
                    margins[r.rid] = float(out[b, n])
                    self.cache.put(tenant, version, key, margins[r.rid])

    # --------------------------------------------------------- generic path
    def _predict_fn(self, weak_name: str):
        if weak_name not in self._predict_cache:
            self._predict_cache[weak_name] = get_weak_learner(weak_name).predict
        return self._predict_cache[weak_name]

    def _eval_generic(self, group, margins: Dict[int, float],
                      policy: Optional[KernelPolicy]) -> None:
        B = len(group)
        T = max(s.n_learners for s, _ in group)
        N = max(len(reqs) for _, reqs in group)
        m = np.zeros((B, T, N), np.float32)
        alf = np.zeros((B, T), np.float32)
        for b, (snap, reqs) in enumerate(group):
            predict = self._predict_fn(snap.weak_name)
            x = jnp.stack([jnp.asarray(r.x) for r in reqs])
            stack = jnp.stack([predict(p, x) for p in snap.learners])
            m[b, :snap.n_learners, :len(reqs)] = np.asarray(stack)
            alf[b, :snap.n_learners] = np.asarray(snap.alphas)
        self.kernel_launches += 1
        out = np.asarray(kops.ensemble_vote_batched(
            jnp.asarray(m), jnp.asarray(alf), policy=policy,
            backend=self._backend_override))
        for b, (_, reqs) in enumerate(group):
            for n, r in enumerate(reqs):
                margins[r.rid] = float(out[b, n])
