# Serving subsystem for federated boosted ensembles: training publishes
# immutable versioned snapshots into a multi-tenant registry; an adaptive
# micro-batcher (the paper's eq.-1 controller on a latency signal) packs
# request traffic across tenants into padded blocks for the batched Pallas
# ensemble-vote kernels.  The sharded layer partitions tenants across
# hosts by rendezvous hashing and replicates snapshots with anti-entropy
# gossip; the result cache memoizes margins per (tenant, version, x-hash).
# FleetAutoscaler scales the host count on queue-depth/p99 pressure (the
# same eq.-1 controller), and PolicyTable resolves batching + kernel
# policies per (tenant, host).
from repro.kernels.dispatch import KernelPolicy  # noqa: F401  (re-export:
# serving components accept policy=KernelPolicy(...) for backend dispatch)
from repro.serve.registry import (  # noqa: F401
    EnsembleRegistry, EnsembleSnapshot, pack_stumps)
from repro.serve.batching import (  # noqa: F401
    AdaptiveWindow, BatchConfig, MicroBatchQueue, Request, SERVE_SCHEDULER)
from repro.serve.cache import (  # noqa: F401
    CacheStats, ResultCache, feature_hash)
from repro.serve.engine import (  # noqa: F401
    BatchEvaluator, EvalStats, Response)
from repro.serve.metrics import ServeMetrics, TenantMetrics  # noqa: F401
from repro.serve.policy import PolicyTable  # noqa: F401
from repro.serve.service import (  # noqa: F401
    EnsembleServer, ShardedEnsembleServer)
from repro.serve.shard import (  # noqa: F401
    GossipConfig, GossipStats, ShardCluster, ShardHost,
    rendezvous_owner, rendezvous_rank, staleness_weight)
from repro.serve.autoscale import (  # noqa: F401
    AutoscaleConfig, AutoscaleStats, FleetAutoscaler)
