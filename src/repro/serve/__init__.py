# Serving subsystem for federated boosted ensembles: training publishes
# immutable versioned snapshots into a multi-tenant registry; an adaptive
# micro-batcher (the paper's eq.-1 controller on a latency signal) packs
# request traffic across tenants into padded blocks for the batched Pallas
# ensemble-vote kernels.
from repro.serve.registry import (  # noqa: F401
    EnsembleRegistry, EnsembleSnapshot, pack_stumps)
from repro.serve.batching import (  # noqa: F401
    AdaptiveWindow, BatchConfig, MicroBatchQueue, Request, SERVE_SCHEDULER)
from repro.serve.engine import BatchEvaluator, Response  # noqa: F401
from repro.serve.metrics import ServeMetrics, TenantMetrics  # noqa: F401
from repro.serve.service import EnsembleServer  # noqa: F401
