"""EnsembleServer: the serving front door.

Composes the admission-controlled :class:`MicroBatchQueue`, the eq.-(1)
:class:`AdaptiveWindow`, the packed-batch :class:`BatchEvaluator`, and
:class:`ServeMetrics` into a single clock-agnostic server:

* ``submit(tenant, x, now)`` enqueues one request and opportunistically
  dispatches any batches already due; it returns ``(accepted, responses)``
  where ``accepted=False`` signals admission-control rejection
  (backpressure) to the caller.
* ``advance(now)`` dispatches every batch whose window has expired (or that
  hit the size cap) up to ``now``; a batch dispatches no earlier than the
  previous batch finished (single-server discipline).
* ``drain()`` flushes the queue regardless of ``now``.

Timestamps are supplied by the caller, so the same server runs under a real
wall clock (the `serve_ensemble` launch driver) and under the simulated
clock of the closed-loop load benchmark.  Service time per dispatched batch
is either measured (wall-clock mode, default) or produced by an injected
``service_model(n_kernel) -> seconds`` (simulation mode), where
``n_kernel`` counts the requests that actually reached the vote kernels —
result-cache hits, in-batch duplicates of a pending kernel request, and
cold-tenant abstains cost no kernel time, so a warm cache shrinks the
modeled service time exactly as it shrinks the measured one.

A per-snapshot :class:`~repro.serve.cache.ResultCache` is enabled by
``BatchConfig.cache_capacity > 0`` (or injected via ``cache=``); the server
attaches its invalidation hook to the registry so snapshots landing by
publish *or* gossip sweep that tenant's stale entries.
"""
from __future__ import annotations

import itertools
import math
import time
import warnings
from typing import Callable, Iterator, List, Optional, Tuple

from repro.kernels.dispatch import KernelPolicy
from repro.serve.batching import AdaptiveWindow, BatchConfig, MicroBatchQueue
from repro.serve.cache import ResultCache
from repro.serve.engine import BatchEvaluator, Response
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import EnsembleRegistry


def _interpret_shim(policy: Optional[KernelPolicy],
                    interpret: Optional[bool],
                    owner: str) -> Optional[KernelPolicy]:
    """Deprecated ``interpret=`` bool -> a backend-forcing KernelPolicy.
    Like the per-call explicit arg it replaces, the bool outranks a policy
    passed alongside it: that policy's calibration table is kept but its
    resolution is pinned to the corresponding backend."""
    if interpret is None:
        return policy
    warnings.warn(
        f"{owner}(interpret=...) is deprecated; pass "
        "policy=KernelPolicy(backend=...) instead",
        DeprecationWarning, stacklevel=3)
    backend = "interpret" if interpret else "mosaic"
    if policy is None:
        return KernelPolicy(backend=backend)
    return KernelPolicy(backend=backend, table=policy.table,
                        env_var=policy.env_var)


class EnsembleServer:
    def __init__(self, registry: EnsembleRegistry,
                 cfg: Optional[BatchConfig] = None, *,
                 service_model: Optional[Callable[[int], float]] = None,
                 metrics: Optional[ServeMetrics] = None,
                 policy: Optional[KernelPolicy] = None,
                 interpret: Optional[bool] = None,
                 cache: Optional[ResultCache] = None,
                 rid_counter: Optional[Iterator[int]] = None):
        self.cfg = cfg or BatchConfig()
        self.registry = registry
        self.policy = _interpret_shim(policy, interpret, "EnsembleServer")
        self.queue = MicroBatchQueue(self.cfg, rid_counter)
        self.window = AdaptiveWindow(self.cfg)
        if cache is None and self.cfg.cache_capacity > 0:
            cache = ResultCache(self.cfg.cache_capacity)
        self.cache = cache
        self._unsubscribe = (cache.attach(registry) if cache is not None
                             else None)
        self.evaluator = BatchEvaluator(registry, policy=self.policy,
                                        cache=cache)
        self.metrics = metrics or ServeMetrics()
        self.service_model = service_model
        self._busy_until = -math.inf     # single server: one batch in flight

    # ------------------------------------------------------------- intake
    def submit(self, tenant: str, x, now: float
               ) -> Tuple[bool, List[Response]]:
        """Enqueue one request.  Returns ``(accepted, responses)``:
        ``accepted`` is False when admission control rejected the request
        (backpressure — the caller must retry or shed it), and
        ``responses`` holds any batches that came due at or before ``now``
        (possibly including this request, if it filled a batch)."""
        out = self.advance(now)          # free queue slots already due
        req = self.queue.submit(tenant, x, now)
        if req is None:
            self.metrics.record_rejected(tenant)
        else:
            self.metrics.record_submit(now, self.queue.depth)
            out += self.advance(now)     # dispatch a batch this one filled
        return req is not None, out

    # ----------------------------------------------------------- dispatch
    def _next_due(self) -> Optional[float]:
        """Earliest instant the head batch may dispatch, or None if empty."""
        oldest = self.queue.oldest_t()
        if oldest is None:
            return None
        full_t = self.queue.full_batch_t()
        due = full_t if full_t is not None else oldest + self.window.window_s
        return max(due, self._busy_until)

    def advance(self, now: float) -> List[Response]:
        """Dispatch every batch due at or before ``now``."""
        out: List[Response] = []
        while True:
            due = self._next_due()
            if due is None or due > now:
                return out
            out.extend(self._dispatch(due))

    def drain(self) -> List[Response]:
        """Flush the queue: dispatch remaining batches as their windows (or
        the server) free up, regardless of the caller's clock."""
        return self.advance(math.inf)

    def close(self) -> None:
        """Detach this server's cache-invalidation subscription so a
        retired server doesn't stay pinned on a long-lived registry."""
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None

    def _dispatch(self, at: float) -> List[Response]:
        batch = self.queue.pop_batch()
        if self.service_model is not None:
            responses = self.evaluator.evaluate(batch)
            service_s = float(self.service_model(
                self.evaluator.last_eval.kernel_requests))
        else:
            t0 = time.perf_counter()
            responses = self.evaluator.evaluate(batch)
            service_s = time.perf_counter() - t0
        finish = at + service_s
        self._busy_until = finish
        self.metrics.record_batch(len(batch), self.window.units, finish)
        for r in responses:
            latency = finish - r.t_submit
            self.window.record(latency)
            self.metrics.record_completion(
                r.tenant, latency,
                staleness_s=self.registry.staleness(r.tenant, finish),
                version=r.snapshot_version)
        return responses


class ShardedEnsembleServer:
    """Multi-host serving front door over a :class:`ShardCluster`.

    One :class:`EnsembleServer` (queue + window + evaluator + cache) runs
    per cluster host against that host's registry replica.  ``submit``
    routes each request to the tenant's rendezvous owner among *up* hosts;
    when the owner is marked down, routing falls over to the next host in
    rendezvous rank, which serves the tenant from its gossiped replica —
    the whole point of anti-entropy dissemination.  Requests are rejected
    (``accepted=False``) only when every host is down or the routed host's
    admission control pushes back.
    """

    def __init__(self, cluster, cfg: Optional[BatchConfig] = None, *,
                 service_model: Optional[Callable[[int], float]] = None,
                 policy: Optional[KernelPolicy] = None,
                 interpret: Optional[bool] = None):
        self.cluster = cluster
        self.cfg = cfg or BatchConfig()
        self.policy = _interpret_shim(policy, interpret,
                                      "ShardedEnsembleServer")
        rids = itertools.count()         # one id space across the fleet
        self.servers: dict = {
            hid: EnsembleServer(host.registry, self.cfg,
                                service_model=service_model,
                                policy=self.policy, rid_counter=rids)
            for hid, host in cluster.hosts.items()}

    def server_for(self, tenant: str) -> Optional[EnsembleServer]:
        host = self.cluster.route(tenant)
        return self.servers[host.host_id] if host else None

    def submit(self, tenant: str, x, now: float
               ) -> Tuple[bool, List[Response]]:
        server = self.server_for(tenant)
        if server is None:                     # total outage: shed the load
            return False, []
        return server.submit(tenant, x, now)

    def advance(self, now: float) -> List[Response]:
        out: List[Response] = []
        for s in self.servers.values():
            out.extend(s.advance(now))
        return out

    def drain(self) -> List[Response]:
        out: List[Response] = []
        for s in self.servers.values():
            out.extend(s.drain())
        return out

    def close(self) -> None:
        for s in self.servers.values():
            s.close()

    # -------------------------------------------------------------- report
    def cache_stats(self) -> dict:
        """Fleet-wide result-cache counters summed over hosts."""
        agg = {"hits": 0, "misses": 0, "fills": 0, "invalidated": 0,
               "evicted": 0}
        for s in self.servers.values():
            if s.cache is None:
                continue
            st = s.cache.stats
            agg["hits"] += st.hits
            agg["misses"] += st.misses
            agg["fills"] += st.fills
            agg["invalidated"] += st.invalidated
            agg["evicted"] += st.evicted
        n = agg["hits"] + agg["misses"]
        agg["hit_rate"] = agg["hits"] / n if n else 0.0
        return agg

    def report(self) -> dict:
        """Merged fleet report plus the per-host breakdown."""
        merged = ServeMetrics()
        per_host = {}
        for hid, s in self.servers.items():
            rep = s.metrics.report()
            per_host[hid] = rep
            for name, t in s.metrics.tenants.items():
                mt = merged.tenant(name)
                mt.completed += t.completed
                mt.rejected += t.rejected
                mt.latencies.extend(t.latencies)
                mt.staleness_sum += t.staleness_sum
                mt.last_version = max(mt.last_version, t.last_version)
            merged.batch_size_hist.update(s.metrics.batch_size_hist)
            merged.window_units_hist.update(s.metrics.window_units_hist)
            merged.n_batches += s.metrics.n_batches
            merged.queue_depth_peak = max(merged.queue_depth_peak,
                                          s.metrics.queue_depth_peak)
            t0, t1 = s.metrics.first_submit_t, s.metrics.last_finish_t
            if t0 is not None:
                merged.first_submit_t = (t0 if merged.first_submit_t is None
                                         else min(merged.first_submit_t, t0))
            if t1 is not None:
                merged.last_finish_t = (t1 if merged.last_finish_t is None
                                        else max(merged.last_finish_t, t1))
        rep = merged.report()
        rep["per_host"] = per_host
        rep["cache"] = self.cache_stats()
        return rep
