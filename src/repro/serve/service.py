"""EnsembleServer: the serving front door.

Composes the admission-controlled :class:`MicroBatchQueue`, the eq.-(1)
:class:`AdaptiveWindow`, the packed-batch :class:`BatchEvaluator`, and
:class:`ServeMetrics` into a single clock-agnostic server:

* ``submit(tenant, x, now)`` enqueues one request and opportunistically
  dispatches any batches already due; it returns ``(accepted, responses)``
  where ``accepted=False`` signals admission-control rejection
  (backpressure) to the caller.
* ``advance(now)`` dispatches every batch whose window has expired (or that
  hit the size cap) up to ``now``; a batch dispatches no earlier than the
  previous batch finished (single-server discipline).
* ``drain()`` flushes the queue regardless of ``now``.

Timestamps are supplied by the caller, so the same server runs under a real
wall clock (the `serve_ensemble` launch driver) and under the simulated
clock of the closed-loop load benchmark.  Service time per dispatched batch
is either measured (wall-clock mode, default) or produced by an injected
``service_model(batch_size) -> seconds`` (simulation mode).
"""
from __future__ import annotations

import math
import time
from typing import Callable, List, Optional, Tuple

from repro.serve.batching import AdaptiveWindow, BatchConfig, MicroBatchQueue
from repro.serve.engine import BatchEvaluator, Response
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import EnsembleRegistry


class EnsembleServer:
    def __init__(self, registry: EnsembleRegistry,
                 cfg: Optional[BatchConfig] = None, *,
                 service_model: Optional[Callable[[int], float]] = None,
                 metrics: Optional[ServeMetrics] = None,
                 interpret: Optional[bool] = None):
        self.cfg = cfg or BatchConfig()
        self.registry = registry
        self.queue = MicroBatchQueue(self.cfg)
        self.window = AdaptiveWindow(self.cfg)
        self.evaluator = BatchEvaluator(registry, interpret=interpret)
        self.metrics = metrics or ServeMetrics()
        self.service_model = service_model
        self._busy_until = -math.inf     # single server: one batch in flight

    # ------------------------------------------------------------- intake
    def submit(self, tenant: str, x, now: float
               ) -> Tuple[bool, List[Response]]:
        """Enqueue one request.  Returns ``(accepted, responses)``:
        ``accepted`` is False when admission control rejected the request
        (backpressure — the caller must retry or shed it), and
        ``responses`` holds any batches that came due at or before ``now``
        (possibly including this request, if it filled a batch)."""
        out = self.advance(now)          # free queue slots already due
        req = self.queue.submit(tenant, x, now)
        if req is None:
            self.metrics.record_rejected(tenant)
        else:
            self.metrics.record_submit(now, self.queue.depth)
            out += self.advance(now)     # dispatch a batch this one filled
        return req is not None, out

    # ----------------------------------------------------------- dispatch
    def _next_due(self) -> Optional[float]:
        """Earliest instant the head batch may dispatch, or None if empty."""
        oldest = self.queue.oldest_t()
        if oldest is None:
            return None
        full_t = self.queue.full_batch_t()
        due = full_t if full_t is not None else oldest + self.window.window_s
        return max(due, self._busy_until)

    def advance(self, now: float) -> List[Response]:
        """Dispatch every batch due at or before ``now``."""
        out: List[Response] = []
        while True:
            due = self._next_due()
            if due is None or due > now:
                return out
            out.extend(self._dispatch(due))

    def drain(self) -> List[Response]:
        """Flush the queue: dispatch remaining batches as their windows (or
        the server) free up, regardless of the caller's clock."""
        return self.advance(math.inf)

    def _dispatch(self, at: float) -> List[Response]:
        batch = self.queue.pop_batch()
        if self.service_model is not None:
            responses = self.evaluator.evaluate(batch)
            service_s = float(self.service_model(len(batch)))
        else:
            t0 = time.perf_counter()
            responses = self.evaluator.evaluate(batch)
            service_s = time.perf_counter() - t0
        finish = at + service_s
        self._busy_until = finish
        self.metrics.record_batch(len(batch), self.window.units, finish)
        for r in responses:
            latency = finish - r.t_submit
            self.window.record(latency)
            self.metrics.record_completion(
                r.tenant, latency,
                staleness_s=self.registry.staleness(r.tenant, finish),
                version=r.snapshot_version)
        return responses
