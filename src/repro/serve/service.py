"""EnsembleServer: the serving front door.

Composes the admission-controlled :class:`MicroBatchQueue`, the eq.-(1)
:class:`AdaptiveWindow`, the packed-batch :class:`BatchEvaluator`, and
:class:`ServeMetrics` into a single clock-agnostic server:

* ``submit(tenant, x, now)`` enqueues one request and opportunistically
  dispatches any batches already due; it returns ``(accepted, responses)``
  where ``accepted=False`` signals admission-control rejection
  (backpressure) to the caller.
* ``advance(now)`` dispatches every batch whose window has expired (or that
  hit the size cap) up to ``now``; a batch dispatches no earlier than the
  previous batch finished (single-server discipline).
* ``drain()`` flushes the queue regardless of ``now``.

Timestamps are supplied by the caller, so the same server runs under a real
wall clock (the `serve_ensemble` launch driver) and under the simulated
clock of the closed-loop load benchmark.  Service time per dispatched batch
is either measured (wall-clock mode, default) or produced by an injected
``service_model(n_kernel) -> seconds`` (simulation mode), where
``n_kernel`` counts the requests that actually reached the vote kernels —
result-cache hits, in-batch duplicates of a pending kernel request, and
cold-tenant abstains cost no kernel time, so a warm cache shrinks the
modeled service time exactly as it shrinks the measured one.

A per-snapshot :class:`~repro.serve.cache.ResultCache` is enabled by
``BatchConfig.cache_capacity > 0`` (or injected via ``cache=``); the server
attaches its invalidation hook to the registry so snapshots landing by
publish *or* gossip sweep that tenant's stale entries.
"""
from __future__ import annotations

import itertools
import math
import time
import warnings
from typing import Callable, Iterator, List, Optional, Tuple

from repro import obs
from repro.kernels.dispatch import KernelPolicy
from repro.serve.batching import AdaptiveWindow, BatchConfig, MicroBatchQueue
from repro.serve.cache import ResultCache
from repro.serve.engine import BatchEvaluator, Response
from repro.serve.metrics import ServeMetrics, weighted_percentile
from repro.serve.policy import PolicyTable
from repro.serve.registry import EnsembleRegistry


def _interpret_shim(policy: Optional[KernelPolicy],
                    interpret: Optional[bool],
                    owner: str) -> Optional[KernelPolicy]:
    """Deprecated ``interpret=`` bool -> a backend-forcing KernelPolicy.
    Like the per-call explicit arg it replaces, the bool outranks a policy
    passed alongside it: that policy's calibration table is kept but its
    resolution is pinned to the corresponding backend."""
    if interpret is None:
        return policy
    warnings.warn(
        f"{owner}(interpret=...) is deprecated; pass "
        "policy=KernelPolicy(backend=...) instead",
        DeprecationWarning, stacklevel=3)
    backend = "interpret" if interpret else "mosaic"
    if policy is None:
        return KernelPolicy(backend=backend)
    return KernelPolicy(backend=backend, table=policy.table,
                        env_var=policy.env_var,
                        fused_fingerprint=getattr(policy,
                                                  "fused_fingerprint",
                                                  False))


class EnsembleServer:
    def __init__(self, registry: EnsembleRegistry,
                 cfg: Optional[BatchConfig] = None, *,
                 service_model: Optional[Callable[[int], float]] = None,
                 metrics: Optional[ServeMetrics] = None,
                 policy: Optional[KernelPolicy] = None,
                 interpret: Optional[bool] = None,
                 cache: Optional[ResultCache] = None,
                 rid_counter: Optional[Iterator[int]] = None,
                 policy_table: Optional[PolicyTable] = None,
                 host_id: Optional[str] = None):
        # per-(tenant, host) policies: the host-level slice of the table
        # supplies this server's own config, the (tenant, host) slice
        # drives per-tenant admission/batch caps in the queue and
        # per-tenant kernel policies in the evaluator.  An explicit cfg
        # passed alongside a table becomes the base the table's override
        # layers compose onto (with_default), so it is never silently
        # discarded.
        if cfg is not None and policy_table is not None:
            policy_table = policy_table.with_default(cfg)
        self.policy_table = policy_table
        self.host_id = host_id
        if policy_table is not None:
            cfg = policy_table.batch_for(host=host_id)
        self.cfg = cfg or BatchConfig()
        self.registry = registry
        self.policy = _interpret_shim(policy, interpret, "EnsembleServer")
        tenant_cfg = policy_for = None
        if policy_table is not None:
            tenant_cfg = lambda t: policy_table.batch_for(t, host_id)
            policy_for = lambda t: policy_table.kernel_for(t, host_id)
        self.queue = MicroBatchQueue(self.cfg, rid_counter,
                                     tenant_cfg=tenant_cfg)
        self.window = AdaptiveWindow(self.cfg)
        if cache is None and self.cfg.cache_capacity > 0:
            cache = ResultCache(self.cfg.cache_capacity)
        self.cache = cache
        self._unsubscribe = (cache.attach(registry) if cache is not None
                             else None)
        self.evaluator = BatchEvaluator(registry, policy=self.policy,
                                        cache=cache, policy_for=policy_for)
        self.metrics = metrics or ServeMetrics()
        self.service_model = service_model
        self.on_completion: Optional[Callable[[float], None]] = None
        # SLO feed: called (tenant, finish_t, latency_s) per completion
        self.on_slo: Optional[Callable[[str, float, float], None]] = None
        self._busy_until = -math.inf     # single server: one batch in flight

    # ------------------------------------------------------------- intake
    def submit(self, tenant: str, x, now: float, ctx=None
               ) -> Tuple[bool, List[Response]]:
        """Enqueue one request.  Returns ``(accepted, responses)``:
        ``accepted`` is False when admission control rejected the request
        (backpressure — the caller must retry or shed it), and
        ``responses`` holds any batches that came due at or before ``now``
        (possibly including this request, if it filled a batch).

        ``ctx`` is a propagated trace context from a fleet front door;
        when tracing is on and none is given, this server is the front
        door and roots the request's trace itself with a ``serve.submit``
        point."""
        sub = None
        if ctx is None and obs.enabled():
            sub = obs.point("serve.submit", sim_t0=now, sim_t1=now,
                            tenant=tenant, host=self.host_id or "")
            ctx = sub.ctx
        out = self.advance(now)          # free queue slots already due
        req = self.queue.submit(tenant, x, now, ctx=ctx)
        if req is None:
            self.metrics.record_rejected(tenant)
            if sub is not None:
                sub.set(accepted=False)
        else:
            self.metrics.record_submit(now, self.queue.depth)
            if sub is not None:
                sub.set(rid=req.rid, accepted=True)
            out += self.advance(now)     # dispatch a batch this one filled
        return req is not None, out

    # ----------------------------------------------------------- dispatch
    def _window_due(self) -> Optional[float]:
        """Instant the head batch becomes dispatchable — its micro-batch
        window closing (or size cap filling) — *ignoring* server busyness.
        The gap between this and the actual dispatch instant is queueing
        delay behind the in-flight batch, which the request traces report
        separately from batching delay."""
        oldest = self.queue.oldest_t()
        if oldest is None:
            return None
        full_t = self.queue.full_batch_t()
        return full_t if full_t is not None else oldest + self.window.window_s

    def _next_due(self) -> Optional[float]:
        """Earliest instant the head batch may dispatch, or None if empty."""
        due = self._window_due()
        return None if due is None else max(due, self._busy_until)

    def advance(self, now: float) -> List[Response]:
        """Dispatch every batch due at or before ``now``."""
        out: List[Response] = []
        while True:
            window_due = self._window_due()
            if window_due is None:
                return out
            due = max(window_due, self._busy_until)
            if due > now:
                return out
            out.extend(self._dispatch(due, window_due))

    def drain(self) -> List[Response]:
        """Flush the queue: dispatch remaining batches as their windows (or
        the server) free up, regardless of the caller's clock."""
        return self.advance(math.inf)

    def close(self) -> None:
        """Detach this server's cache-invalidation subscription so a
        retired server doesn't stay pinned on a long-lived registry."""
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None

    def _dispatch(self, at: float,
                  window_due: Optional[float] = None) -> List[Response]:
        # window_due <= at: `at` adds only the wait behind the in-flight
        # batch (single-server discipline).  drain()-style callers that
        # dispatch without a window bound collapse batching delay into
        # queueing delay by passing nothing.
        if window_due is None:
            window_due = at
        traced = obs.enabled()
        batch = self.queue.pop_batch()
        bsp = obs.span("serve.batch", sim_t=at, host=self.host_id or "",
                       size=len(batch))
        if self.service_model is not None:
            with obs.span("serve.kernel"):
                responses = self.evaluator.evaluate(batch)
            service_s = float(self.service_model(
                self.evaluator.last_eval.kernel_requests))
        else:
            t0 = time.perf_counter()
            with obs.span("serve.kernel"):
                responses = self.evaluator.evaluate(batch)
            service_s = time.perf_counter() - t0
        finish = at + service_s
        self._busy_until = finish
        self.metrics.record_batch(len(batch), self.window.units, finish)
        ctxs = {rq.rid: rq.ctx for rq in batch} if traced else {}
        for r in responses:
            latency = finish - r.t_submit
            self.window.record(latency)
            if self.on_completion is not None:   # autoscaler pressure feed
                self.on_completion(latency)
            if self.on_slo is not None:          # SLO error-budget feed
                self.on_slo(r.tenant, finish, latency)
            self.metrics.record_completion(
                r.tenant, latency,
                staleness_s=self.registry.staleness(r.tenant, finish),
                version=r.snapshot_version)
            if traced:
                # exact decomposition: batch_s (waiting for the window to
                # close) + queue_s (waiting for the server to free up) +
                # kernel_s (the batch's service time) == latency, whether
                # the request arrived before or after the window closed.
                # ctx= continues the request's own trace (rooted at its
                # serve.submit point, possibly on another host before a
                # reroute) while the stack parent stays the serve.batch
                # span that wall-contains the completion.
                obs.point(
                    "serve.request", sim_t0=r.t_submit, sim_t1=finish,
                    ctx=ctxs.get(r.rid), host=self.host_id or "",
                    rid=r.rid, tenant=r.tenant,
                    batch_s=max(0.0, window_due - r.t_submit),
                    queue_s=at - max(r.t_submit, window_due),
                    kernel_s=service_s, latency_s=latency)
        if traced:
            le = self.evaluator.last_eval
            bsp.set(window_units=self.window.units, service_s=service_s,
                    kernel_requests=le.kernel_requests,
                    cached=le.cached_requests, deduped=le.deduped_requests,
                    abstained=le.abstained_requests)
        bsp.end(sim_t=finish)
        return responses


class ShardedEnsembleServer:
    """Multi-host serving front door over a :class:`ShardCluster`.

    One :class:`EnsembleServer` (queue + window + evaluator + cache) runs
    per cluster host against that host's registry replica.  ``submit``
    routes each request to the tenant's rendezvous owner among *up* hosts;
    when the owner is marked down, routing falls over to the next host in
    rendezvous rank, which serves the tenant from its gossiped replica —
    the whole point of anti-entropy dissemination.  Requests are rejected
    (``accepted=False``) only when every host is down or the routed host's
    admission control pushes back; a total-outage shed is charged to the
    fleet-level metrics (there is no host to charge), so the report never
    undercounts rejected load.

    Membership is elastic: :meth:`add_host` grows the fleet behind a
    gossip-warmed replica and :meth:`remove_host` drains a victim without
    dropping any accepted request — the
    :class:`~repro.serve.autoscale.FleetAutoscaler` drives both from the
    queue-depth/p99 pressure signal.  A :class:`PolicyTable` makes batching
    and kernel policies resolve per (tenant, host).
    """

    def __init__(self, cluster, cfg: Optional[BatchConfig] = None, *,
                 service_model: Optional[Callable[[int], float]] = None,
                 policy: Optional[KernelPolicy] = None,
                 interpret: Optional[bool] = None,
                 policy_table: Optional[PolicyTable] = None):
        self.cluster = cluster
        # an explicit cfg composes with the table (it becomes the fleet
        # default the override layers stack onto) instead of being
        # silently discarded
        if cfg is not None and policy_table is not None:
            policy_table = policy_table.with_default(cfg)
        self.policy_table = policy_table
        if cfg is None and policy_table is not None:
            cfg = policy_table.batch_for()       # fleet-wide default slice
        self.cfg = cfg or BatchConfig()
        self.policy = _interpret_shim(policy, interpret,
                                      "ShardedEnsembleServer")
        self.service_model = service_model
        self._rids = itertools.count()   # one id space across the fleet
        # fleet-level counters for load shed before any host is reached
        # (total outage): there is no per-host server to charge it to
        self.metrics = ServeMetrics()
        # scaled-in hosts live on in the report as (id, metrics, cache
        # stats) — not whole servers, so churn doesn't accrete evaluators
        # and cache contents for the fleet's lifetime
        self._retired: List[Tuple[str, ServeMetrics, Optional[object]]] = []
        self._slo = None                 # optional obs.slo.SLOMonitor
        self.servers: dict = {hid: self._make_server(hid)
                              for hid in cluster.hosts}

    def _make_server(self, host_id: str) -> EnsembleServer:
        # self.policy_table already has any explicit cfg folded in as its
        # default, so the host server resolves per-host config from it;
        # without a table, the fleet cfg applies verbatim
        cfg = None if self.policy_table is not None else self.cfg
        server = EnsembleServer(self.cluster.hosts[host_id].registry, cfg,
                                service_model=self.service_model,
                                policy=self.policy, rid_counter=self._rids,
                                policy_table=self.policy_table,
                                host_id=host_id)
        if self._slo is not None:
            server.on_slo = self._slo.record_completion
        return server

    def attach_slo(self, monitor) -> None:
        """Feed every fleet outcome into an :class:`repro.obs.slo.
        SLOMonitor`: completions on whichever host serves them (hosts
        added later included), rejections/sheds at submit time."""
        self._slo = monitor
        for s in self.servers.values():
            s.on_slo = monitor.record_completion

    def server_for(self, tenant: str) -> Optional[EnsembleServer]:
        host = self.cluster.route(tenant)
        return self.servers[host.host_id] if host else None

    def host_id_taken(self, host_id: str) -> bool:
        """True if ``host_id`` is live, in the cluster, or retired —
        everything :meth:`add_host` would refuse (an id generator probes
        this instead of crashing on its first collision)."""
        return (host_id in self.servers or host_id in self.cluster.hosts
                or any(hid == host_id for hid, *_ in self._retired))

    def submit(self, tenant: str, x, now: float
               ) -> Tuple[bool, List[Response]]:
        server = self.server_for(tenant)
        if server is None:                     # total outage: shed the load
            self.metrics.record_rejected(tenant)
            if obs.enabled():
                obs.point("serve.submit", sim_t0=now, sim_t1=now,
                          tenant=tenant, host="", accepted=False)
            if self._slo is not None:
                self._slo.record(tenant, now, rejected=True)
            return False, []
        accepted, out = server.submit(tenant, x, now)
        if not accepted and self._slo is not None:
            self._slo.record(tenant, now, rejected=True)
        return accepted, out

    # ---------------------------------------------------------- membership
    def add_host(self, host_id: str, now: float = 0.0) -> EnsembleServer:
        """Scale-out: the cluster spins up a replica that warms via a
        gossip pull *before* it enters the rendezvous ring, then a fresh
        per-host server joins the fleet rid space.  A retired id cannot be
        reused — the fleet report keys per-host rows by id forever."""
        if any(hid == host_id for hid, *_ in self._retired):
            raise ValueError(
                f"host id {host_id!r} was scaled in earlier; retired ids "
                "stay reserved in the fleet report — pick a fresh id")
        self.cluster.add_host(host_id, now=now)
        server = self._make_server(host_id)
        self.servers[host_id] = server
        return server

    def remove_host(self, host_id: str, now: float = 0.0
                    ) -> Tuple[List[Response], int]:
        """Scale-in: dispatch the victim's due batches, reroute its residual
        queue along rendezvous rank onto surviving hosts (admission
        bypassed — those requests were already accepted), hand its registry
        window to a survivor, then drop the host.  Its metrics and cache
        counters stay in the fleet report.  Returns ``(responses, n)``:
        the drain-dispatched responses and the rerouted-request count."""
        victim = self.servers[host_id]
        if len(self.cluster.hosts) <= 1:
            raise ValueError(
                f"cannot scale in {host_id!r}: it is the cluster's last "
                "host (its registry window has nowhere to go)")
        others_up = any(h.up for hid, h in self.cluster.hosts.items()
                        if hid != host_id)
        if not others_up and len(victim.queue):
            raise ValueError(
                f"cannot scale in {host_id!r}: no surviving up host to "
                "take its queued requests")
        was_up = self.cluster.hosts[host_id].up
        del self.servers[host_id]
        self.cluster.mark_down(host_id)      # routing now skips the victim
        # a live victim dispatches what is already due before handing the
        # rest over; a host that was down was not serving — everything it
        # still holds reroutes rather than being "served" by a dead host
        responses = victim.advance(now) if was_up else []
        rerouted = 0
        for req in victim.queue.pop_all():
            target = self.server_for(req.tenant)
            target.queue.requeue(req)
            rerouted += 1
        victim.close()
        self._retired.append((host_id, victim.metrics,
                              victim.cache.stats if victim.cache else None))
        self.cluster.remove_host(host_id, now=now)
        return responses, rerouted

    def advance(self, now: float) -> List[Response]:
        out: List[Response] = []
        for s in self.servers.values():
            out.extend(s.advance(now))
        return out

    def drain(self) -> List[Response]:
        out: List[Response] = []
        for s in self.servers.values():
            out.extend(s.drain())
        return out

    def close(self) -> None:
        for s in self.servers.values():
            s.close()

    # -------------------------------------------------------------- report
    def _all_metrics(self) -> List[Tuple[str, str, ServeMetrics]]:
        """(host_id, status, metrics) for live and scaled-in hosts alike —
        a retired host's traffic must stay in the fleet totals."""
        out = []
        for hid, s in self.servers.items():
            host = self.cluster.hosts.get(hid)
            status = "up" if (host is not None and host.up) else "down"
            out.append((hid, status, s.metrics))
        out.extend((hid, "retired", m) for hid, m, _ in self._retired)
        return out

    def cache_stats(self) -> dict:
        """Fleet-wide result-cache counters summed over hosts (scaled-in
        hosts included)."""
        agg = {"hits": 0, "misses": 0, "fills": 0, "invalidated": 0,
               "evicted": 0}
        stats = [s.cache.stats for s in self.servers.values()
                 if s.cache is not None]
        stats.extend(st for _, _, st in self._retired if st is not None)
        for st in stats:
            agg["hits"] += st.hits
            agg["misses"] += st.misses
            agg["fills"] += st.fills
            agg["invalidated"] += st.invalidated
            agg["evicted"] += st.evicted
        n = agg["hits"] + agg["misses"]
        agg["hit_rate"] = agg["hits"] / n if n else 0.0
        return agg

    def report(self) -> dict:
        """Merged fleet report plus the per-host breakdown.  Merges the
        per-host :class:`ServeMetrics` (per-tenant reservoirs concatenated,
        ``last_version`` by max, histograms/counters summed, makespan by
        min-submit/max-finish) plus the fleet-level counters (total-outage
        rejections) across up, down, and scaled-in hosts."""
        merged = ServeMetrics()
        per_host = {}
        for hid, status, m in self._all_metrics():
            rep = m.report()
            rep["status"] = status
            per_host[hid] = rep
            self._merge_into(merged, m)
        self._merge_into(merged, self.metrics)   # outage shed, no host
        rep = merged.report()
        # fleet percentiles from the *pre-merge* per-host pairs: merging
        # re-thins full reservoirs (keeping every 8th incoming sample), so
        # quantiles over the merged reservoir would double-weight whatever
        # survived the second thinning; the exact-weight union never
        # re-thins (pinned by tests/test_obs.py merge-of-merges coverage)
        pairs = self.metrics.latency_pairs()
        for _, _, m in self._all_metrics():
            pairs.extend(m.latency_pairs())
        if pairs:
            rep["p50_ms"] = 1e3 * weighted_percentile(pairs, 50.0)
            rep["p99_ms"] = 1e3 * weighted_percentile(pairs, 99.0)
        rep["per_host"] = per_host
        rep["cache"] = self.cache_stats()
        return rep

    @staticmethod
    def _merge_into(merged: ServeMetrics, m: ServeMetrics) -> None:
        for name, t in m.tenants.items():
            merged.tenant(name).merge_from(t)
        merged.batch_size_hist.update(m.batch_size_hist)
        merged.window_units_hist.update(m.window_units_hist)
        merged.registry.counter("serve.batches").inc(m.n_batches)
        merged.registry.gauge("serve.queue_depth_peak").max(
            m.queue_depth_peak)
        t0, t1 = m.first_submit_t, m.last_finish_t
        if t0 is not None:
            merged.first_submit_t = (t0 if merged.first_submit_t is None
                                     else min(merged.first_submit_t, t0))
        if t1 is not None:
            merged.last_finish_t = (t1 if merged.last_finish_t is None
                                    else max(merged.last_finish_t, t1))
