"""llama4-scout-17b-a16e — 16-expert top-1 MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (assignment: 48L d_model=5120 40H GQA kv=8 d_ff=8192 vocab=202048, MoE 16e top-1, early fusion)",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192),
    moe_every=0,                   # every layer MoE (Scout interleave step 1)
    frontend="vision",             # early-fusion multimodal: stubbed patch embeddings
)
