"""Architecture + run configuration dataclasses.

Every assigned architecture is expressed as an :class:`ArchConfig`; the four
assigned input shapes as :class:`ShapeConfig`.  Configs are plain frozen
dataclasses so they can be hashed into jit static args and serialized into
dry-run artifacts.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kinds used by the unified stack ------------------------------------
ATTN = "attn"          # full (causal) attention
ATTN_LOCAL = "attn_local"   # sliding-window attention
MAMBA = "mamba"        # Mamba2 SSD block
# MLP kinds
MLP_DENSE = "dense"
MLP_MOE = "moe"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int            # per-expert hidden dim
    capacity_factor: float = 1.25
    # "tensor": experts replicated across data, d_ff sharded over model.
    # "expert": experts sharded over model axis (expert parallel).
    sharding: str = "tensor"
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2               # d_inner = expand * d_model
    head_dim: int = 64
    chunk: int = 256              # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture.  Field values follow the assignment block
    verbatim; ``source`` cites the paper / model card."""

    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    source: str
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention details
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0    # gemma2 final-logit softcap (0 = off)
    attn_softcap: float = 0.0     # gemma2 attention-logit softcap
    sliding_window: int = 0       # window for ATTN_LOCAL layers
    local_global_alternate: bool = False   # gemma2 pattern

    # block composition
    moe: Optional[MoEConfig] = None
    moe_every: int = 0            # MoE MLP on layers where (i % moe_every)==moe_offset
    moe_offset: int = 1
    mamba: Optional[MambaConfig] = None
    attn_every: int = 0           # hybrid: attention on layers where (i % attn_every)==attn_offset
    attn_offset: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0          # e.g. 1500 mel frames after conv stub

    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"

    # norms / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"             # silu -> SwiGLU, gelu -> GeGLU-ish dense

    # ---------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 for clean model-axis sharding."""
        return _round_up(self.vocab_size, 128)

    def layer_kind(self, i: int) -> str:
        """Sequence-mixer kind for layer i."""
        if self.family == "ssm":
            return MAMBA
        if self.attn_every:  # hybrid (jamba): attention every `attn_every`
            return ATTN if (i % self.attn_every) == self.attn_offset else MAMBA
        if self.local_global_alternate:
            return ATTN_LOCAL if (i % 2) == 0 else ATTN
        return ATTN

    def mlp_kind(self, i: int) -> str:
        if self.moe is None:
            return MLP_DENSE
        if self.moe_every == 0:
            return MLP_MOE            # every layer MoE
        return MLP_MOE if (i % self.moe_every) == self.moe_offset else MLP_DENSE

    # layer-pattern period: the scan body covers `period` layers so that
    # heterogeneous stacks (jamba, gemma2, moe-alternating) still scan.
    @property
    def pattern_period(self) -> int:
        p = 1
        if self.attn_every:
            p = math.lcm(p, self.attn_every)
        if self.local_global_alternate:
            p = math.lcm(p, 2)
        if self.moe is not None and self.moe_every:
            p = math.lcm(p, self.moe_every)
        return p

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.padded_vocab * d                     # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d                 # lm head
        hd = self.resolved_head_dim
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind in (ATTN, ATTN_LOCAL):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                n += q + kv + o
                if self.qkv_bias:
                    n += (self.n_heads + 2 * self.n_kv_heads) * hd
            else:  # mamba
                mc = self.mamba or MambaConfig()
                di = mc.d_inner(d)
                nh = mc.n_heads(d)
                n += d * (2 * di + 2 * mc.d_state * 1 + nh)   # in_proj(z,x)+B,C,dt (grouped)
                n += di * mc.d_conv                            # conv
                n += di * d                                    # out proj
                n += 2 * nh                                    # A_log, D
            if self.mlp_kind(i) == MLP_MOE:
                m = self.moe
                n += m.num_experts * (3 * d * m.d_ff_expert)   # gate,up,down
                n += d * m.num_experts                         # router
            else:
                n += 3 * d * self.d_ff
            n += 2 * d                                         # 2 norms
        if self.is_encoder_decoder:
            # encoder blocks + cross attention in decoder
            for _ in range(self.n_encoder_layers):
                n += 4 * d * self.n_heads * hd + 3 * d * self.d_ff + 2 * d
            n += self.n_layers * (4 * d * self.n_heads * hd + d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        n = self.param_count()
        m = self.moe
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.mlp_kind(i) == MLP_MOE)
        full = n_moe_layers * m.num_experts * 3 * self.d_model * m.d_ff_expert
        active = n_moe_layers * m.top_k * 3 * self.d_model * m.d_ff_expert
        return n - full + active


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # "train" | "prefill" | "decode"


# The four assigned input shapes -------------------------------------------
TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def reduced(cfg: ArchConfig, n_layers: int = 2, d_model: int = 256,
            max_experts: int = 4, vocab: int = 512) -> ArchConfig:
    """Reduced variant of the same family for CPU smoke tests
    (2 layers, d_model<=512, <=4 experts)."""
    hd = 32
    n_heads = max(1, min(cfg.n_heads, d_model // hd)) if cfg.n_heads else 0
    kv = max(1, min(cfg.n_kv_heads, n_heads)) if n_heads else 0
    # keep the GQA ratio flavour
    if n_heads and cfg.n_kv_heads < cfg.n_heads:
        kv = max(1, n_heads // max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)))
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=max(64, d_model // 4))
    mamba = None
    if cfg.mamba is not None:
        mamba = dataclasses.replace(cfg.mamba, d_state=16, head_dim=32, chunk=32)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=hd if n_heads else 0,
        d_ff=max(64, d_model * 2),
        vocab_size=vocab,
        moe=moe,
        mamba=mamba,
        attn_every=min(cfg.attn_every, n_layers) if cfg.attn_every else 0,
        attn_offset=min(cfg.attn_offset, n_layers - 1) if cfg.attn_every else 0,
        moe_every=min(cfg.moe_every, 2) if cfg.moe_every else 0,
        moe_offset=min(cfg.moe_offset, 1),
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 64) if cfg.encoder_seq else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
    )
