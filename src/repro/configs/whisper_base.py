"""whisper-base — encoder-decoder audio model, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    source="arXiv:2212.04356 (assignment: 6L d_model=512 8H GQA kv=8 d_ff=2048 vocab=51865, enc-dec, conv frontend stub)",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    n_encoder_layers=6,
    encoder_seq=1500,              # 30 s of audio after the (stubbed) conv frontend
    frontend="audio",
    act="gelu",
)
