"""chameleon-34b — early-fusion VLM over VQ image tokens [arXiv:2405.09818]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818 (assignment: 48L d_model=8192 64H GQA kv=8 d_ff=22016 vocab=65536, early-fusion VQ image tokens)",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,              # text + VQ image codes in one vocab (early fusion)
    head_dim=128,
    frontend="vision",             # VQ tokenizer stubbed: input_specs gives token ids
)
