"""Architecture registry: maps ``--arch <id>`` to its ArchConfig.

Applicable-shape logic lives here too (which of the four assigned input
shapes each architecture runs — see DESIGN.md §Shape-applicability).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES
from repro.configs import (
    qwen2_5_3b, jamba_1_5_large_398b, yi_9b, qwen1_5_0_5b, qwen3_moe_30b_a3b,
    mamba2_1_3b, llama4_scout_17b_a16e, whisper_base, chameleon_34b, gemma2_27b,
)

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in (
        qwen2_5_3b.CONFIG,
        jamba_1_5_large_398b.CONFIG,
        yi_9b.CONFIG,
        qwen1_5_0_5b.CONFIG,
        qwen3_moe_30b_a3b.CONFIG,
        mamba2_1_3b.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
        whisper_base.CONFIG,
        chameleon_34b.CONFIG,
        gemma2_27b.CONFIG,
    )
}

# Archs whose attention is sub-quadratic (SSM / hybrid / sliding-window),
# eligible for the 524k-token decode shape.
SUBQUADRATIC = {"mamba2-1.3b", "jamba-1.5-large-398b", "gemma2-27b"}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """DESIGN.md §Shape-applicability."""
    if shape.name == "long_500k":
        return arch.name in SUBQUADRATIC
    return True


def applicable_pairs() -> List[tuple]:
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            if shape_applicable(a, s):
                out.append((a.name, s.name))
    return out
