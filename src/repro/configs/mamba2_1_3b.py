"""mamba2-1.3b — attention-free SSD (state-space duality) model [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, MambaConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060 (assignment: 48L d_model=2048 attn-free d_ff=0 vocab=50280, ssm_state=128)",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                        # attn-free, no MLP blocks (Mamba2 pure stack)
    vocab_size=50280,              # padded to 50304 for model-axis sharding
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
)
