"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE [arXiv:2403.19887]."""
from repro.configs.base import ArchConfig, MoEConfig, MambaConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887 (assignment: 72L d_model=8192 64H GQA kv=8 d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attn 7:1)",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    # 1 attention layer per 8-layer Jamba period (the paper places it mid-period)
    attn_every=8,
    attn_offset=4,
    # MoE on every other layer (Jamba's e=2 stride), 16 experts top-2
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    moe_every=2,
    moe_offset=1,
    mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=128, chunk=256),
)
