"""yi-9b — llama-architecture dense GQA decoder [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652 (assignment: 48L d_model=4096 32H GQA kv=4 d_ff=11008 vocab=64000)",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5_000_000.0,
)
