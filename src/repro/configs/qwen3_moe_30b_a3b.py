"""qwen3-moe-30b-a3b — 128-expert top-8 MoE decoder [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B (assignment: 48L d_model=2048 32H GQA kv=4 d_ff=768 vocab=151936, MoE 128e top-8)",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                     # per-expert ff dim (assignment value)
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    moe_every=0,                  # every layer is MoE
)
