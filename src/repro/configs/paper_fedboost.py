"""The paper's own experiment configuration: enhanced asynchronous AdaBoost
federated learning across the five application domains.

All hyperparameters referenced in the paper's Methodology section (α, β,
θ₁, θ₂, λ, I bounds) live here, with the values used for the reproduction
runs.  The paper does not publish its exact constants; these were chosen so
the *baseline* (synchronize every round, no compensation) and *enhanced*
configurations reproduce the relative improvement bands of Table 1 — see
EXPERIMENTS.md §Paper for the sensitivity sweep over these choices.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class SchedulerConfig:
    """Adaptive communication scheduling rule (paper eq. 1)."""
    alpha: float = 1.0          # interval increase step when error stable/improving
    beta: float = 2.0           # interval decrease step when error regresses
    # Δε < θ₁ (improving, or stable within +θ₁) → widen interval;
    # Δε > θ₂ (regressing) → shrink.  The paper calls θ₁, θ₂ "stability
    # thresholds": a plateau (Δε ≈ 0 < θ₁) must widen the interval, which is
    # exactly when synchronization stops paying for itself.
    theta1: float = 0.001
    theta2: float = 0.01
    i_min: int = 1
    i_max: int = 8
    i_init: int = 1


@dataclass(frozen=True)
class CompensationConfig:
    """Delayed weight compensation α̃ = α·s(τ) (paper eq. 2 generalized).

    ``decay`` selects s(τ) from the FedAsync staleness family
    (repro.core.compensation): ``exp`` is the paper's eq.-(2)
    exp(−λτ) and the default; ``constant``/``hinge``/``poly`` are the
    FedAsync alternatives (FLGo's defaults for a and b).  τ is clamped to
    [0, tau_cap] for every family."""
    lam: float = 0.15           # staleness decay constant λ (exp family)
    tau_cap: int = 32           # clamp pathological delays
    decay: str = "exp"          # exp | constant | hinge | poly
    hinge_a: float = 10.0       # hinge slope 1/(a·(τ−b)) beyond b
    hinge_b: float = 6.0        # hinge grace period in rounds
    poly_a: float = 0.5         # polynomial exponent (τ+1)^(−a)


@dataclass(frozen=True)
class FedBoostConfig:
    """One federated async-AdaBoost experiment."""
    n_clients: int = 16
    n_rounds: int = 80          # local boosting rounds per client
    target_error: float = 0.0   # 0 = run all rounds; else early stop metric
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    compensation: CompensationConfig = field(default_factory=CompensationConfig)
    weak_learner: str = "stump"  # stump | logistic | mlp
    balanced_init: bool = False  # class-balanced D_0 (imbalanced domains)
    # BEYOND-PAPER: client-side relevance filter — at sync, drop buffered
    # learners whose staleness-compensated local alpha falls below
    # `relevance_filter` x the buffer's best (0 = off, paper-faithful).
    # Realizes the paper's "fewer but more relevant updates" remark
    # (Mobile Personalization section) as an actual mechanism.
    relevance_filter: float = 0.0
    seed: int = 0
    # async client heterogeneity (simulator): per-client compute-time
    # multipliers drawn log-uniform in [1, straggler_factor]
    straggler_factor: float = 4.0
    dropout_prob: float = 0.05   # per-round client dropout probability
    # communication model: bytes per learner and per sync message header
    link_mbps: float = 10.0      # client uplink
    header_bytes: int = 256
    # scale knob: at sync, replay at most this many of the newest foreign
    # learners into the client's local distribution (None = exact/paper-
    # faithful replay of the whole window).  Fleet-scale scenarios cap this
    # so catch-up work per sync is O(cap), not O(ensemble); it applies to
    # both modes so the baseline/enhanced comparison stays apples-to-apples.
    catch_up_cap: Optional[int] = None


@dataclass(frozen=True)
class DomainConfig:
    """One of the paper's five application domains (synthetic environment)."""
    name: str
    n_samples: int
    n_features: int
    n_clients: int
    noniid_alpha: float          # Dirichlet concentration (lower = more skew)
    label_imbalance: float       # fraction of positive class
    noise: float
    straggler_factor: float
    dropout_prob: float
    link_mbps: float


def __getattr__(name: str):
    # DEPRECATED: the ad-hoc five-domain table moved into the scenario
    # registry (repro.sim.scenarios), which binds each domain to a
    # partitioner, behavior traces, and paper bands.  This shim keeps the
    # old import working for one release.
    if name == "DOMAINS":
        import warnings
        warnings.warn(
            "repro.configs.paper_fedboost.DOMAINS is deprecated; the "
            "domain table lives in the scenario registry — use "
            "repro.sim.scenarios.DOMAINS (or get_scenario(name).domain)",
            DeprecationWarning, stacklevel=2)
        from repro.sim.scenarios import DOMAINS
        return DOMAINS
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


DEFAULT = FedBoostConfig()
