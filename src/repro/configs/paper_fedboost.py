"""The paper's own experiment configuration: enhanced asynchronous AdaBoost
federated learning across the five application domains.

All hyperparameters referenced in the paper's Methodology section (α, β,
θ₁, θ₂, λ, I bounds) live here, with the values used for the reproduction
runs.  The paper does not publish its exact constants; these were chosen so
the *baseline* (synchronize every round, no compensation) and *enhanced*
configurations reproduce the relative improvement bands of Table 1 — see
EXPERIMENTS.md §Paper for the sensitivity sweep over these choices.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class SchedulerConfig:
    """Adaptive communication scheduling rule (paper eq. 1)."""
    alpha: float = 1.0          # interval increase step when error stable/improving
    beta: float = 2.0           # interval decrease step when error regresses
    # Δε < θ₁ (improving, or stable within +θ₁) → widen interval;
    # Δε > θ₂ (regressing) → shrink.  The paper calls θ₁, θ₂ "stability
    # thresholds": a plateau (Δε ≈ 0 < θ₁) must widen the interval, which is
    # exactly when synchronization stops paying for itself.
    theta1: float = 0.001
    theta2: float = 0.01
    i_min: int = 1
    i_max: int = 8
    i_init: int = 1


@dataclass(frozen=True)
class CompensationConfig:
    """Delayed weight compensation α̃ = α·exp(−λτ) (paper eq. 2)."""
    lam: float = 0.15           # staleness decay constant λ
    tau_cap: int = 32           # clamp pathological delays


@dataclass(frozen=True)
class FedBoostConfig:
    """One federated async-AdaBoost experiment."""
    n_clients: int = 16
    n_rounds: int = 80          # local boosting rounds per client
    target_error: float = 0.0   # 0 = run all rounds; else early stop metric
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    compensation: CompensationConfig = field(default_factory=CompensationConfig)
    weak_learner: str = "stump"  # stump | logistic | mlp
    balanced_init: bool = False  # class-balanced D_0 (imbalanced domains)
    # BEYOND-PAPER: client-side relevance filter — at sync, drop buffered
    # learners whose staleness-compensated local alpha falls below
    # `relevance_filter` x the buffer's best (0 = off, paper-faithful).
    # Realizes the paper's "fewer but more relevant updates" remark
    # (Mobile Personalization section) as an actual mechanism.
    relevance_filter: float = 0.0
    seed: int = 0
    # async client heterogeneity (simulator): per-client compute-time
    # multipliers drawn log-uniform in [1, straggler_factor]
    straggler_factor: float = 4.0
    dropout_prob: float = 0.05   # per-round client dropout probability
    # communication model: bytes per learner and per sync message header
    link_mbps: float = 10.0      # client uplink
    header_bytes: int = 256


@dataclass(frozen=True)
class DomainConfig:
    """One of the paper's five application domains (synthetic environment)."""
    name: str
    n_samples: int
    n_features: int
    n_clients: int
    noniid_alpha: float          # Dirichlet concentration (lower = more skew)
    label_imbalance: float       # fraction of positive class
    noise: float
    straggler_factor: float
    dropout_prob: float
    link_mbps: float


# Five domains, parameterized to reflect each scenario's published traits.
DOMAINS = {
    "edge_vision": DomainConfig(
        name="edge_vision", n_samples=4000, n_features=64, n_clients=12,
        noniid_alpha=0.5, label_imbalance=0.5, noise=0.15,
        straggler_factor=5.0, dropout_prob=0.10, link_mbps=8.0),
    "blockchain": DomainConfig(
        name="blockchain", n_samples=5000, n_features=32, n_clients=8,
        noniid_alpha=1.0, label_imbalance=0.45, noise=0.20,
        straggler_factor=2.0, dropout_prob=0.02, link_mbps=2.0),  # chain latency
    "mobile": DomainConfig(
        name="mobile", n_samples=6000, n_features=48, n_clients=32,
        noniid_alpha=0.2, label_imbalance=0.5, noise=0.18,
        straggler_factor=6.0, dropout_prob=0.15, link_mbps=5.0),
    "iot": DomainConfig(
        name="iot", n_samples=4000, n_features=24, n_clients=24,
        noniid_alpha=0.3, label_imbalance=0.15, noise=0.10,  # anomalies are rare
        straggler_factor=3.0, dropout_prob=0.12, link_mbps=1.0),
    "healthcare": DomainConfig(
        name="healthcare", n_samples=3000, n_features=40, n_clients=6,
        noniid_alpha=0.8, label_imbalance=0.20, noise=0.12,  # class imbalance
        straggler_factor=2.5, dropout_prob=0.03, link_mbps=20.0),
}

DEFAULT = FedBoostConfig()
