"""gemma2-27b — local+global alternating attention with logit softcap [arXiv:2408.00118]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118 (assignment: 46L d_model=4608 32H GQA kv=16 d_ff=36864 vocab=256000, local+global alternating, logit softcap)",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    local_global_alternate=True,
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)
