"""Pytree checkpointing: npz shards + json manifest, step-indexed, with
atomic writes and resume.  No external dependency (orbax unavailable
offline); good enough for CPU-scale runs and structurally identical to a
real multi-host checkpointer (per-leaf files keyed by tree path).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, tree: PyTree,
         extra: Optional[Dict] = None) -> str:
    """Atomically write checkpoint for `step`; returns its path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves, _ = _flatten_with_paths(tree)
        arrays, dtypes = {}, {}
        for k, v in leaves.items():
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype == jnp.bfloat16:
                a = a.astype(np.float32)      # npz has no bf16; manifest
            arrays[k] = a                     # records the true dtype
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": dtypes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, like: PyTree,
            step: Optional[int] = None) -> Tuple[PyTree, int, Dict]:
    """Restore into the structure of `like` (validates shapes/dtypes)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    want, treedef = _flatten_with_paths(like)
    leaves = {}
    for k, ref in want.items():
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = data[k]
        if list(arr.shape) != list(ref.shape):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {arr.shape} vs {ref.shape}")
        leaves[k] = jnp.asarray(arr, dtype=ref.dtype)
    ordered = [leaves[k] for k in want.keys()]
    tree = jax.tree_util.tree_unflatten(treedef, ordered)
    return tree, manifest["step"], manifest.get("extra", {})


def prune(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
