from repro.checkpoint.checkpoint import save, restore, latest_step, prune  # noqa: F401
