from repro.models.model_api import Model, input_specs, concrete_inputs  # noqa: F401
