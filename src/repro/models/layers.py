"""Shared neural building blocks: norms, RoPE, MLPs, embeddings.

All layers are pure functions over explicit parameter pytrees (dicts of
jnp arrays) so they compose with jit/scan/shard_map without a framework
dependency.  Initializers take an explicit PRNG key.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rms_norm_init(d: int, dtype=jnp.float32) -> jnp.ndarray:
    # stored as (scale - 1) so zeros-init == identity, gemma-style
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------------------
# softcap (gemma2)
# ---------------------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, head_dim); positions: (..., T) or (T,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    gate = x @ p["w_gate"]
    gate = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return (gate * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# embeddings / LM head
# ---------------------------------------------------------------------------

def embed_apply(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def lm_head_apply(table_or_w: jnp.ndarray, x: jnp.ndarray, tied: bool,
                  logit_cap: float = 0.0) -> jnp.ndarray:
    if tied:
        logits = x @ table_or_w.T
    else:
        logits = x @ table_or_w
    return softcap(logits.astype(jnp.float32), logit_cap)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  vocab_true: int) -> jnp.ndarray:
    """Mean token cross-entropy; positions >= vocab_true (padding vocab) masked."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad > vocab_true:
        neg = jnp.full((vpad - vocab_true,), -1e30, jnp.float32)
        logits = logits.at[..., vocab_true:].add(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
