"""Public model API: one entry point per architecture family.

``Model`` wraps init / loss / prefill / decode behind a uniform interface
so the launcher, the dry-run, and the federated trainer don't branch on
architecture family.  ``input_specs`` produces ShapeDtypeStruct stand-ins
for every model input (weak-type-correct, shardable, no device allocation)
— the dry-run lowers against these.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, transformer

Params = Dict[str, Any]


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------- params
    def init(self, key, dtype=jnp.float32) -> Params:
        if self.cfg.is_encoder_decoder:
            return encdec.init_params(key, self.cfg, dtype)
        return transformer.init_params(key, self.cfg, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        if self.cfg.is_encoder_decoder:
            return encdec.abstract_params(self.cfg, dtype)
        return transformer.abstract_params(self.cfg, dtype)

    # --------------------------------------------------------------- loss
    def loss(self, params: Params, batch: Dict[str, jnp.ndarray], *,
             remat: bool = True):
        if self.cfg.is_encoder_decoder:
            return encdec.loss_fn(params, batch, self.cfg, remat=remat)
        return transformer.loss_fn(params, batch, self.cfg, remat=remat)

    # ------------------------------------------------------------ serving
    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                cache_seq: Optional[int] = None):
        if self.cfg.is_encoder_decoder:
            return encdec.prefill(params, batch["frames"], batch["tokens"],
                                  self.cfg, cache_seq)
        return transformer.prefill(params, batch["tokens"], self.cfg,
                                   cache_seq)

    def decode_step(self, params: Params, tokens: jnp.ndarray, caches,
                    pos: jnp.ndarray):
        if self.cfg.is_encoder_decoder:
            return encdec.decode_step(params, tokens, caches, pos, self.cfg)
        return transformer.decode_step(params, tokens, caches, pos, self.cfg)

    def init_caches(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        if self.cfg.is_encoder_decoder:
            return encdec.init_caches(self.cfg, batch, seq_len, dtype)
        return transformer.init_caches(self.cfg, batch, seq_len, dtype)

    def abstract_caches(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        if self.cfg.is_encoder_decoder:
            return encdec.abstract_caches(self.cfg, batch, seq_len, dtype)
        return transformer.abstract_caches(self.cfg, batch, seq_len, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this
    (arch x input-shape) combination.

    train   -> {tokens, labels} (+ frames for audio)
    prefill -> {tokens} (+ frames)
    decode  -> {tokens (B,1), pos scalar} (+ frames); caches are built via
               Model.abstract_caches and passed alongside.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    tok = lambda *s: jax.ShapeDtypeStruct(s, i32)
    specs: Dict[str, Any] = {}
    if shape.mode == "train":
        specs["tokens"] = tok(B, S)
        specs["labels"] = tok(B, S)
    elif shape.mode == "prefill":
        specs["tokens"] = tok(B, S)
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = tok(B, 1)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
    if cfg.frontend == "audio":
        # stubbed conv frontend: precomputed frame embeddings
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    # vision early-fusion archs (chameleon, llama4) consume VQ/patch tokens
    # through the same token stream — the tokenizer stub needs no extra input
    return specs


def concrete_inputs(cfg: ArchConfig, shape: ShapeConfig, key,
                    batch_override: Optional[int] = None,
                    seq_override: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Small concrete batches for smoke tests (reduced shapes)."""
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    out: Dict[str, jnp.ndarray] = {}
    if shape.mode == "train":
        out["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size, jnp.int32)
        out["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab_size, jnp.int32)
    elif shape.mode == "prefill":
        out["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size, jnp.int32)
    else:
        out["tokens"] = jax.random.randint(k1, (B, 1), 0, cfg.vocab_size, jnp.int32)
        out["pos"] = jnp.array(S // 2, jnp.int32)
    if cfg.frontend == "audio":
        enc_s = cfg.encoder_seq
        out["frames"] = jax.random.normal(k3, (B, enc_s, cfg.d_model),
                                          jnp.float32).astype(jnp.bfloat16)
    return out
