"""Unified decoder stack: builds any assigned architecture from ArchConfig.

Design notes
------------
* Layers are stacked per *pattern slot*: a config with pattern period p
  (jamba: 8, gemma2: 2, dense: 1) stores its parameters as a tuple of p
  slot-pytrees whose leaves carry a leading ``n_periods`` axis.  The forward
  pass is one ``lax.scan`` over periods whose body applies the p
  (heterogeneous, python-level) slots in order — so a 72-layer hybrid
  compiles to the same small HLO as a 2-layer one, which keeps the
  40-combination dry-run tractable.
* Three entry points per model: ``forward`` (train: full logits),
  ``prefill`` (returns last-token logits + populated caches) and
  ``decode_step`` (one token against the caches).  Caches are per-slot
  pytrees with the same leading ``n_periods`` axis, scanned alongside.
* MoE layers contribute a load-balance aux loss, accumulated in the scan
  carry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ArchConfig, ATTN, ATTN_LOCAL, MAMBA, MLP_DENSE, MLP_MOE)
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    cross_entropy, dense_init, embed_apply, embed_init, lm_head_apply,
    mlp_apply, mlp_init, rms_norm, rms_norm_init, softcap)
from repro.sharding_ctx import constrain

Params = Dict[str, Any]


def _slot_kinds(cfg: ArchConfig):
    """(mixer_kind, mlp_kind) for each of the p slots in a period."""
    p = cfg.pattern_period
    return [(cfg.layer_kind(i), cfg.mlp_kind(i)) for i in range(p)]


def n_periods(cfg: ArchConfig) -> int:
    p = cfg.pattern_period
    if cfg.n_layers % p != 0:
        raise ValueError(f"{cfg.name}: n_layers={cfg.n_layers} not divisible "
                         f"by pattern period {p}")
    return cfg.n_layers // p


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig, mixer: str, mlp: str, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"norm1": rms_norm_init(cfg.d_model, dtype)}
    if mixer in (ATTN, ATTN_LOCAL):
        p["attn"] = attn_mod.attn_init(k1, cfg, dtype)
    else:
        p["mamba"] = mamba_mod.mamba_init(k1, cfg, dtype)
    if cfg.d_ff > 0 or mlp == MLP_MOE:
        p["norm2"] = rms_norm_init(cfg.d_model, dtype)
        if mlp == MLP_MOE:
            p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    np_ = n_periods(cfg)
    slots = _slot_kinds(cfg)
    keys = jax.random.split(key, 3 + len(slots))
    params: Params = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.padded_vocab,
                                       dtype)

    blocks = []
    for s, (mixer, mlp) in enumerate(slots):
        layer_keys = jax.random.split(keys[3 + s], np_)
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_layer_init(layer_keys[i], cfg, mixer, mlp, dtype)
              for i in range(np_)])
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    return params


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.key(0))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _block_apply(lp: Params, x, cfg: ArchConfig, mixer: str, mlp: str, *,
                 positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if mixer in (ATTN, ATTN_LOCAL):
        window = cfg.sliding_window if mixer == ATTN_LOCAL else 0
        h = attn_mod.attn_apply(lp["attn"], h, cfg, positions=positions,
                                window=window)
    else:
        h = mamba_mod.mamba_apply(lp["mamba"], h, cfg)
    x = x + h
    if "norm2" in lp:
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if mlp == MLP_MOE:
            h, a = moe_mod.moe_apply(lp["moe"], h, cfg, cfg.act)
            aux = aux + a
        else:
            h = mlp_apply(lp["mlp"], h, cfg.act)
        x = x + h
    return x, aux


def forward(params: Params, tokens: jnp.ndarray, cfg: ArchConfig, *,
            remat: bool = True, unroll: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward.  tokens: (B,T) -> (logits (B,T,Vpad) f32, aux)."""
    B, T = tokens.shape
    slots = _slot_kinds(cfg)
    x = constrain(embed_apply(params["embed"], tokens), "btd")
    positions = jnp.arange(T, dtype=jnp.int32)

    def period_body(carry, slot_params):
        x, aux = carry
        for s, (mixer, mlp) in enumerate(slots):
            x, a = _block_apply(slot_params[s], x, cfg, mixer, mlp,
                                positions=positions)
            aux = aux + a
        return (constrain(x, "btd"), aux), None

    body = jax.checkpoint(period_body) if remat else period_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"],
                               unroll=n_periods(cfg) if unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(
        lm_head_apply(head, x, cfg.tie_embeddings, cfg.logit_softcap), "btv")
    return logits, aux


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            *, remat: bool = True, unroll: bool = False
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, batch["tokens"], cfg, remat=remat,
                          unroll=unroll)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# caches (decode)
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, seq_len: int,
                dtype=jnp.bfloat16) -> Tuple:
    """Per-slot cache pytrees, leaves stacked over n_periods."""
    np_ = n_periods(cfg)
    slots = _slot_kinds(cfg)
    caches = []
    for mixer, _ in slots:
        if mixer in (ATTN, ATTN_LOCAL):
            c = attn_mod.init_cache(cfg, mixer, batch, seq_len, dtype)
        else:
            c = mamba_mod.init_mamba_state(cfg, batch, dtype)
        caches.append(jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (np_,) + l.shape), c))
    return tuple(caches)


def abstract_caches(cfg: ArchConfig, batch: int, seq_len: int,
                    dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_caches(cfg, batch, seq_len, dtype))


def decode_step(params: Params, tokens: jnp.ndarray, caches: Tuple,
                pos: jnp.ndarray, cfg: ArchConfig):
    """One decode step.  tokens: (B,1); caches from init_caches/prefill;
    pos: scalar int32 count of tokens already generated.
    Returns (logits (B,Vpad) f32, new_caches)."""
    slots = _slot_kinds(cfg)
    x = constrain(embed_apply(params["embed"], tokens), "btd")

    def period_body(x, xs):
        slot_params, slot_caches = xs
        new_caches = []
        for s, (mixer, mlp) in enumerate(slots):
            lp, c = slot_params[s], slot_caches[s]
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            if mixer in (ATTN, ATTN_LOCAL):
                h, c1 = attn_mod.attn_decode(lp["attn"], h, c, cfg, pos=pos,
                                             kind=mixer)
            else:
                h, c1 = mamba_mod.mamba_decode(lp["mamba"], h, c, cfg)
            x = x + h
            if "norm2" in lp:
                h = rms_norm(x, lp["norm2"], cfg.norm_eps)
                if mlp == MLP_MOE:
                    h, _ = moe_mod.moe_apply(lp["moe"], h, cfg, cfg.act)
                else:
                    h = mlp_apply(lp["mlp"], h, cfg.act)
                x = x + h
            new_caches.append(c1)
        return constrain(x, "btd"), tuple(new_caches)

    x, new_caches = jax.lax.scan(period_body, x,
                                 (params["blocks"], caches))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(lm_head_apply(head, x[:, 0], cfg.tie_embeddings,
                                     cfg.logit_softcap), "bv")
    return logits, new_caches


def prefill(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
            cache_seq: Optional[int] = None):
    """Prefill: consume (B,T) prompt, return (last logits (B,Vpad), caches
    sized for cache_seq (default T) further decode)."""
    B, T = tokens.shape
    S = cache_seq or T
    slots = _slot_kinds(cfg)
    x = constrain(embed_apply(params["embed"], tokens), "btd")
    positions = jnp.arange(T, dtype=jnp.int32)

    def period_body(x, slot_params):
        new_caches = []
        for s, (mixer, mlp) in enumerate(slots):
            lp = slot_params[s]
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            if mixer in (ATTN, ATTN_LOCAL):
                h, c1 = attn_mod.attn_prefill(lp["attn"], h, cfg,
                                              positions=positions, kind=mixer,
                                              cache_seq=S)
            else:
                h, st = mamba_mod.mamba_forward(lp["mamba"], h, cfg)
                c1 = st
            x = x + h
            if "norm2" in lp:
                h = rms_norm(x, lp["norm2"], cfg.norm_eps)
                if mlp == MLP_MOE:
                    h, _ = moe_mod.moe_apply(lp["moe"], h, cfg, cfg.act)
                else:
                    h = mlp_apply(lp["mlp"], h, cfg.act)
                x = x + h
            new_caches.append(c1)
        return constrain(x, "btd"), tuple(new_caches)

    x, caches = jax.lax.scan(period_body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(lm_head_apply(head, x[:, -1], cfg.tie_embeddings,
                                     cfg.logit_softcap), "bv")
    return logits, caches
