"""Token-choice top-k Mixture-of-Experts with capacity-bounded scatter
dispatch, routed independently per batch row.

Two deliberate properties:

* Dispatch is gather/scatter based (no (tokens x experts x capacity) one-hot
  matmul), so HLO FLOPs reflect only the *active* expert compute — essential
  for an honest roofline (a one-hot-dispatch einsum would inflate HLO_FLOPs
  by ~num_experts/top_k and drown the MODEL_FLOPS/HLO_FLOPs ratio).

* Routing/dispatch is vmapped over the batch axis, so under pjit with batch
  sharded over `data` every shard dispatches its own rows locally — no
  cross-shard cumsum/scatter semantics.

Sharding modes (cfg.moe.sharding):
  * "tensor": experts on every data shard, per-expert d_ff split over the
    `model` axis.  No dispatch collectives; the down-proj all-reduce is the
    standard Megatron pattern.  Robust default.
  * "expert": expert dim split over `model` (expert parallelism); XLA SPMD
    materializes the token exchange as collectives.  Compared against
    "tensor" in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import dense_init
from repro.sharding_ctx import constrain, current as ctx_current, current_mesh

Params = Dict[str, jnp.ndarray]


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def init(k, di, do):
        keys = jax.random.split(k, e)
        return jnp.stack([dense_init(kk, di, do, dtype) for kk in keys])

    return {
        "router": dense_init(k1, d, e, jnp.float32),
        "w_gate": init(k2, d, f),       # (E, D, F)
        "w_up": init(k3, d, f),
        "w_down": init(k4, f, d),       # (E, F, D)
    }


def capacity(m: MoEConfig, tokens_per_row: int) -> int:
    cap = int(m.capacity_factor * tokens_per_row * m.top_k / m.num_experts)
    return max(m.top_k, min(tokens_per_row, max(1, cap)))


def _topk_iterative(probs: jnp.ndarray, k: int):
    """Top-k via k argmax passes.  ``lax.top_k`` lowers to a sort, which the
    SPMD partitioner refuses to batch-partition (it all-gathers the operand
    — the global-batch gathers seen in the qwen3-moe HLO).  argmax is a
    plain partitionable reduce, and k is small (<=8) for every assigned MoE."""
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        v = jnp.take_along_axis(p, i[..., None], axis=-1)[..., 0]
        vals.append(v)
        idxs.append(i)
        p = p - jax.nn.one_hot(i, probs.shape[-1], dtype=p.dtype) * 1e9
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1).astype(jnp.int32)


def _dispatch_row(x_row: jnp.ndarray, expert_idx: jnp.ndarray, m: MoEConfig,
                  cap: int):
    """Scatter one row of T tokens into its (E, C, D) expert buffer, given
    the (already batched) top-k expert choices."""
    T, D = x_row.shape
    E, K = m.num_experts, m.top_k

    flat_e = expert_idx.reshape(-1)                           # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]

    keep = pos_in_e < cap
    dst = jnp.where(keep, flat_e * cap + pos_in_e, E * cap)   # E*cap = drop
    tok = jnp.repeat(jnp.arange(T), K)

    buf = jnp.zeros((E * cap, D), x_row.dtype)
    xe = buf.at[dst].set(x_row[tok], mode="drop").reshape(E, cap, D)
    return keep, dst, tok, xe


def _expert_compute(p: Params, xe: jnp.ndarray, m: MoEConfig, act: str):
    """(B,E,C,D) -> (B,E,C,D) through the per-expert SwiGLU.

    "tensor_sm" mode (§Perf hillclimb): the Megatron down-proj partial sum
    is made an EXPLICIT bf16 ``psum`` inside ``shard_map`` — under plain jit
    the partitioner places the all-reduce on the dot output, which the CPU
    backend has promoted to f32 (2x the wire bytes of the logical dtype).
    FSDP weight gathers are likewise explicit (bf16 all_gather over the
    fsdp axis, transposed to a reduce-scatter for the weight grads).
    """

    def dense_path(xe):
        g = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
        return constrain(
            jnp.einsum("becf,efd->becd", g * u, p["w_down"]), "b4")

    ctx = ctx_current()
    mesh = current_mesh()
    if m.sharding != "tensor_sm" or mesh is None or ctx is None:
        return dense_path(xe)

    from jax.sharding import PartitionSpec as P
    model_ax = ctx["model"]
    fsdp_ax = ctx.get("fsdp")
    batch_ax = ctx.get("batch")

    def body(xe_l, wg_l, wu_l, wd_l):
        if fsdp_ax is not None:
            wg_l = jax.lax.all_gather(wg_l, fsdp_ax, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, fsdp_ax, axis=1, tiled=True)
            wd_l = jax.lax.all_gather(wd_l, fsdp_ax, axis=2, tiled=True)
        g = jnp.einsum("becd,edf->becf", xe_l, wg_l)
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        u = jnp.einsum("becd,edf->becf", xe_l, wu_l)
        ye_part = jnp.einsum("becf,efd->becd", g * u, wd_l)
        # cast the partial to bf16 BEFORE the psum and pin it there: without
        # the barrier XLA's algebraic simplifier hoists the convert across
        # the all-reduce (f32 accumulation), doubling the wire bytes
        ye_part = jax.lax.optimization_barrier(ye_part.astype(xe_l.dtype))
        return jax.lax.psum(ye_part, model_ax)

    w_spec_gu = P(None, fsdp_ax, model_ax)
    w_spec_d = P(None, model_ax, fsdp_ax)
    xe_spec = P(batch_ax, None, None, None)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(xe_spec, w_spec_gu, w_spec_gu, w_spec_d),
        out_specs=xe_spec, check_vma=False,
    )(xe, p["w_gate"], p["w_up"], p["w_down"])


def moe_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig,
              act: str = "silu") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,T,D) -> (out, aux_loss).  Capacity-overflow tokens fall back to
    the residual path (standard Switch drop behaviour)."""
    m = cfg.moe
    B, T, D = x.shape
    E, K = m.num_experts, m.top_k
    C = capacity(m, T)

    # routing is BATCHED (not inside the per-row vmap): a vmapped top_k was
    # observed to make the partitioner gather the global batch per device
    # ((256,4096,128) f32 all-gathers, ~51 GB/step in qwen3-moe train)
    probs = constrain(
        jax.nn.softmax(x.astype(jnp.float32) @ p["router"], axis=-1), "b3")
    gate_vals, expert_idx = _topk_iterative(probs, K)         # (B, T, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    gate_vals = constrain(gate_vals, "b3")
    expert_idx = constrain(expert_idx, "b3")

    def route(x_row, idx_row):
        return _dispatch_row(x_row, idx_row, m, C)

    keep, dst, tok, xe = jax.vmap(route)(x, expert_idx)
    # anchor the dispatch intermediates: without these the SPMD partitioner
    # replicates the per-row scatter subgraph across the batch
    keep = constrain(keep, "b2")
    dst = constrain(dst, "b2")
    xe = constrain(xe, "b4")   # (B, E, C, D)

    # load-balance auxiliary loss (Switch eq. 4), global over batch
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx.reshape(-1, K), E,
                               dtype=jnp.float32), axis=1), axis=0)
    aux = m.aux_loss_coef * E * jnp.sum(me * ce)

    # expert compute, batched over rows: (B,E,C,D) @ (E,D,F)
    ye = _expert_compute(p, xe, m, act)                          # (B,E,C,D)

    def combine(ye_row, keep_row, dst_row, tok_row, gates_row):
        yf = ye_row.reshape(E * C, D)
        picked = yf[jnp.minimum(dst_row, E * C - 1)]
        picked = jnp.where(keep_row[:, None], picked, 0.0)
        contrib = picked * gates_row.reshape(-1)[:, None].astype(ye_row.dtype)
        return jnp.zeros((T, D), ye_row.dtype).at[tok_row].add(contrib)

    out = constrain(jax.vmap(combine)(ye, keep, dst, tok, gate_vals), "btd")
    return out.reshape(B, T, D), aux
