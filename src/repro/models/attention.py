"""GQA attention with QKV bias, RoPE, sliding-window masks, logit softcap,
chunked (memory-bounded) softmax, and KV-cache decode.

The training/prefill path uses a q-chunked lazy-flash formulation — logits
are materialized only per (block_q x T) tile — so 32k-sequence prefill
lowers with bounded intermediates even without the Pallas kernel.  The
Pallas `flash_attention` kernel (repro.kernels) is a drop-in replacement
selected via ``impl="pallas"`` for the optimized path.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init, softcap

Params = Dict[str, jnp.ndarray]

NEG_INF = -1e30


def attn_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, hq * hd, dtype),
        "wk": dense_init(k2, d, hkv * hd, dtype),
        "wv": dense_init(k3, d, hkv * hd, dtype),
        "wo": dense_init(k4, hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                 positions: jnp.ndarray, rope: bool = True):
    B, T, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, hq, hd)
    k = k.reshape(B, T, hkv, hd)
    v = v.reshape(B, T, hkv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: int) -> jnp.ndarray:
    """(Tq, Tk) additive mask from absolute positions."""
    dif = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(dif.shape, bool)
    if causal:
        ok &= dif >= 0
    if window > 0:
        ok &= dif < window
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                  attn_cap: float, block_q: int = 512) -> jnp.ndarray:
    """q:(B,Tq,Hq,hd) k,v:(B,Tk,Hkv,hd) -> (B,Tq,Hq,hd).

    Scans over q blocks; each block materializes (B,Hq,block_q,Tk) logits
    only.  GQA is handled by reshaping q heads into (Hkv, group) so the
    einsum broadcasts without repeating K/V.
    """
    B, Tq, Hq, hd = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    nblk = max(1, Tq // block_q)
    bq = Tq // nblk if Tq % nblk == 0 else Tq  # fall back to single block
    if Tq % bq != 0:
        bq, nblk = Tq, 1

    qg = q.reshape(B, Tq, Hkv, G, hd)
    qs = qg.reshape(B, nblk, bq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(nblk, bq)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_block(carry, xs):
        qb, qpb = xs                                 # (B,bq,Hkv,G,hd), (bq,)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32), kf)
        logits = logits * scale
        logits = softcap(logits, attn_cap)
        bias = _mask_bias(qpb, k_pos, causal, window)        # (bq,Tk)
        logits = logits + bias[None, None, None, :, :]
        w = jax.nn.softmax(logits, axis=-1)
        ob = jnp.einsum("bhgqk,bkhd->bqhgd", w, vf)
        return carry, ob.astype(q.dtype)

    _, outs = jax.lax.scan(one_block, None, (qs, qp))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tq, Hq, hd)
    return out


def attn_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
               positions: jnp.ndarray, window: int = 0,
               block_q: int = 512) -> jnp.ndarray:
    """Full-sequence (train/prefill) attention; returns (B,T,D)."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = _sdpa_chunked(q, k, v, positions, positions, causal=True,
                        window=window, attn_cap=cfg.attn_softcap,
                        block_q=block_q)
    return out.reshape(B, T, cfg.n_heads * cfg.resolved_head_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def cache_len(cfg: ArchConfig, kind: str, seq_len: int) -> int:
    """Local (sliding-window) layers keep a window-capped ring cache."""
    if kind == "attn_local" and cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ArchConfig, kind: str, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    S = cache_len(cfg, kind, seq_len)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, S, hkv, hd), dtype),
        "v": jnp.zeros((batch, S, hkv, hd), dtype),
    }


def attn_prefill(p: Params, x: jnp.ndarray, cfg: ArchConfig, *,
                 positions: jnp.ndarray, kind: str, cache_seq: int,
                 block_q: int = 512):
    """Prefill: full attention + return a populated cache of cache_seq slots."""
    B, T, _ = x.shape
    window = cfg.sliding_window if kind == "attn_local" else 0
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = _sdpa_chunked(q, k, v, positions, positions, causal=True,
                        window=window, attn_cap=cfg.attn_softcap,
                        block_q=block_q)
    S = cache_len(cfg, kind, cache_seq)
    if T >= S:
        # keep the last S entries, laid out in ring order (slot = abs_pos % S)
        # so attn_decode's ring-slot bookkeeping continues seamlessly
        ck = jnp.roll(k[:, T - S:], shift=T % S, axis=1)
        cv = jnp.roll(v[:, T - S:], shift=T % S, axis=1)
    else:
        pad = S - T
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ck.astype(jnp.bfloat16), "v": cv.astype(jnp.bfloat16)}
    o = out.reshape(B, T, cfg.n_heads * cfg.resolved_head_dim) @ p["wo"]
    return o, cache


def attn_decode(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                cfg: ArchConfig, *, pos: jnp.ndarray, kind: str):
    """One-token decode. x: (B,1,D); cache k/v: (B,S,Hkv,hd); pos: scalar
    int32 (number of tokens already in cache).  Returns (out, new_cache).

    For sliding-window layers the cache is a ring buffer of window slots
    (slot = pos % S); masking selects the valid window entries.
    """
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    S = cache["k"].shape[1]
    window = cfg.sliding_window if kind == "attn_local" else 0

    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)

    slot = (pos % S).astype(jnp.int32)   # == pos for full-length caches
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))

    # absolute position of every cache slot (ring-aware)
    idx = jnp.arange(S, dtype=jnp.int32)
    # slots <= current slot hold positions (pos - slot + idx); slots beyond
    # hold the previous wrap (pos - slot + idx - S)
    abs_pos = pos - slot + idx + jnp.where(idx > slot, -S, 0)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window > 0:
        valid &= (pos - abs_pos) < window

    G = hq // hkv
    qg = q.reshape(B, hkv, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, ck.astype(jnp.float32))
    logits = logits / math.sqrt(hd)
    logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, cv.astype(jnp.float32))
    o = o.reshape(B, 1, hq * hd).astype(x.dtype) @ p["wo"]
    return o, {"k": ck, "v": cv}
