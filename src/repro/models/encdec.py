"""Encoder-decoder stack (whisper-base).

The audio frontend (mel spectrogram + conv feature extractor) is a STUB per
the assignment carve-out: the encoder consumes precomputed frame embeddings
of shape (B, encoder_seq, d_model) — ``input_specs()`` provides them.  The
transformer encoder (bidirectional self-attention) and the decoder
(causal self-attention + cross-attention + KV caches for both) are real.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models.layers import (
    cross_entropy, dense_init, embed_apply, embed_init, lm_head_apply,
    mlp_apply, mlp_init, rms_norm, rms_norm_init)
from repro.sharding_ctx import constrain

Params = Dict[str, Any]


def _xattn_init(key, cfg: ArchConfig, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, h * hd, dtype),
        "wk": dense_init(k2, d, h * hd, dtype),
        "wv": dense_init(k3, d, h * hd, dtype),
        "wo": dense_init(k4, h * hd, d, dtype),
    }


def _enc_layer_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": rms_norm_init(cfg.d_model, dtype),
        "attn": attn_mod.attn_init(k1, cfg, dtype),
        "norm2": rms_norm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg: ArchConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": rms_norm_init(cfg.d_model, dtype),
        "attn": attn_mod.attn_init(k1, cfg, dtype),
        "norm_x": rms_norm_init(cfg.d_model, dtype),
        "xattn": _xattn_init(k2, cfg, dtype),
        "norm2": rms_norm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    stack = lambda mk, keys: jax.tree.map(
        lambda *xs: jnp.stack(xs), *[mk(k, cfg, dtype) for k in keys])
    return {
        "embed": embed_init(ks[2], cfg.padded_vocab, cfg.d_model, dtype),
        "enc_pos": embed_init(ks[3], cfg.encoder_seq, cfg.d_model, dtype),
        "enc_layers": stack(_enc_layer_init, enc_keys),
        "enc_norm": rms_norm_init(cfg.d_model, dtype),
        "dec_layers": stack(_dec_layer_init, dec_keys),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.padded_vocab, dtype),
    }


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.key(0))


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def _bidir_attn(p, x, cfg: ArchConfig, positions):
    """Non-causal self attention (encoder)."""
    B, T, _ = x.shape
    q, k, v = attn_mod._project_qkv(p, x, cfg, positions, rope=False)
    out = attn_mod._sdpa_chunked(q, k, v, positions, positions, causal=False,
                                 window=0, attn_cap=0.0)
    return out.reshape(B, T, cfg.n_heads * cfg.resolved_head_dim) @ p["wo"]


def encode(params: Params, frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """frames: (B, S_enc, D) stubbed conv-frontend output."""
    B, S, D = frames.shape
    x = frames + params["enc_pos"][None, :S]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + _bidir_attn(lp["attn"], h, cfg, positions)
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg.act)
        return constrain(x, "btd"), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _cross_attn(p, x, enc_kv, cfg: ArchConfig):
    """x: (B,T,D); enc_kv: precomputed (k,v) each (B,S_enc,H,hd)."""
    B, T, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, T, h, hd)
    k, v = enc_kv
    tq = jnp.arange(T, dtype=jnp.int32)
    tk = jnp.arange(k.shape[1], dtype=jnp.int32)
    out = attn_mod._sdpa_chunked(q, k, v, tq, tk, causal=False, window=0,
                                 attn_cap=0.0)
    return out.reshape(B, T, h * hd) @ p["wo"]


def enc_kv(params_layer, enc_out: jnp.ndarray, cfg: ArchConfig):
    B, S, _ = enc_out.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    k = (enc_out @ params_layer["wk"]).reshape(B, S, h, hd)
    v = (enc_out @ params_layer["wv"]).reshape(B, S, h, hd)
    return k, v


def _dec_block(lp, x, enc_out, cfg: ArchConfig, positions):
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    x = x + attn_mod.attn_apply(lp["attn"], h, cfg, positions=positions)
    h = rms_norm(x, lp["norm_x"], cfg.norm_eps)
    x = x + _cross_attn(lp["xattn"], h, enc_kv(lp["xattn"], enc_out, cfg), cfg)
    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    x = x + mlp_apply(lp["mlp"], h, cfg.act)
    return x


def forward(params: Params, frames: jnp.ndarray, tokens: jnp.ndarray,
            cfg: ArchConfig, *, remat: bool = True):
    """Training forward: (frames (B,S_enc,D), tokens (B,T)) -> logits."""
    enc_out = encode(params, frames, cfg)
    B, T = tokens.shape
    x = embed_apply(params["embed"], tokens)
    positions = jnp.arange(T, dtype=jnp.int32)

    def body(x, lp):
        return constrain(_dec_block(lp, x, enc_out, cfg, positions),
                         "btd"), None

    b = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(b, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain(lm_head_apply(params["lm_head"], x, False, 0.0),
                       "btv")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params: Params, batch, cfg: ArchConfig, *, remat: bool = True):
    logits, aux = forward(params, batch["frames"], batch["tokens"], cfg,
                          remat=remat)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Self-attn KV cache per decoder layer + precomputed cross K/V."""
    L = cfg.n_layers
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    self_c = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (L,) + l.shape),
        attn_mod.init_cache(cfg, "attn", batch, seq_len, dtype))
    cross = {
        "k": jnp.zeros((L, batch, cfg.encoder_seq, h, hd), dtype),
        "v": jnp.zeros((L, batch, cfg.encoder_seq, h, hd), dtype),
    }
    return {"self": self_c, "cross": cross}


def abstract_caches(cfg: ArchConfig, batch: int, seq_len: int,
                    dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_caches(cfg, batch, seq_len, dtype))


def prefill(params: Params, frames: jnp.ndarray, tokens: jnp.ndarray,
            cfg: ArchConfig, cache_seq: Optional[int] = None):
    """Encode + consume prompt tokens; build decode caches."""
    enc_out = encode(params, frames, cfg)
    B, T = tokens.shape
    S = cache_seq or T
    x = embed_apply(params["embed"], tokens)
    positions = jnp.arange(T, dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        a, self_c = attn_mod.attn_prefill(lp["attn"], h, cfg,
                                          positions=positions, kind="attn",
                                          cache_seq=S)
        x = x + a
        ck, cv = enc_kv(lp["xattn"], enc_out, cfg)
        h = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        x = x + _cross_attn(lp["xattn"], h, (ck, cv), cfg)
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg.act)
        cross_c = {"k": ck.astype(jnp.bfloat16), "v": cv.astype(jnp.bfloat16)}
        return constrain(x, "btd"), {"self": self_c, "cross": cross_c}

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head_apply(params["lm_head"], x[:, -1], False, 0.0)
    return logits, caches


def decode_step(params: Params, tokens: jnp.ndarray, caches, pos, cfg):
    """tokens: (B,1). caches: {"self": ..., "cross": ...} stacked over layers."""
    x = embed_apply(params["embed"], tokens)

    def body(x, xs):
        lp, self_c, ck, cv = xs
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        a, c1 = attn_mod.attn_decode(lp["attn"], h, self_c, cfg, pos=pos,
                                     kind="attn")
        x = x + a
        h = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        x = x + _cross_attn(lp["xattn"], h, (ck, cv), cfg)
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg.act)
        return constrain(x, "btd"), c1

    x, self_new = jax.lax.scan(
        body, x, (params["dec_layers"], caches["self"],
                  caches["cross"]["k"], caches["cross"]["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head_apply(params["lm_head"], x[:, 0], False, 0.0)
    return logits, {"self": self_new, "cross": caches["cross"]}
