"""Weak learners for (federated) AdaBoost.

Three families, all pure-JAX and all trained against a *weighted* sample
distribution D_t(i) as the paper's boosting loop requires:

* ``stump``   — decision stumps: exhaustive search over (feature, threshold,
                polarity) minimizing weighted error.  The classical AdaBoost
                weak learner; compute hot-spot served by the
                ``stump_scan`` Pallas kernel (repro.kernels).
* ``logistic``— weighted logistic regression, a few Newton/GD steps.
* ``mlp``     — one-hidden-layer MLP trained by weighted SGD.

A weak learner is represented by a (params, predict_fn_name) pair where
params is a flat pytree of small arrays — this is exactly what crosses the
network at a synchronization event, so its byte size is what the paper's
communication accounting measures.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# decision stump
# ---------------------------------------------------------------------------

def stump_thresholds(x: Array, n_thresholds: int = 16) -> Array:
    """Per-feature threshold grid from feature quantiles.  x: (N,F)."""
    qs = jnp.linspace(0.0, 1.0, n_thresholds + 2)[1:-1]
    return jnp.quantile(x, qs, axis=0).T          # (F, T)


@functools.partial(jax.jit, static_argnames=("backend",))
def fit_stump(x: Array, y: Array, w: Array, thresholds: Array,
              backend: str | None = None) -> Dict[str, Array]:
    """Weighted-error-optimal stump.

    x: (N,F); y: (N,) in {-1,+1}; w: (N,) distribution; thresholds: (F,T).
    Returns {"feature", "threshold", "polarity"} scalars.

    err(f,t,+) = sum_i w_i * [sign(x_if - t) != y_i]; polarity flips sign.
    ``backend=None`` keeps the jnp oracle (the training-loop default); a
    dispatcher backend name routes the scan through ``kernels.ops``.
    """
    if backend is None:
        from repro.kernels import ref as kref
        err_pos = kref.stump_scan_ref(x, y, w, thresholds)
    else:
        from repro.kernels import ops as kops
        err_pos = kops.stump_scan(x, y, w, thresholds, backend=backend)
    # (F,T) weighted error of polarity +1; polarity -1 error is 1 - err.
    return _pick_stump(err_pos, thresholds)


def predict_stump(p: Dict[str, Array], x: Array) -> Array:
    """-> (N,) margins in {-1,+1}."""
    xv = x[:, p["feature"]]
    return p["polarity"] * jnp.sign(xv - p["threshold"] + 1e-12)


def _pick_stump(err_pos: Array, thresholds: Array) -> Dict[str, Array]:
    """The argmin/polarity selection shared by the single and batched
    fitters: err_pos is the (F,T) weighted error grid of polarity +1."""
    err_neg = 1.0 - err_pos
    best_pos = jnp.unravel_index(jnp.argmin(err_pos), err_pos.shape)
    best_neg = jnp.unravel_index(jnp.argmin(err_neg), err_neg.shape)
    take_pos = err_pos[best_pos] <= err_neg[best_neg]
    f = jnp.where(take_pos, best_pos[0], best_neg[0])
    t_idx = jnp.where(take_pos, best_pos[1], best_neg[1])
    thr = thresholds[f, t_idx]
    pol = jnp.where(take_pos, 1.0, -1.0)
    return {"feature": f.astype(jnp.int32), "threshold": thr,
            "polarity": pol}


@functools.partial(jax.jit, static_argnames=("backend",))
def fit_stump_batched(x: Array, y: Array, w: Array, thresholds: Array,
                      backend: str | None = None) -> Dict[str, Array]:
    """Fit one stump per fleet slot in a single bucketed launch.

    x: (B,N,F); y, w: (B,N); thresholds: (B,F,T).  Returns
    {"feature", "threshold", "polarity"} arrays of shape (B,).  Slots
    padded with all-zero weights are fit to garbage and must be sliced
    off by the caller (their error grid is identically zero).

    Note: ``w`` rows need not be normalized per slot — the weighted-error
    *argmin* is scale-invariant, and the engine recomputes eps against the
    true distribution — but the convention is to pass D_t rows directly.
    """
    if backend is None:
        from repro.kernels import ref as kref
        err_pos = kref.stump_scan_batched_ref(x, y, w, thresholds)
    else:
        from repro.kernels import ops as kops
        err_pos = kops.stump_scan_batched(x, y, w, thresholds,
                                          backend=backend)
    return jax.vmap(_pick_stump)(err_pos, thresholds)


@functools.partial(jax.jit, static_argnames=("n_thresholds",))
def stump_thresholds_batched(x: Array, n_valid: Array,
                             n_thresholds: int = 16) -> Array:
    """Per-client quantile threshold grids for a padded fleet stack.

    x: (B,N,F) with slot b valid in rows [0, n_valid[b]); -> (B,F,T).
    Matches ``stump_thresholds`` (jnp.quantile, linear interpolation) on
    each slot's valid rows exactly: padding rows are replaced with +inf so
    they sink to the bottom of the per-slot sort and the quantile position
    is scaled by the true row count.
    """
    B, N, F = x.shape
    qs = jnp.linspace(0.0, 1.0, n_thresholds + 2)[1:-1]          # (T,)
    valid = jnp.arange(N)[None, :] < n_valid[:, None]            # (B,N)
    xs = jnp.sort(jnp.where(valid[:, :, None], x, jnp.inf), axis=1)
    pos = qs[None, :] * (n_valid[:, None].astype(jnp.float32) - 1.0)
    lo = jnp.floor(pos).astype(jnp.int32)                        # (B,T)
    hi = jnp.ceil(pos).astype(jnp.int32)
    frac = (pos - lo.astype(jnp.float32))[:, :, None]            # (B,T,1)
    take = lambda idx: jnp.take_along_axis(xs, idx[:, :, None], axis=1)
    grid = take(lo) * (1.0 - frac) + take(hi) * frac             # (B,T,F)
    return jnp.transpose(grid, (0, 2, 1))                        # (B,F,T)


STUMP_BYTES = 3 * 4   # feature idx + threshold + polarity


# ---------------------------------------------------------------------------
# weighted logistic regression
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("steps",))
def fit_logistic(x: Array, y: Array, w: Array, key, steps: int = 50,
                 lr: float = 0.5) -> Dict[str, Array]:
    N, F = x.shape
    y01 = (y + 1.0) / 2.0

    def loss(params):
        z = x @ params["w"] + params["b"]
        p = jax.nn.sigmoid(z)
        ll = y01 * jnp.log(p + 1e-9) + (1 - y01) * jnp.log(1 - p + 1e-9)
        return -jnp.sum(w * ll)

    params = {"w": jnp.zeros((F,)), "b": jnp.zeros(())}
    g = jax.grad(loss)

    def step(params, _):
        grads = g(params)
        return jax.tree.map(lambda p, gr: p - lr * gr, params, grads), None

    params, _ = jax.lax.scan(step, params, None, length=steps)
    return params


def predict_logistic(p: Dict[str, Array], x: Array) -> Array:
    return jnp.tanh(x @ p["w"] + p["b"])


# ---------------------------------------------------------------------------
# tiny MLP
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("steps", "hidden"))
def fit_mlp(x: Array, y: Array, w: Array, key, steps: int = 80,
            hidden: int = 16, lr: float = 0.1) -> Dict[str, Array]:
    N, F = x.shape
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (F, hidden)) / jnp.sqrt(F),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden,)) / jnp.sqrt(hidden),
        "b2": jnp.zeros(()),
    }

    def fwd(params, x):
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        return jnp.tanh(h @ params["w2"] + params["b2"])

    def loss(params):
        m = fwd(params, x)
        return jnp.sum(w * jnp.square(m - y))

    g = jax.grad(loss)

    def step(params, _):
        grads = g(params)
        return jax.tree.map(lambda p, gr: p - lr * gr, params, grads), None

    params, _ = jax.lax.scan(step, params, None, length=steps)
    return params


def predict_mlp(p: Dict[str, Array], x: Array) -> Array:
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return jnp.tanh(h @ p["w2"] + p["b2"])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class WeakLearnerSpec:
    name: str
    fit: Callable              # (x, y, w, key) -> params
    predict: Callable          # (params, x) -> margins (N,)
    param_bytes: Callable      # params -> bytes on the wire


def _pytree_bytes(p) -> int:
    return int(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(p)))


def get_weak_learner(name: str, n_thresholds: int = 16,
                     policy=None) -> WeakLearnerSpec:
    """``policy`` (a :class:`repro.kernels.KernelPolicy`) routes the stump
    scan through the kernel dispatcher, re-resolved per fit call so env or
    calibration changes take effect without rebuilding the spec; ``None``
    keeps the jnp oracle."""
    if name == "stump":
        def fit(x, y, w, key):
            thr = stump_thresholds(x, n_thresholds)
            if policy is None:
                return fit_stump(x, y, w, thr)
            from repro.kernels import dispatch as kdispatch
            backend = policy.resolve_name(
                "stump_scan", kdispatch.bucket_of("stump_scan",
                                                  (x, y, w, thr)))
            return fit_stump(x, y, w, thr, backend=backend)
        return WeakLearnerSpec("stump", fit, predict_stump,
                               lambda p: STUMP_BYTES)
    if name == "logistic":
        return WeakLearnerSpec("logistic", fit_logistic, predict_logistic,
                               _pytree_bytes)
    if name == "mlp":
        return WeakLearnerSpec("mlp", fit_mlp, predict_mlp, _pytree_bytes)
    raise KeyError(name)
