"""Mamba2 SSD (state-space duality) block — chunked training scan + O(1)
decode recurrence [arXiv:2405.21060].

The training path evaluates the SSD dual form chunk-by-chunk inside one
``lax.scan``: each chunk computes the quadratic intra-chunk term (an
attention-like (L x L) product under the cumulative-decay mask) plus the
inter-chunk term from the carried state, then updates the state.  Keeping
the (B,H,L,L) score tile inside the scan body bounds transient memory to a
single chunk regardless of sequence length — the TPU-VMEM-friendly
formulation of the paper's blocked algorithm.

Numerics: A < 0, so every exponent that appears (cum_t - cum_s for t>=s,
total - cum_s, cum_t) is <= 0 and the exponentials are stable in fp32.

Decode carries {ssm_state: (B,H,P,N), conv_state: (B,k-1,conv_dim)} — the
SSM analogue of a KV cache, O(1) in sequence length (why mamba2 is the
long_500k-eligible architecture).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MambaConfig
from repro.models.layers import dense_init, rms_norm, rms_norm_init

Params = Dict[str, jnp.ndarray]


def _dims(cfg: ArchConfig):
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.d_inner(d)
    nh = mc.n_heads(d)
    return mc, d, di, nh, mc.head_dim, mc.d_state


def conv_dim(cfg: ArchConfig) -> int:
    mc, d, di, nh, hd, N = _dims(cfg)
    return di + 2 * N          # conv runs over [x, B, C] (single group)


def mamba_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    mc, d, di, nh, hd, N = _dims(cfg)
    ks = jax.random.split(key, 8)
    cd = conv_dim(cfg)
    return {
        "wz": dense_init(ks[0], d, di, dtype),
        "wx": dense_init(ks[1], d, di, dtype),
        "wB": dense_init(ks[2], d, N, dtype),
        "wC": dense_init(ks[3], d, N, dtype),
        "wdt": dense_init(ks[4], d, nh, dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (mc.d_conv, cd), jnp.float32)
                   * (1.0 / mc.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": rms_norm_init(di, dtype),
        "wo": dense_init(ks[6], di, d, dtype),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time.  xbc: (B,T,Cd); w: (k,Cd)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):                       # k is 4: unrolled shifts
        out = out + pad[:, i:i + xbc.shape[1]] * w[i]
    return out + b


def _project(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    """x: (B,T,D) -> z,(conv-in xBC), dt."""
    z = x @ p["wz"]
    xin = x @ p["wx"]
    Bp = x @ p["wB"]
    Cp = x @ p["wC"]
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    xbc = jnp.concatenate([xin, Bp, Cp], axis=-1)
    return z, xbc, dt


def _split_conv(xbc: jnp.ndarray, cfg: ArchConfig):
    mc, d, di, nh, hd, N = _dims(cfg)
    xin, Bp, Cp = jnp.split(xbc, [di, di + N], axis=-1)
    return jax.nn.silu(xin), Bp, Cp


def _ssd_chunk_scan(xh, Bp, Cp, dt, A, h0):
    """One-shot SSD over all chunks.

    xh: (B,C,L,H,P); Bp,Cp: (B,C,L,N); dt: (B,C,L,H) fp32; A: (H,) negative.
    h0: (B,H,P,N) initial state.  Returns (y: (B,C,L,H,P), h_final)."""

    def body(h, inp):
        xc, Bc, Cc, dtc = inp                # (B,L,H,P) (B,L,N) (B,L,N) (B,L,H)
        dA = dtc * A                          # (B,L,H) <= 0
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1]                    # (B,H)

        # intra-chunk (dual / attention-like) term.  Mask the exponent (not
        # the product): for t < s the difference is positive and exp would
        # overflow to inf, poisoning the 0-mask with inf*0=nan.
        CB = jnp.einsum("btn,bsn->bts", Cc, Bc)                  # (B,L,L)
        expo = cum[:, :, None, :] - cum[:, None, :, :]            # (B,t,s,H)
        tri = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        decay = jnp.exp(jnp.where(tri[None, :, :, None], expo, -jnp.inf))
        scores = CB[..., None] * decay * dtc[:, None, :, :]       # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", scores, xh_f(xc))

        # inter-chunk term from carried state
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "btn,bhpn->bthp", Cc, h)

        # state update
        w = jnp.exp(total[:, None, :] - cum) * dtc                # (B,L,H)
        S = jnp.einsum("blh,bln,blhp->bhpn", w, Bc, xh_f(xc))
        h1 = jnp.exp(total)[:, :, None, None] * h + S
        return h1, (y_intra + y_inter)

    def xh_f(v):
        return v.astype(jnp.float32)

    xs = (jnp.swapaxes(xh, 0, 1), jnp.swapaxes(Bp, 0, 1),
          jnp.swapaxes(Cp, 0, 1), jnp.swapaxes(dt, 0, 1))
    h_final, ys = jax.lax.scan(body, h0, xs)
    return jnp.swapaxes(ys, 0, 1), h_final


def _ssd(xin, Bp, Cp, dt, A, D, cfg: ArchConfig, h0=None):
    """xin: (B,T,di) post-conv; returns (y: (B,T,di), h_final: (B,H,P,N))."""
    mc, d, di, nh, hd, N = _dims(cfg)
    B, T, _ = xin.shape
    L = min(mc.chunk, T)
    while T % L != 0:
        L //= 2
    L = max(L, 1)
    C = T // L
    xh = xin.reshape(B, C, L, nh, hd)
    Bc = Bp.reshape(B, C, L, N).astype(jnp.float32)
    Cc = Cp.reshape(B, C, L, N).astype(jnp.float32)
    dtc = dt.reshape(B, C, L, nh)
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    y, h = _ssd_chunk_scan(xh, Bc, Cc, dtc, A, h0)
    y = y.reshape(B, T, nh, hd) + D[None, None, :, None] * xh.reshape(B, T, nh, hd).astype(jnp.float32)
    return y.reshape(B, T, di).astype(xin.dtype), h


def mamba_apply(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    y, _ = mamba_forward(p, x, cfg)
    return y


def mamba_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                  h0=None, conv0=None):
    """Full-sequence forward.  Returns (out (B,T,D), states dict)."""
    mc, d, di, nh, hd, N = _dims(cfg)
    z, xbc, dt = _project(p, x, cfg)
    if conv0 is not None:
        # prepend carried conv state (used by chunked prefill continuation)
        xbc_in = jnp.concatenate([conv0, xbc], axis=1)
        conv_out = _causal_conv(xbc_in, p["conv_w"], p["conv_b"])[:, conv0.shape[1]:]
    else:
        conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin, Bp, Cp = _split_conv(conv_out, cfg)
    A = -jnp.exp(p["A_log"])
    y, h = _ssd(xin, Bp, Cp, dt, A, p["D"], cfg, h0)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]
    k = mc.d_conv
    conv_state = xbc[:, -(k - 1):] if xbc.shape[1] >= k - 1 else jnp.pad(
        xbc, ((0, 0), (k - 1 - xbc.shape[1], 0), (0, 0)))
    return out, {"ssm": h, "conv": conv_state}


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    mc, d, di, nh, hd, N = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nh, hd, N), jnp.float32),
        "conv": jnp.zeros((batch, mc.d_conv - 1, conv_dim(cfg)), dtype),
    }


def mamba_decode(p: Params, x: jnp.ndarray, state: Dict, cfg: ArchConfig):
    """One-token decode.  x: (B,1,D).  Returns (out (B,1,D), new_state)."""
    mc, d, di, nh, hd, N = _dims(cfg)
    B = x.shape[0]
    z, xbc, dt = _project(p, x, cfg)                   # T=1
    xbc1 = xbc[:, 0]                                    # (B,Cd)
    window = jnp.concatenate([state["conv"], xbc1[:, None]], axis=1)  # (B,k,Cd)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xin, Bp, Cp = _split_conv(conv_out[:, None].astype(x.dtype), cfg)
    xh = xin[:, 0].reshape(B, nh, hd).astype(jnp.float32)
    Bv = Bp[:, 0].astype(jnp.float32)                   # (B,N)
    Cv = Cp[:, 0].astype(jnp.float32)
    dt1 = dt[:, 0]                                      # (B,H)

    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt1 * A)                               # (B,H)
    h0 = state["ssm"]
    upd = dt1[:, :, None, None] * xh[:, :, :, None] * Bv[:, None, None, :]
    h1 = dA[:, :, None, None] * h0 + upd                # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", h1, Cv) + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]
    return out, {"ssm": h1, "conv": window[:, 1:]}
