"""Per-client contribution audits: the measurement layer for the
Byzantine track.

Every merge the engines perform is attributed to the contributing client:
the *update magnitude* (absolute compensated vote weight folded into the
ensemble), the *error delta* (validation error before minus after the
merge — positive means the client helped), the *staleness* (sync rounds
between training and merging), and the merge *outcome*.  Stats land in
two places:

* labeled instruments on the metrics registry
  (``audit.update_magnitude{cid}``, ``audit.error_delta{cid}``,
  ``audit.staleness{cid}`` histograms and ``audit.outcomes{cid,outcome}``
  counters), so a metrics snapshot carries the whole per-client picture;
* bounded per-client rolling windows inside :class:`ContributionAudit`,
  from which :meth:`flags` computes **robust z-score outliers** — the
  modified z-score of Iglewicz & Hoaglin, ``0.6745 * (x - median) / MAD``
  over the per-client means, flagging ``|z| > 3.5``.  Median/MAD (not
  mean/std) keeps a single poisoning client from masking itself by
  inflating the spread it is judged against — the property the
  asynchronous-Byzantine literature (Cox & Decouchant) builds detection
  on.

This module only *measures*; it never changes what the engines merge, so
attaching an audit preserves bit-for-bit loop/events parity (the extra
validation-error reads are pure).  The vectorized fleet profile merges
whole windows in one launch without per-client error deltas, so audits
are a non-fleet feature (``FederatedBoostEngine.attach_audit`` refuses).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

import repro.obs as obs

__all__ = ["AuditFlag", "ClientStats", "ContributionAudit"]

# Iglewicz & Hoaglin: |modified z| > 3.5 marks an outlier
Z_THRESHOLD = 3.5
_MAD_SCALE = 0.6745            # normal-consistency constant for the MAD


@dataclass
class AuditFlag:
    """One flagged (client, metric) pair with its robust z-score."""
    cid: int
    metric: str                # "magnitude" | "error_delta" | "staleness"
    z: float
    value: float               # the client's windowed mean
    median: float              # fleet median of windowed means

    def to_dict(self) -> Dict:
        return {"cid": self.cid, "metric": self.metric, "z": self.z,
                "value": self.value, "median": self.median}


class ClientStats:
    """One client's bounded rolling contribution window."""

    __slots__ = ("cid", "merges", "magnitude", "error_delta", "staleness",
                 "outcomes")

    def __init__(self, cid: int, window: int):
        self.cid = cid
        self.merges = 0
        self.magnitude: Deque[float] = deque(maxlen=window)
        self.error_delta: Deque[float] = deque(maxlen=window)
        self.staleness: Deque[float] = deque(maxlen=window)
        self.outcomes: Dict[str, int] = {}

    def mean(self, metric: str) -> float:
        vals = getattr(self, metric)
        return sum(vals) / len(vals) if vals else 0.0

    def summary(self) -> Dict:
        return {"cid": self.cid, "merges": self.merges,
                "mean_magnitude": self.mean("magnitude"),
                "mean_error_delta": self.mean("error_delta"),
                "mean_staleness": self.mean("staleness"),
                "outcomes": dict(self.outcomes)}


def _median(vals: List[float]) -> float:
    s = sorted(vals)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def robust_z(values: Dict[int, float]) -> Dict[int, float]:
    """Modified z-scores over a {cid: value} map.  With MAD == 0 (most
    clients identical) falls back to the mean absolute deviation scaled to
    normal consistency; if that is zero too, every score is 0."""
    if len(values) < 3:
        return {cid: 0.0 for cid in values}
    med = _median(list(values.values()))
    devs = [abs(v - med) for v in values.values()]
    mad = _median(devs)
    if mad > 0.0:
        scale = mad / _MAD_SCALE
    else:
        mean_dev = sum(devs) / len(devs)
        scale = mean_dev * 1.253314  # E|N(0,1)| consistency
    if scale <= 0.0 or not math.isfinite(scale):
        return {cid: 0.0 for cid in values}
    return {cid: (v - med) / scale for cid, v in values.items()}


class ContributionAudit:
    """Rolling per-client contribution stats + robust outlier flags.

    ``registry`` defaults to the process-wide metrics registry at record
    time (so a harness-scoped fresh registry is respected); ``window``
    bounds each client's rolling deques."""

    METRICS = ("magnitude", "error_delta", "staleness")

    def __init__(self, registry=None, window: int = 256,
                 z_threshold: float = Z_THRESHOLD):
        self._registry = registry
        self.window = int(window)
        self.z_threshold = float(z_threshold)
        self.clients: Dict[int, ClientStats] = {}
        self.recorded = 0

    @property
    def registry(self):
        return (self._registry if self._registry is not None
                else obs.get_registry())

    def stats(self, cid: int) -> ClientStats:
        st = self.clients.get(cid)
        if st is None:
            st = self.clients[cid] = ClientStats(cid, self.window)
        return st

    # ------------------------------------------------------------ recording
    def record(self, cid: int, *, magnitude: float, error_delta: float,
               staleness: float, outcome: str = "merged") -> None:
        """Record one merged (or rejected) contribution."""
        st = self.stats(int(cid))
        st.merges += 1
        st.magnitude.append(float(magnitude))
        st.error_delta.append(float(error_delta))
        st.staleness.append(float(staleness))
        st.outcomes[outcome] = st.outcomes.get(outcome, 0) + 1
        self.recorded += 1
        reg = self.registry
        cid_label = str(int(cid))
        reg.histogram("audit.update_magnitude", cid=cid_label).observe(
            float(magnitude))
        reg.histogram("audit.error_delta", cid=cid_label).observe(
            float(error_delta))
        reg.histogram("audit.staleness", cid=cid_label).observe(
            float(staleness))
        reg.counter("audit.outcomes", cid=cid_label, outcome=outcome).inc()

    # -------------------------------------------------------------- reading
    def flags(self, metric: Optional[str] = None) -> List[AuditFlag]:
        """Outlier flags across clients: for each audited metric, robust
        z-scores of the per-client windowed means, flagging
        ``|z| > z_threshold``.  ``metric`` restricts to one metric."""
        metrics = (metric,) if metric is not None else self.METRICS
        out: List[AuditFlag] = []
        for m in metrics:
            values = {cid: st.mean(m) for cid, st in self.clients.items()
                      if getattr(st, m)}
            zs = robust_z(values)
            med = _median(list(values.values())) if values else 0.0
            for cid, z in sorted(zs.items()):
                if abs(z) > self.z_threshold:
                    out.append(AuditFlag(cid, m, z, values[cid], med))
        return out

    def summary(self) -> Dict:
        return {"clients": {cid: st.summary()
                            for cid, st in sorted(self.clients.items())},
                "recorded": self.recorded,
                "flags": [f.to_dict() for f in self.flags()]}
