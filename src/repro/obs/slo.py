"""SLO error budgets and multi-window burn-rate alerting on the sim clock.

The serving stack reports latency distributions; this module turns them
into *objectives*: a per-tenant :class:`SLObjective` declares what counts
as a good request (completed within a latency threshold — a rejection is
always bad) and what fraction must be good (``target``, e.g. 0.99).  The
complement ``1 - target`` is the **error budget**: the fraction of
requests the tenant is allowed to fail over a rolling window before the
objective is breached.

Alerting follows the multi-window burn-rate construction from the Google
SRE workbook: the *burn rate* over a window is the observed bad fraction
divided by the budget fraction (burn 1.0 = spending the budget exactly at
the sustainable rate; burn 10 = ten times too fast).  A
:class:`BurnRateRule` fires only when **both** a long and a short window
exceed its factor — the long window keeps one transient spike from paging,
the short window makes the alert *resolve* promptly once the burst ends
instead of waiting for the long window to drain.  Transitions are recorded
in an :class:`AlertLog` and emitted into the trace stream as
``alert.fire`` / ``alert.resolve`` points, so a stitched timeline shows
exactly which requests burned the budget.

Everything runs on the **simulated clock** (the same virtual time the
serving spans carry); nothing here reads wall time, so a quick CI run and
a long soak exercise identical logic.

The ledger is exact, not sampled: :class:`SLOMonitor` counts every
recorded outcome in ``good_total``/``bad_total`` (and optionally journals
each one), which is what ``benchmarks/sustained_slo.py`` asserts against
the request log.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Iterable, List, Optional, Tuple

import repro.obs as obs

__all__ = ["SLObjective", "BurnRateRule", "AlertEvent", "AlertLog",
           "ErrorBudget", "SLOMonitor", "default_rules"]


@dataclass(frozen=True)
class SLObjective:
    """One tenant's serving objective: at least ``target`` of requests must
    complete within ``latency_threshold_s`` (rejections count as misses),
    measured over a rolling ``window_s`` of simulated time."""
    tenant: str
    latency_threshold_s: float = 0.025
    target: float = 0.99
    window_s: float = 1.0

    @property
    def budget_fraction(self) -> float:
        """The error budget as a fraction of traffic (``1 - target``)."""
        return max(1e-9, 1.0 - float(self.target))


@dataclass(frozen=True)
class BurnRateRule:
    """Fire when the burn rate over BOTH windows is >= ``factor``; resolve
    once the short window drops back below it."""
    name: str
    long_s: float
    short_s: float
    factor: float


def default_rules(objective: SLObjective) -> Tuple[BurnRateRule, ...]:
    """The stock two-rule ladder, scaled to the objective's window: a fast
    page (burning >= 8x budget over window/4 + window/16) and a slow
    ticket (>= 2x over the full window + window/4)."""
    w = float(objective.window_s)
    return (BurnRateRule("page", long_s=w / 4.0, short_s=w / 16.0,
                         factor=8.0),
            BurnRateRule("ticket", long_s=w, short_s=w / 4.0, factor=2.0))


@dataclass
class AlertEvent:
    t: float
    tenant: str
    rule: str
    kind: str                  # "fire" | "resolve"
    burn_short: float
    burn_long: float

    def to_dict(self) -> Dict:
        return {"t": self.t, "tenant": self.tenant, "rule": self.rule,
                "kind": self.kind, "burn_short": self.burn_short,
                "burn_long": self.burn_long}


class AlertLog:
    """Ordered record of alert transitions across all tenants/rules."""

    def __init__(self):
        self.events: List[AlertEvent] = []
        self._active: Dict[Tuple[str, str], AlertEvent] = {}

    def fire(self, ev: AlertEvent) -> None:
        self.events.append(ev)
        self._active[(ev.tenant, ev.rule)] = ev

    def resolve(self, ev: AlertEvent) -> None:
        self.events.append(ev)
        self._active.pop((ev.tenant, ev.rule), None)

    def is_active(self, tenant: str, rule: str) -> bool:
        return (tenant, rule) in self._active

    def active(self) -> List[AlertEvent]:
        """The fire events still unresolved, oldest first."""
        return sorted(self._active.values(), key=lambda e: e.t)

    def timeline(self) -> List[Dict]:
        return [e.to_dict() for e in self.events]


class ErrorBudget:
    """One tenant's rolling ledger of request outcomes on the sim clock.

    Every outcome is counted exactly once in the cumulative totals; the
    windowed view trims to ``horizon_s`` so a long soak holds bounded
    state.  Records must arrive in non-decreasing ``t`` order (the serving
    stack's completion order), which makes trimming a deque pop."""

    def __init__(self, objective: SLObjective, horizon_s: float):
        self.objective = objective
        self.horizon_s = float(horizon_s)
        self._events: Deque[Tuple[float, bool]] = deque()
        self.good_total = 0
        self.bad_total = 0

    def record(self, t: float, good: bool) -> None:
        t = float(t)
        if good:
            self.good_total += 1
        else:
            self.bad_total += 1
        self._events.append((t, good))
        self._trim(t)

    def _trim(self, now: float) -> None:
        cutoff = now - self.horizon_s
        ev = self._events
        while ev and ev[0][0] < cutoff:
            ev.popleft()

    # ------------------------------------------------------------- reading
    @property
    def total(self) -> int:
        return self.good_total + self.bad_total

    def window_counts(self, now: float, window_s: float) -> Tuple[int, int]:
        """(good, bad) over ``(now - window_s, now]``."""
        cutoff = now - float(window_s)
        good = bad = 0
        for t, g in reversed(self._events):
            if t <= cutoff:
                break
            if g:
                good += 1
            else:
                bad += 1
        return good, bad

    def bad_fraction(self, now: float, window_s: float) -> float:
        good, bad = self.window_counts(now, window_s)
        n = good + bad
        return bad / n if n else 0.0

    def burn_rate(self, now: float, window_s: float) -> float:
        """Observed bad fraction over the window, in units of the budget:
        1.0 = spending the error budget exactly as fast as allowed."""
        return (self.bad_fraction(now, window_s)
                / self.objective.budget_fraction)

    def remaining(self, now: float) -> float:
        """Fraction of the objective-window budget still unspent (clipped
        to [0, 1]): 1.0 = no bad requests in the window, 0.0 = budget
        exhausted or overdrawn."""
        return min(1.0, max(0.0, 1.0 - self.burn_rate(
            now, self.objective.window_s)))


class SLOMonitor:
    """Per-tenant error budgets + burn-rate alerting over a serving run.

    Feed it every request outcome (:meth:`record` / the serving stack's
    ``on_slo`` hook via :meth:`record_completion`), call :meth:`check`
    as the sim clock advances, and read alerts from :attr:`alerts`.
    ``journal`` (optional) collects one dict per recorded outcome — the
    exact request log the benchmark reconciles the ledger against."""

    def __init__(self, objectives: Iterable[SLObjective],
                 rules: Optional[Iterable[BurnRateRule]] = None,
                 journal: Optional[List[Dict]] = None):
        self.objectives: Dict[str, SLObjective] = {
            o.tenant: o for o in objectives}
        if not self.objectives:
            raise ValueError("SLOMonitor needs at least one SLObjective")
        self._rules: Dict[str, Tuple[BurnRateRule, ...]] = {}
        self.budgets: Dict[str, ErrorBudget] = {}
        for tenant, o in self.objectives.items():
            tr = tuple(rules) if rules is not None else default_rules(o)
            self._rules[tenant] = tr
            horizon = max([o.window_s] + [r.long_s for r in tr])
            self.budgets[tenant] = ErrorBudget(o, horizon)
        self.alerts = AlertLog()
        self.journal = journal

    def rules_for(self, tenant: str) -> Tuple[BurnRateRule, ...]:
        return self._rules[tenant]

    # ------------------------------------------------------------ recording
    def record(self, tenant: str, t: float, latency_s: Optional[float] = None,
               rejected: bool = False) -> bool:
        """Record one request outcome at sim time ``t``; returns whether it
        was good.  Unknown tenants (no objective) are ignored."""
        obj = self.objectives.get(tenant)
        if obj is None:
            return True
        good = ((not rejected) and latency_s is not None
                and latency_s <= obj.latency_threshold_s)
        self.budgets[tenant].record(t, good)
        obs.count("slo.good" if good else "slo.bad", tenant=tenant)
        if self.journal is not None:
            self.journal.append({"t": float(t), "tenant": tenant,
                                 "good": good, "rejected": bool(rejected),
                                 "latency_s": latency_s})
        return good

    def record_completion(self, tenant: str, t: float,
                          latency_s: float) -> None:
        """`EnsembleServer.on_slo`-shaped adapter."""
        self.record(tenant, t, latency_s=latency_s)

    # ------------------------------------------------------------- alerting
    def check(self, now: float) -> List[AlertEvent]:
        """Evaluate every (tenant, rule) at sim time ``now``; returns the
        transitions (fires + resolves) this call produced."""
        out: List[AlertEvent] = []
        for tenant, budget in self.budgets.items():
            for rule in self._rules[tenant]:
                bl = budget.burn_rate(now, rule.long_s)
                bs = budget.burn_rate(now, rule.short_s)
                self._gauge(tenant, rule, bs)
                active = self.alerts.is_active(tenant, rule.name)
                if not active and bl >= rule.factor and bs >= rule.factor:
                    ev = AlertEvent(float(now), tenant, rule.name, "fire",
                                    bs, bl)
                    self.alerts.fire(ev)
                    out.append(ev)
                    obs.count("alert.fires", tenant=tenant, rule=rule.name)
                    obs.point("alert.fire", sim_t0=now, sim_t1=now,
                              tenant=tenant, rule=rule.name,
                              burn_short=bs, burn_long=bl)
                elif active and bs < rule.factor:
                    ev = AlertEvent(float(now), tenant, rule.name,
                                    "resolve", bs, bl)
                    self.alerts.resolve(ev)
                    out.append(ev)
                    obs.count("alert.resolves", tenant=tenant,
                              rule=rule.name)
                    obs.point("alert.resolve", sim_t0=now, sim_t1=now,
                              tenant=tenant, rule=rule.name,
                              burn_short=bs, burn_long=bl)
        return out

    def _gauge(self, tenant: str, rule: BurnRateRule, burn: float) -> None:
        obs.get_registry().gauge("slo.burn_rate", tenant=tenant,
                                 rule=rule.name).set(burn)

    # -------------------------------------------------------------- reading
    def burn_pressure(self, now: float) -> float:
        """Burn rate as an autoscaler pressure signal: the max over every
        (tenant, rule) of ``burn_short / factor`` — crosses 1.0 exactly
        when some rule's short window is burning fast enough to fire."""
        p = 0.0
        for tenant, budget in self.budgets.items():
            for rule in self._rules[tenant]:
                p = max(p, budget.burn_rate(now, rule.short_s) / rule.factor)
        return p

    def budget_remaining(self, tenant: str, now: float) -> float:
        return self.budgets[tenant].remaining(now)

    def report(self, now: float) -> Dict:
        """Per-tenant ledger summary + the alert timeline."""
        return {
            "tenants": {
                tenant: {
                    "good": b.good_total,
                    "bad": b.bad_total,
                    "budget_remaining": b.remaining(now),
                    "burn_window": b.burn_rate(now, b.objective.window_s),
                }
                for tenant, b in sorted(self.budgets.items())
            },
            "alerts": self.alerts.timeline(),
            "active_alerts": [e.to_dict() for e in self.alerts.active()],
        }
