"""repro.obs — unified tracing + metrics across train / serve / sim.

Two process-wide singletons, both consumed through cheap module-level
helpers the instrumented subsystems call unconditionally:

* the **tracer** (:mod:`repro.obs.trace`): off by default; while off,
  :func:`span`/:func:`point` return the shared :data:`NULL_SPAN` without
  allocating.  Enable with :func:`configure` (CLIs) or the :func:`tracing`
  context manager (tests, harness runs), which installs a fresh
  :class:`Tracer` and restores the previous state on exit.
* the **metrics registry** (:mod:`repro.obs.registry`): always available
  via :func:`get_registry` (counters are a dict hit + float add).  The
  expensive recorders — kernel-launch wall timing in
  ``repro.kernels.dispatch``, which must block on device results to time
  them — additionally gate on :func:`profiling_enabled`, which
  :func:`configure`/:func:`tracing` switch on alongside tracing unless
  told otherwise.

See ``src/repro/obs/README.md`` for the JSONL trace schema and the
registry namespace conventions, and ``repro.launch.obs_report`` for the
reporter CLI.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                percentile, weighted_percentile)
from repro.obs.trace import (NULL_SPAN, Span, TraceContext, Tracer,
                             load_jsonl, load_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_SPAN",
    "Span", "TraceContext", "Tracer", "configure", "count", "disable",
    "enabled", "get_registry", "get_tracer", "load_jsonl", "load_trace",
    "observe", "percentile", "point", "profiling_enabled", "set_registry",
    "span", "tracing", "weighted_percentile",
]

_TRACER: Optional[Tracer] = None
_REGISTRY = MetricsRegistry()
_PROFILE = False


# ------------------------------------------------------------------ tracer
def get_tracer() -> Optional[Tracer]:
    """The active tracer, or None while tracing is disabled."""
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


def profiling_enabled() -> bool:
    """Whether the blocking kernel-launch timers should run."""
    return _PROFILE


def configure(trace: bool = True, ring: int = 65536,
              profile_kernels: Optional[bool] = None,
              registry: Optional[MetricsRegistry] = None) -> Optional[Tracer]:
    """Install (or tear down) the process-wide observability state.

    ``trace=True`` installs a fresh :class:`Tracer` with a ``ring``-bounded
    span buffer; ``trace=False`` disables tracing.  ``profile_kernels``
    defaults to following ``trace``.  ``registry`` swaps the global
    metrics registry (a fresh one isolates a run's counters).  Returns the
    active tracer (None when disabled)."""
    global _TRACER, _PROFILE, _REGISTRY
    _TRACER = Tracer(ring) if trace else None
    _PROFILE = trace if profile_kernels is None else bool(profile_kernels)
    if registry is not None:
        _REGISTRY = registry
    return _TRACER


def disable() -> None:
    """Turn tracing and kernel profiling off (the default state)."""
    global _TRACER, _PROFILE
    _TRACER = None
    _PROFILE = False


@contextlib.contextmanager
def tracing(ring: int = 65536, profile_kernels: Optional[bool] = None,
            fresh_registry: bool = True) -> Iterator[Tracer]:
    """Scoped tracing: install a fresh tracer (and, by default, a fresh
    metrics registry so the scope's counters are isolated), yield it, and
    restore the previous global state on exit — exception-safe, so a test
    or harness run can never leak an enabled tracer into the process."""
    global _TRACER, _PROFILE, _REGISTRY
    prev = (_TRACER, _PROFILE, _REGISTRY)
    tracer = Tracer(ring)
    _TRACER = tracer
    _PROFILE = True if profile_kernels is None else bool(profile_kernels)
    if fresh_registry:
        _REGISTRY = MetricsRegistry()
    try:
        yield tracer
    finally:
        _TRACER, _PROFILE, _REGISTRY = prev


def span(name: str, sim_t: Optional[float] = None,
         ctx: Optional[TraceContext] = None, host: Optional[str] = None,
         link=None, **attrs):
    """Open a nested span on the active tracer — or return the shared
    no-op span when tracing is off (the hot-path fast path).  ``ctx``
    continues a propagated :class:`TraceContext`, ``host`` stamps the
    emitting host/node, ``link`` records extra cross-trace causal edges."""
    if _TRACER is None:
        return NULL_SPAN
    return _TRACER.span(name, sim_t=sim_t, ctx=ctx, host=host, link=link,
                        **attrs)


def point(name: str, sim_t0: Optional[float] = None,
          sim_t1: Optional[float] = None,
          ctx: Optional[TraceContext] = None, host: Optional[str] = None,
          link=None, **attrs):
    """Record an instant (already-finished) span; no-op when disabled."""
    if _TRACER is None:
        return NULL_SPAN
    return _TRACER.point(name, sim_t0=sim_t0, sim_t1=sim_t1, ctx=ctx,
                        host=host, link=link, **attrs)


# ---------------------------------------------------------------- registry
def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the global registry; returns the previous one."""
    global _REGISTRY
    old, _REGISTRY = _REGISTRY, registry
    return old


def count(name: str, n: float = 1.0, **labels) -> None:
    """Increment a counter on the global registry (always cheap)."""
    _REGISTRY.counter(name, **labels).inc(n)


def observe(name: str, v: float, **labels) -> None:
    """Observe one histogram sample on the global registry."""
    _REGISTRY.histogram(name, **labels).observe(v)


# SLO + audit layers consume the helpers above, so they import last (they
# only touch the module object at call time, never during import).
from repro.obs.audit import AuditFlag, ContributionAudit        # noqa: E402
from repro.obs.slo import (AlertEvent, AlertLog, BurnRateRule,  # noqa: E402
                           ErrorBudget, SLObjective, SLOMonitor)

__all__ += [
    "AlertEvent", "AlertLog", "AuditFlag", "BurnRateRule",
    "ContributionAudit", "ErrorBudget", "SLObjective", "SLOMonitor",
]
