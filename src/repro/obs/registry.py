"""Metrics registry: labeled counters / gauges / histograms in one
snapshot-able namespace.

Instrument names are dotted namespaces (``train.*``, ``serve.*``,
``kernel.*``, ``gossip.*``, ``autoscale.*``); labels are keyword pairs —
``registry.counter("serve.completed", tenant="mobile")`` — and each
distinct (name, labels) pair is one instrument, created on first touch and
returned on every later one (so call sites just write
``registry.counter(...).inc()`` with no registration step).

:class:`Histogram` is the repo's *single* bounded-reservoir quantile
estimator: it keeps the first ``reservoir`` samples verbatim, then thins
the stream by keeping every 8th sample, sweeping a dedicated write cursor
across the whole reservoir — the exact policy ``serve.metrics.
TenantMetrics`` used to carry privately (that class is now a view over one
of these).  Memory stays bounded under an unbounded soak; quantiles track
the full stream within the tolerance pinned by
``tests/test_obs.py::test_reservoir_soak``.

This module depends on nothing else in the repo (the serving/engine layers
import *it*, never the reverse).
"""
from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


# --------------------------------------------------------------- quantiles
def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (no numpy dependency on the hot path).

    Explicit ceil form: the smallest sample value with at least ``q``\\ %
    of the sorted sample at or below it, i.e. rank ``ceil(q/100 * n)``
    (1-based).  An earlier ``int(round(...))`` formulation used banker's
    rounding, which can land an index off the nearest rank on even-length
    lists; the behavior is pinned by a table-driven test."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = math.ceil(q / 100.0 * len(s))          # 1-based nearest rank
    return s[min(len(s) - 1, max(0, rank - 1))]


def weighted_percentile(pairs: Iterable[Tuple[float, float]],
                        q: float) -> float:
    """Nearest-rank percentile of a *weighted* sample.

    ``pairs`` is ``(value, weight)`` with weight the number of stream
    observations each retained sample stands for.  The result is the
    smallest value whose cumulative weight reaches ``q``\\ % of the total —
    the weighted generalisation of :func:`percentile` (with unit weights
    they agree exactly).  This is how a fleet percentile is computed over
    per-tenant thinned reservoirs: a tenant whose 100k completions were
    thinned to 4k samples carries 25x the weight per sample of a tenant
    whose 4k completions all fit, instead of being undercounted 25x."""
    items = sorted((float(v), float(w)) for v, w in pairs if w > 0)
    if not items:
        return 0.0
    total = sum(w for _, w in items)
    need = q / 100.0 * total
    cum = 0.0
    for v, w in items:
        cum += w
        if cum >= need - 1e-12:
            return v
    return items[-1][0]


# -------------------------------------------------------------- instruments
class Counter:
    """Monotone accumulator (float increments allowed)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar, with a convenience high-water helper."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def max(self, v: float) -> None:
        if v > self.value:
            self.value = float(v)


class Histogram:
    """Bounded-reservoir stream summary: count, sum, and quantiles.

    The first ``reservoir`` observations are kept verbatim; past that the
    stream is thinned — every 8th sample overwrites the slot under a
    dedicated write cursor that sweeps the whole reservoir (``count %
    size`` would revisit only ``size/8`` slots).  ``weight_per_sample``
    exposes how many stream observations each retained sample represents,
    which is what weighted cross-histogram percentiles consume."""

    __slots__ = ("values", "count", "sum", "_reservoir", "_skip")

    def __init__(self, reservoir: int = 4096):
        self.values: List[float] = []
        self.count = 0
        self.sum = 0.0
        self._reservoir = int(reservoir)
        self._skip = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if len(self.values) < self._reservoir:
            self.values.append(v)
        else:                        # thin the stream: keep every 8th sample
            self._skip += 1
            if self._skip % 8 == 0:
                self.values[(self._skip // 8) % self._reservoir] = v

    def extend(self, other: "Histogram") -> None:
        """Fold another histogram's retained samples + totals in (fleet
        merging of per-host instruments for the *same* stream)."""
        self.count += other.count
        self.sum += other.sum
        for v in other.values:
            if len(self.values) < self._reservoir:
                self.values.append(v)
            else:
                self._skip += 1
                if self._skip % 8 == 0:
                    self.values[(self._skip // 8) % self._reservoir] = v

    @property
    def weight_per_sample(self) -> float:
        """Stream observations each retained sample stands for."""
        return self.count / len(self.values) if self.values else 0.0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        return percentile(self.values, q)

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)


# ---------------------------------------------------------------- registry
def _key(name: str, labels: Dict) -> Tuple[str, Tuple[Tuple[str, str], ...]]:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render(key) -> str:
    name, labels = key
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class MetricsRegistry:
    """One flat namespace of labeled instruments.

    ``counter``/``gauge``/``histogram`` get-or-create; ``snapshot``
    renders everything to plain JSON-able dicts (instrument kind ->
    ``name{label=value,...}`` -> state); ``save`` persists the snapshot.
    """

    def __init__(self):
        self._counters: Dict = {}
        self._gauges: Dict = {}
        self._hists: Dict = {}

    # ------------------------------------------------------------- factory
    def counter(self, name: str, **labels) -> Counter:
        return self._counters.setdefault(_key(name, labels), Counter())

    def gauge(self, name: str, **labels) -> Gauge:
        return self._gauges.setdefault(_key(name, labels), Gauge())

    def histogram(self, name: str, reservoir: int = 4096,
                  **labels) -> Histogram:
        key = _key(name, labels)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram(reservoir)
        return h

    # ----------------------------------------------------------- iteration
    def counters(self) -> List[Tuple[str, Dict[str, str], Counter]]:
        return [(n, dict(ls), c) for (n, ls), c in self._counters.items()]

    def histograms(self) -> List[Tuple[str, Dict[str, str], Histogram]]:
        return [(n, dict(ls), h) for (n, ls), h in self._hists.items()]

    def gauges(self) -> List[Tuple[str, Dict[str, str], Gauge]]:
        return [(n, dict(ls), g) for (n, ls), g in self._gauges.items()]

    def __len__(self) -> int:
        return len(self._counters) + len(self._gauges) + len(self._hists)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict:
        return {
            "counters": {_render(k): c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {_render(k): g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {
                _render(k): {"count": h.count, "sum": h.sum,
                             "mean": h.mean, "p50": h.p50, "p99": h.p99}
                for k, h in sorted(self._hists.items())},
        }

    def save(self, path) -> str:
        p = Path(path)
        if p.parent != Path(""):
            p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.snapshot(), indent=2) + "\n")
        return str(p)

    def clear(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()
