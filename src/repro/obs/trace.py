"""Process-wide event/trace layer: nested spans on two clocks.

Every span records a *wall-clock* interval (``time.perf_counter``) and,
optionally, a *simulated-clock* interval (``sim_t0``/``sim_t1``) — the
engines stamp spans with the event-queue virtual time, the serving stack
with the caller-supplied serving clock, so one exported timeline merges
"what the hardware did" with "when the simulation said it happened".

Tracing is **off by default** and the disabled path is a true no-op: the
module-level :func:`span` helper returns the shared :data:`NULL_SPAN`
singleton without allocating anything, so instrumentation costs one global
load and one ``is None`` test on the serving hot path (pinned by
``tests/test_obs.py::test_disabled_span_is_shared_noop``).

Beyond the implicit nesting stack, spans carry a **distributed trace
identity**: every root span allocates a fresh ``trace_id``, children
inherit it, and a :class:`TraceContext` captured from one span can be
handed across hosts/nodes (a queued serving request, an on-chain commit)
to continue the same trace elsewhere.  Because the *stack* parent of a
deferred continuation is whatever span happens to be open at replay time
(a ``serve.batch`` wall-contains requests from many traces), causality
across traces is carried by explicit ``links`` — ``(trace_id, span_id)``
pairs back to the context that was propagated — and ``obs_report --check``
validates that any span whose trace differs from its stack parent's
carries such a link.

Finished spans land in a bounded in-memory ring (oldest dropped first) and
export as JSON Lines — a ``meta`` header line, then one object per span::

    {"meta": {"schema": 2, "dropped": 0, "started": 41, "exported": 41}}
    {"name": "serve.batch",          # dotted namespace (train./serve./...)
     "span": 7, "parent": 3,         # ids; parent null for roots
     "trace": "t000004",             # distributed trace identity
     "host": "host-1",               # emitting host/node ("" when unbound)
     "links": [["t000002", 5]],      # causal edges into other traces
     "t0": 0.0123, "t1": 0.0456,     # wall clock, perf_counter seconds
     "sim_t0": 1.5, "sim_t1": 1.52,  # simulated clock (null when unstamped)
     "attrs": {"tenant": "mobile", "queue_s": 0.004, ...}}

Nesting is by ``parent`` ids: a span opened while another is open becomes
its child (one implicit stack per tracer; the tree is validated by
``repro.launch.obs_report --check``).  The tracer is deliberately
single-threaded — everything in this repo advances a simulated clock from
one thread; a threaded ingress would hold one tracer per worker.
"""
from __future__ import annotations

import itertools
import json
import time
from collections import deque
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class TraceContext:
    """The propagable identity of one span: enough to continue its trace
    on another host (set the continuation's ``trace_id``) and to record
    the causal edge back (a ``(trace_id, span_id)`` link)."""
    trace_id: str
    span_id: int
    host: str = ""


def _norm_links(ctx, link) -> List[Tuple[str, int]]:
    """Normalize the ``ctx``/``link`` kwargs into ``(trace_id, span_id)``
    pairs.  ``link`` accepts a single :class:`TraceContext` or an iterable
    of them; ``ctx`` always contributes its own edge."""
    out: List[Tuple[str, int]] = []
    if ctx is not None:
        out.append((ctx.trace_id, ctx.span_id))
    if link is not None:
        if isinstance(link, TraceContext):
            link = (link,)
        out.extend((lc.trace_id, lc.span_id) for lc in link if lc is not None)
    return out


class Span:
    """One traced interval.  Use as a context manager (``with tracer.span
    (...)``) or end explicitly via :meth:`end`."""

    __slots__ = ("name", "span_id", "parent_id", "trace_id", "host",
                 "links", "t0", "t1", "sim_t0", "sim_t1", "attrs",
                 "_tracer")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: Optional[int], sim_t: Optional[float],
                 attrs: Dict, trace_id: str = "", host: str = "",
                 links: Optional[List[Tuple[str, int]]] = None):
        self._tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace_id = trace_id
        self.host = host
        self.links = links or []
        self.t0 = time.perf_counter()
        self.t1: Optional[float] = None
        self.sim_t0 = None if sim_t is None else float(sim_t)
        self.sim_t1: Optional[float] = None
        self.attrs = attrs

    # ------------------------------------------------------------- surface
    @property
    def ctx(self) -> TraceContext:
        """The propagable context of this span — hand it to whatever will
        continue this trace on another host/node."""
        return TraceContext(self.trace_id, self.span_id, self.host)

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes; returns self for chaining.  Valid
        after :meth:`end` too (the ring holds the span object, so late
        annotations — e.g. the rid assigned after admission — still
        export)."""
        self.attrs.update(attrs)
        return self

    def end_sim(self, sim_t: float) -> "Span":
        """Stamp the simulated end time (wall end still set by end())."""
        self.sim_t1 = float(sim_t)
        return self

    def end(self, sim_t: Optional[float] = None) -> None:
        if self.t1 is not None:       # idempotent: with-block + manual end
            return
        if sim_t is not None:
            self.sim_t1 = float(sim_t)
        self.t1 = time.perf_counter()
        self._tracer._finish(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end()

    # --------------------------------------------------------------- export
    def to_dict(self) -> Dict:
        d = {"name": self.name, "span": self.span_id,
             "parent": self.parent_id, "trace": self.trace_id,
             "host": self.host, "t0": self.t0, "t1": self.t1,
             "sim_t0": self.sim_t0, "sim_t1": self.sim_t1,
             "attrs": self.attrs}
        if self.links:
            d["links"] = [list(l) for l in self.links]
        return d


class _NullSpan:
    """The shared disabled-tracing span: every operation is a no-op.  A
    single module-level instance is returned for *every* span request while
    tracing is off, so the hot path never allocates."""

    __slots__ = ()

    ctx = None                 # no trace identity while tracing is off

    def set(self, **attrs) -> "_NullSpan":
        return self

    def end_sim(self, sim_t: float) -> "_NullSpan":
        return self

    def end(self, sim_t: Optional[float] = None) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + bounded ring of finished spans.

    ``ring`` bounds memory: a long soak keeps the most recent spans and
    drops the oldest (dropped count in :attr:`dropped` — surfaced by the
    export meta line so a truncated ring is never read as complete).
    """

    def __init__(self, ring: int = 65536):
        self._ring: deque = deque(maxlen=int(ring))
        self._stack: List[Span] = []       # open spans (nesting)
        self._ids = itertools.count(1)
        self._trace_ids = itertools.count(1)
        self.dropped = 0
        self.started = 0

    # ------------------------------------------------------------ creation
    def _identity(self, ctx: Optional[TraceContext], host: Optional[str]
                  ) -> Tuple[str, str]:
        """Resolve (trace_id, host) for a new span: an explicit ``ctx``
        continues its trace, otherwise the innermost open span's trace is
        inherited, otherwise a fresh trace starts."""
        parent = self._stack[-1] if self._stack else None
        if ctx is not None:
            tid = ctx.trace_id
        elif parent is not None:
            tid = parent.trace_id
        else:
            tid = f"t{next(self._trace_ids):06d}"
        if host is None:
            host = parent.host if parent is not None else ""
        return tid, host

    def span(self, name: str, sim_t: Optional[float] = None,
             ctx: Optional[TraceContext] = None,
             host: Optional[str] = None, link=None, **attrs) -> Span:
        """Open a nested span; the parent is the innermost open span.
        ``ctx`` continues a propagated trace (and records the causal link
        back), ``host`` stamps the emitting host/node, ``link`` records
        extra cross-trace edges."""
        parent = self._stack[-1] if self._stack else None
        tid, hid = self._identity(ctx, host)
        sp = Span(self, name, next(self._ids),
                  parent.span_id if parent is not None else None,
                  sim_t, attrs, trace_id=tid, host=hid,
                  links=_norm_links(ctx, link))
        self._stack.append(sp)
        self.started += 1
        return sp

    def point(self, name: str, sim_t0: Optional[float] = None,
              sim_t1: Optional[float] = None,
              ctx: Optional[TraceContext] = None,
              host: Optional[str] = None, link=None, **attrs) -> Span:
        """Record an already-finished (instant) span — an event.  It is a
        child of the innermost open span but never enters the stack."""
        parent = self._stack[-1] if self._stack else None
        tid, hid = self._identity(ctx, host)
        sp = Span(self, name, next(self._ids),
                  parent.span_id if parent is not None else None,
                  sim_t0, attrs, trace_id=tid, host=hid,
                  links=_norm_links(ctx, link))
        sp.sim_t1 = None if sim_t1 is None else float(sim_t1)
        self.started += 1
        sp.end()
        return sp

    def _finish(self, sp: Span) -> None:
        # pop through the stack to this span: children left open by an
        # early exit are abandoned rather than corrupting later parents
        if any(s is sp for s in self._stack):
            while self._stack and self._stack[-1] is not sp:
                self._stack.pop()
            if self._stack:
                self._stack.pop()
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        self._ring.append(sp)

    # -------------------------------------------------------------- export
    def __len__(self) -> int:
        return len(self._ring)

    def finished(self) -> List[Dict]:
        """Finished spans as dicts, oldest first."""
        return [s.to_dict() for s in self._ring]

    def iter_finished(self) -> Iterator[Dict]:
        return (s.to_dict() for s in self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self._stack.clear()
        self.dropped = 0
        self.started = 0

    def meta(self) -> Dict:
        """The export header: ring accounting a reader needs to know
        whether the trace is complete (``dropped == 0``)."""
        return {"schema": 2, "dropped": self.dropped,
                "started": self.started, "exported": len(self._ring)}

    def export_jsonl(self, path) -> str:
        """Write the ring as JSON Lines (meta header first); returns the
        path written."""
        p = Path(path)
        if p.parent != Path(""):
            p.parent.mkdir(parents=True, exist_ok=True)
        with p.open("w") as f:
            f.write(json.dumps({"meta": self.meta()}) + "\n")
            for d in self.iter_finished():
                f.write(json.dumps(d) + "\n")
        return str(p)


def load_trace(path) -> Tuple[Optional[Dict], List[Dict]]:
    """Parse a trace file: returns ``(meta, spans)``.  ``meta`` is None for
    pre-schema-2 files (no header line)."""
    meta: Optional[Dict] = None
    spans: List[Dict] = []
    with Path(path).open() as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "meta" in d and "name" not in d:
                meta = d["meta"]
            else:
                spans.append(d)
    return meta, spans


def load_jsonl(path) -> List[Dict]:
    """Parse a trace file written by :meth:`Tracer.export_jsonl` (the meta
    header, when present, is skipped — use :func:`load_trace` to read it)."""
    return load_trace(path)[1]
