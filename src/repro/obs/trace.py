"""Process-wide event/trace layer: nested spans on two clocks.

Every span records a *wall-clock* interval (``time.perf_counter``) and,
optionally, a *simulated-clock* interval (``sim_t0``/``sim_t1``) — the
engines stamp spans with the event-queue virtual time, the serving stack
with the caller-supplied serving clock, so one exported timeline merges
"what the hardware did" with "when the simulation said it happened".

Tracing is **off by default** and the disabled path is a true no-op: the
module-level :func:`span` helper returns the shared :data:`NULL_SPAN`
singleton without allocating anything, so instrumentation costs one global
load and one ``is None`` test on the serving hot path (pinned by
``tests/test_obs.py::test_disabled_span_is_shared_noop``).

Finished spans land in a bounded in-memory ring (oldest dropped first) and
export as JSON Lines — one object per line::

    {"name": "serve.batch",          # dotted namespace (train./serve./...)
     "span": 7, "parent": 3,         # ids; parent null for roots
     "t0": 0.0123, "t1": 0.0456,     # wall clock, perf_counter seconds
     "sim_t0": 1.5, "sim_t1": 1.52,  # simulated clock (null when unstamped)
     "attrs": {"tenant": "mobile", "queue_s": 0.004, ...}}

Nesting is by ``parent`` ids: a span opened while another is open becomes
its child (one implicit stack per tracer; the tree is validated by
``repro.launch.obs_report --check``).  The tracer is deliberately
single-threaded — everything in this repo advances a simulated clock from
one thread; a threaded ingress would hold one tracer per worker.
"""
from __future__ import annotations

import itertools
import json
import time
from collections import deque
from pathlib import Path
from typing import Dict, Iterator, List, Optional


class Span:
    """One traced interval.  Use as a context manager (``with tracer.span
    (...)``) or end explicitly via :meth:`end`."""

    __slots__ = ("name", "span_id", "parent_id", "t0", "t1",
                 "sim_t0", "sim_t1", "attrs", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: Optional[int], sim_t: Optional[float],
                 attrs: Dict):
        self._tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = time.perf_counter()
        self.t1: Optional[float] = None
        self.sim_t0 = None if sim_t is None else float(sim_t)
        self.sim_t1: Optional[float] = None
        self.attrs = attrs

    # ------------------------------------------------------------- surface
    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes; returns self for chaining."""
        self.attrs.update(attrs)
        return self

    def end_sim(self, sim_t: float) -> "Span":
        """Stamp the simulated end time (wall end still set by end())."""
        self.sim_t1 = float(sim_t)
        return self

    def end(self, sim_t: Optional[float] = None) -> None:
        if self.t1 is not None:       # idempotent: with-block + manual end
            return
        if sim_t is not None:
            self.sim_t1 = float(sim_t)
        self.t1 = time.perf_counter()
        self._tracer._finish(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end()

    # --------------------------------------------------------------- export
    def to_dict(self) -> Dict:
        return {"name": self.name, "span": self.span_id,
                "parent": self.parent_id, "t0": self.t0, "t1": self.t1,
                "sim_t0": self.sim_t0, "sim_t1": self.sim_t1,
                "attrs": self.attrs}


class _NullSpan:
    """The shared disabled-tracing span: every operation is a no-op.  A
    single module-level instance is returned for *every* span request while
    tracing is off, so the hot path never allocates."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def end_sim(self, sim_t: float) -> "_NullSpan":
        return self

    def end(self, sim_t: Optional[float] = None) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + bounded ring of finished spans.

    ``ring`` bounds memory: a long soak keeps the most recent spans and
    drops the oldest (dropped count in :attr:`dropped`).
    """

    def __init__(self, ring: int = 65536):
        self._ring: deque = deque(maxlen=int(ring))
        self._stack: List[int] = []        # open span ids (nesting)
        self._ids = itertools.count(1)
        self.dropped = 0
        self.started = 0

    # ------------------------------------------------------------ creation
    def span(self, name: str, sim_t: Optional[float] = None,
             **attrs) -> Span:
        """Open a nested span; the parent is the innermost open span."""
        parent = self._stack[-1] if self._stack else None
        sp = Span(self, name, next(self._ids), parent, sim_t, attrs)
        self._stack.append(sp.span_id)
        self.started += 1
        return sp

    def point(self, name: str, sim_t0: Optional[float] = None,
              sim_t1: Optional[float] = None, **attrs) -> Span:
        """Record an already-finished (instant) span — an event.  It is a
        child of the innermost open span but never enters the stack."""
        parent = self._stack[-1] if self._stack else None
        sp = Span(self, name, next(self._ids), parent, sim_t0, attrs)
        sp.sim_t1 = None if sim_t1 is None else float(sim_t1)
        self.started += 1
        sp.end()
        return sp

    def _finish(self, sp: Span) -> None:
        # pop through the stack to this span: children left open by an
        # early exit are abandoned rather than corrupting later parents
        if sp.span_id in self._stack:
            while self._stack and self._stack[-1] != sp.span_id:
                self._stack.pop()
            if self._stack:
                self._stack.pop()
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        self._ring.append(sp.to_dict())

    # -------------------------------------------------------------- export
    def __len__(self) -> int:
        return len(self._ring)

    def finished(self) -> List[Dict]:
        """Finished spans, oldest first (copies the ring)."""
        return list(self._ring)

    def iter_finished(self) -> Iterator[Dict]:
        return iter(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self._stack.clear()
        self.dropped = 0
        self.started = 0

    def export_jsonl(self, path) -> str:
        """Write the ring as JSON Lines; returns the path written."""
        p = Path(path)
        if p.parent != Path(""):
            p.parent.mkdir(parents=True, exist_ok=True)
        with p.open("w") as f:
            for d in self._ring:
                f.write(json.dumps(d) + "\n")
        return str(p)


def load_jsonl(path) -> List[Dict]:
    """Parse a trace file written by :meth:`Tracer.export_jsonl`."""
    out = []
    with Path(path).open() as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
