"""Step-function factories shared by the dry-run, the trainer and the
server: build (fn, abstract inputs, in/out shardings) for one
(architecture x input-shape x mesh x policy) combination.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import Model, input_specs
from repro.launch import shardings as sh
from repro.optim import adamw, cosine_schedule


def apply_policy_to_cfg(cfg: ArchConfig, pol: sh.ShardingPolicy) -> ArchConfig:
    if cfg.moe is None:
        return cfg
    moe = cfg.moe
    if pol.moe_expert_parallel:
        moe = dataclasses.replace(moe, sharding="expert")
    elif pol.moe_tensor_sm:
        moe = dataclasses.replace(moe, sharding="tensor_sm")
    if pol.moe_capacity > 0:
        moe = dataclasses.replace(moe, capacity_factor=pol.moe_capacity)
    return dataclasses.replace(cfg, moe=moe)


def build(cfg: ArchConfig, shape: ShapeConfig, mesh, pol: sh.ShardingPolicy,
          *, param_dtype=jnp.bfloat16, remat: bool = True):
    """Returns dict with fn, args (abstract), in_shardings, out_shardings."""
    cfg = apply_policy_to_cfg(cfg, pol)
    model = Model(cfg)
    aparams = model.abstract_params(param_dtype)
    pspecs = sh.param_specs(aparams, mesh, pol)
    ispecs = input_specs(cfg, shape)
    ispec_tree = sh.input_spec_tree(cfg, shape, mesh, pol)

    if shape.mode == "train":
        opt = adamw(cosine_schedule(3e-4, 100, 10_000), b2=0.95,
                    weight_decay=0.1, state_dtype=jnp.bfloat16)
        aopt = jax.eval_shape(opt.init, aparams)
        optspecs = {"m": pspecs, "v": pspecs}
        astep = jax.ShapeDtypeStruct((), jnp.int32)

        def train_step(params, opt_state, step, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=remat), has_aux=True)(params)
            new_params, new_opt = opt.update(grads, params, opt_state, step)
            return new_params, new_opt, step + 1, {
                "loss": loss, "ce": metrics["ce"], "aux": metrics["aux"]}

        return {
            "fn": train_step,
            "args": (aparams, aopt, astep, ispecs),
            "in_shardings": (pspecs, optspecs, P(), ispec_tree),
            "out_shardings": (pspecs, optspecs, P(),
                              {"loss": P(), "ce": P(), "aux": P()}),
        }

    if shape.mode == "prefill":
        def prefill_step(params, batch):
            logits, caches = model.prefill(params, batch)
            return logits, caches

        acaches = jax.eval_shape(
            lambda p, b: model.prefill(p, b)[1], aparams, ispecs)
        cspecs = sh.cache_specs(cfg, acaches, shape, mesh, pol)
        B = shape.global_batch
        batch_ok = B % sh._axis_size(mesh, pol.batch_axes) == 0
        b = pol.batch_axes if batch_ok else None
        return {
            "fn": prefill_step,
            "args": (aparams, ispecs),
            "in_shardings": (pspecs, ispec_tree),
            "out_shardings": (P(b, "model"), cspecs),
        }

    # decode: one token against a seq_len cache
    def decode_fn(params, caches, batch):
        logits, new_caches = model.decode_step(
            params, batch["tokens"], caches, batch["pos"])
        return logits, new_caches

    acaches = model.abstract_caches(shape.global_batch, shape.seq_len)
    cspecs = sh.cache_specs(cfg, acaches, shape, mesh, pol)
    B = shape.global_batch
    batch_ok = B % sh._axis_size(mesh, pol.batch_axes) == 0
    b = pol.batch_axes if batch_ok else None
    return {
        "fn": decode_fn,
        "args": (aparams, acaches, ispecs),
        "in_shardings": (pspecs, cspecs, ispec_tree),
        "out_shardings": (P(b, "model"), cspecs),
    }
