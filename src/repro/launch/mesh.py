"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.

Mesh shapes (TPU v5e pods):
  single-pod:  (data=16, model=16)              = 256 chips
  multi-pod:   (pod=2, data=16, model=16)       = 512 chips
"""
from __future__ import annotations

import jax

try:                                 # jax >= 0.5
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:                  # older jax: Auto is the only behaviour
    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False, data: int = 16,
                         model: int = 16):
    """256 chips per pod; (data, model) split configurable for the
    mesh-shape experiments in EXPERIMENTS.md §Perf (data*model must be 256)."""
    assert data * model == 256, (data, model)
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (cpu) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"), **_axis_kwargs(2))


def batch_axes(mesh) -> tuple:
    """Axes the batch dimension shards over."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
