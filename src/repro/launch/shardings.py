"""Per-architecture sharding policy (DESIGN.md §6).

Megatron-style tensor parallelism over the ``model`` axis + (optional) FSDP
over ``data`` on the other weight dim, chosen per-tensor by *divisibility* —
GQA configs whose kv-head count doesn't divide the model axis (qwen2.5-3b
kv=2) silently fall back on that tensor instead of failing to lower.

Every rule goes through :func:`_guard`, which drops an axis assignment
whose dimension isn't divisible by the mesh axis size.  Stacked block
leaves (leading ``n_periods`` axis from the scan-over-layers layout) get a
leading ``None``.

Variants (the §Perf hillclimb knobs) modulate the policy:
  * ``kv_shard_seq``  — decode caches shard the sequence dim over ``data``
    when the batch can't use it (long_500k), instead of replicating.
  * ``no_fsdp``       — weights sharded over ``model`` only.
  * (MoE expert-parallel lives in the model config: moe.sharding.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True
    kv_shard_seq: bool = False        # variant: shard cache seq over data
    moe_expert_parallel: bool = False  # variant: experts over model axis
    moe_tensor_sm: bool = False       # variant: explicit bf16 psum (shard_map)
    moe_capacity: float = 0.0         # variant: override capacity factor (0=keep)
    kv_seq_model: bool = False        # variant: shard decode cache SEQ over model
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_axis: str = "data"


def _axis_size(mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name]


def _guard(mesh, shape: Tuple[int, ...], spec: Tuple) -> P:
    """Drop axis assignments whose dim isn't divisible by the axis size,
    or that repeat an axis already used."""
    used = set()
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a in used for a in axes):
            out.append(None)
            continue
        if dim % _axis_size(mesh, ax) != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(ax)
    return P(*out)


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh,
               pol: ShardingPolicy) -> P:
    name = path[-1]
    in_moe = "moe" in path
    lead = ()
    body = shape
    # stacked block leaves carry a leading n_periods / n_layers axis
    if any(p in ("blocks", "enc_layers", "dec_layers") for p in path):
        lead = (None,)
        body = shape[1:]

    m, f = pol.model_axis, (pol.fsdp_axis if pol.fsdp else None)

    def mk(*spec):
        return _guard(mesh, shape, lead + spec)

    if name == "embed":
        return _guard(mesh, shape, (m, f))
    if name == "lm_head":
        return _guard(mesh, shape, (f, m))
    if name == "enc_pos":
        return _guard(mesh, shape, (None, None))
    if name in ("final_norm", "enc_norm", "norm", "norm1", "norm2", "norm_x",
                "dt_bias", "conv_b", "A_log", "D"):
        return P(*([None] * len(shape)))
    if name in ("bq", "bk", "bv"):
        return mk(m)
    if in_moe and name in ("w_gate", "w_up"):
        if pol.moe_expert_parallel:
            return mk(m, f, None)       # (E->model, D->data, F)
        return mk(None, f, m)           # (E, D->data, F->model)
    if in_moe and name == "w_down":
        if pol.moe_expert_parallel:
            return mk(m, None, f)
        return mk(None, m, f)
    if name == "router":
        return mk(f, None)
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "wz", "wx",
                "wB", "wC", "wdt"):
        return mk(f, m)                 # (in -> data, out -> model)
    if name in ("wo", "w_down"):
        return mk(m, f)                 # (in -> model, out -> data)
    if name == "conv_w":
        return mk(None, m)
    # fallback: replicate
    return P(*([None] * len(shape)))


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def param_specs(abstract_params, mesh, pol: ShardingPolicy):
    """PartitionSpec pytree matching the (abstract) parameter tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        # route xattn projections through the attn rules
        specs.append(_leaf_spec(names, leaf.shape, mesh, pol))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# inputs / caches
# ---------------------------------------------------------------------------

def input_spec_tree(cfg: ArchConfig, shape: ShapeConfig, mesh,
                    pol: ShardingPolicy) -> Dict[str, P]:
    B = shape.global_batch
    batch_ok = B % _axis_size(mesh, pol.batch_axes) == 0
    b = pol.batch_axes if batch_ok else None
    out: Dict[str, P] = {}
    if shape.mode == "train":
        out["tokens"] = P(b, None)
        out["labels"] = P(b, None)
    elif shape.mode == "prefill":
        out["tokens"] = P(b, None)
    else:
        out["tokens"] = P(b, None)
        out["pos"] = P()
    if cfg.frontend == "audio":
        out["frames"] = P(b, None, None)
    return out


def cache_specs(cfg: ArchConfig, abstract_caches, shape: ShapeConfig, mesh,
                pol: ShardingPolicy):
    """Decode-cache PartitionSpecs.

    Priority per KV-cache leaf (np, B, S, Hkv, hd):
      batch -> data when divisible; kv-heads -> model when divisible, else
      head_dim -> model (hd is a multiple of 16 for every assigned arch);
      with ``kv_shard_seq`` and an unshardable batch (long_500k B=1), the
      sequence dim shards over data instead of idling the axis.
    """
    B = shape.global_batch
    batch_ok = B % _axis_size(mesh, pol.batch_axes) == 0
    b = pol.batch_axes if batch_ok else None
    m = pol.model_axis

    def leaf(path, l):
        names = _path_names(path)
        name = names[-1]
        shp = l.shape
        if name in ("k", "v"):
            # (np_or_L, B, S, H, hd)
            if pol.kv_seq_model:
                # flash-decode layout: sequence over model, batch over data;
                # softmax/attn reductions over the sharded S psum small stats
                return _guard(mesh, shp, (None, b, m, None, None))
            seq_ax = (pol.batch_axes if (pol.kv_shard_seq and not batch_ok)
                      else None)
            return _guard(mesh, shp, (None, b, seq_ax, m, m))
        if name == "ssm":
            # (np, B, H, hd, N)
            return _guard(mesh, shp, (None, b, m, None, None))
        if name == "conv":
            # (np, B, k-1, conv_dim)
            return _guard(mesh, shp, (None, b, None, m))
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(leaf, abstract_caches)


def to_named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda v: isinstance(v, P))
