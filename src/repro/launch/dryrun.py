import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production mesh and record the compiled
artifact's cost/memory/collective statistics for §Roofline.

MUST be run as its own process (the XLA_FLAGS line above precedes every
jax import and locks the backend to 512 placeholder host devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod sweep
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --variant kv_shard_seq

Artifacts go to artifacts/dryrun/<arch>__<shape>__<mesh>__<variant>.json and
are skipped when present (delete to re-run).
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax

from repro.configs.registry import ARCHS, SHAPES, get_arch, get_shape, shape_applicable
from repro.launch import shardings as sh
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# sharding-policy variants for §Perf hillclimbing
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    "kv_shard_seq": {"kv_shard_seq": True},
    "no_fsdp": {"fsdp": False},
    "moe_expert_parallel": {"moe_expert_parallel": True},
    "moe_bf16_psum": {"moe_tensor_sm": True},
    "moe_cap1": {"moe_capacity": 1.0},
    "moe_ep_cap1": {"moe_expert_parallel": True, "moe_capacity": 1.0},
    "kv_seq_model": {"kv_seq_model": True},
    "serve_nofsdp": {"fsdp": False},
    "serve_opt": {"fsdp": False, "kv_seq_model": True},
    "mesh64x4": {"mesh_data": 64, "mesh_model": 4},
    "mesh32x8": {"mesh_data": 32, "mesh_model": 8},
    "mesh64x4_ep_cap1": {"mesh_data": 64, "mesh_model": 4,
                         "moe_expert_parallel": True, "moe_capacity": 1.0},
    "mesh32x8_ep_cap1": {"mesh_data": 32, "mesh_model": 8,
                         "moe_expert_parallel": True, "moe_capacity": 1.0},
    "mesh32x8_cap1": {"mesh_data": 32, "mesh_model": 8, "moe_capacity": 1.0},
    "no_remat": {},          # handled via remat flag below
}


def parse_collectives(hlo_text: str) -> Dict:
    """Sum result-buffer bytes of every collective op in the (post-SPMD,
    per-device) HLO.  `-start` variants counted, `-done` skipped."""
    out = {c: {"count": 0, "bytes": 0} for c in COLLECTIVES}
    # e.g.:  %ag = bf16[9,2048,688]{...} all-gather(...)
    pat = re.compile(
        r"=\s*(?:\([^)]*\)\s*)?([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    for mt in pat.finditer(hlo_text):
        dt, dims, op = mt.groups()
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op]["count"] += 1
        out[op]["bytes"] += n * DTYPE_BYTES[dt]
    # tuple-shaped collectives:  ( bf16[..], bf16[..] ) all-reduce-start
    tpat = re.compile(
        r"=\s*\(([^)]*)\)\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    spat = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
    for mt in tpat.finditer(hlo_text):
        inner, op = mt.groups()
        total = 0
        for dt, dims in spat.findall(inner):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES[dt]
        if total:
            out[op]["count"] += 1
            out[op]["bytes"] += total
    return out


def run_one(arch_name: str, shape_name: str, *, multi_pod: bool,
            variant: str = "baseline", force: bool = False) -> Dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(
        ART_DIR, f"{arch_name}__{shape_name}__{mesh_name}__{variant}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if not shape_applicable(cfg, shape):
        rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
               "variant": variant, "status": "skipped",
               "reason": "full-attention arch at 524k decode (DESIGN.md)"}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    vkw = dict(VARIANTS.get(variant, {}))
    data_sz = vkw.pop("mesh_data", 16)
    model_sz = vkw.pop("mesh_model", 16)
    mesh = make_production_mesh(multi_pod=multi_pod, data=data_sz,
                                model=model_sz)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    pol = sh.ShardingPolicy(batch_axes=batch_axes, **vkw)
    remat = variant != "no_remat"

    t0 = time.time()
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "n_devices": mesh.size}
    try:
        from repro.sharding_ctx import activation_sharding
        built = build(cfg, shape, mesh, pol, remat=remat)
        in_sh = sh.to_named(mesh, built["in_shardings"])
        out_sh = sh.to_named(mesh, built["out_shardings"])
        batch_ok = shape.global_batch % sh._axis_size(mesh, batch_axes) == 0
        with mesh, activation_sharding(batch_axes, "model",
                                       batch_shardable=batch_ok, mesh=mesh,
                                       fsdp_axis="data" if pol.fsdp else None):
            jitted = jax.jit(built["fn"], in_shardings=in_sh,
                             out_shardings=out_sh)
            lowered = jitted.lower(*built["args"])
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):   # older jax: one dict per device
            cost = cost[0] if cost else {}
        rec["flops_per_device"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed_per_device"] = float(
            cost.get("bytes accessed", 0.0))
        rec["cost_analysis_keys"] = sorted(
            k for k in cost.keys() if not k.startswith("bytes accessed"))[:40]

        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes",
                    "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # pragma: no cover
            rec["memory_analysis"] = {"error": str(e)}

        hlo = compiled.as_text()
        ana = hlo_analyze(hlo)
        rec["flops_corrected"] = ana["flops_corrected"]
        rec["bytes_accessed_corrected"] = ana["bytes_accessed_corrected"]
        rec["collectives"] = ana["collectives"]
        rec["collective_bytes_total"] = ana["collective_bytes_total"]
        rec["hlo_bytes"] = len(hlo)
        rec["lower_s"] = round(t_lower - t0, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    ok = err = skip = 0
    for a, s in combos:
        rec = run_one(a, s, multi_pod=args.multi_pod, variant=args.variant,
                      force=args.force)
        st = rec["status"]
        ok += st == "ok"
        err += st == "error"
        skip += st == "skipped"
        msg = rec.get("error", "")[:120]
        gf = rec.get("flops_corrected", rec.get("flops_per_device", 0)) / 1e9
        cb = rec.get("collective_bytes_total", 0) / 1e6
        print(f"[{st:7s}] {a:26s} {s:12s} {rec['mesh']:10s} "
              f"{rec.get('compile_s', 0):7.1f}s  {gf:10.1f} GF/dev  "
              f"{cb:10.1f} MB coll  {msg}", flush=True)
    print(f"done: {ok} ok, {skip} skipped, {err} errors")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
