"""Trace reporter: where did the time go, from an exported obs trace.

    PYTHONPATH=src python -m repro.launch.obs_report TRACE.jsonl
    PYTHONPATH=src python -m repro.launch.obs_report TRACE.jsonl \
        --metrics METRICS.json --top 20 --folded out.folded --check

Reads the JSON Lines trace written by ``Tracer.export_jsonl`` (schema in
``src/repro/obs/README.md``) and prints:

* **top spans** aggregated by name — count, total/self wall time, p50/p99
  span duration (self time excludes child spans, so a phase that merely
  *contains* the work doesn't dominate its own children);
* a **per-phase breakdown** by namespace prefix (``train.`` / ``serve.`` /
  ``kernel.`` / ``gossip.`` / ...) of self wall time;
* with ``--metrics``, the **kernel profile** table from the registry
  snapshot's ``kernel.wall_s{...}`` histograms, cross-checked against the
  persisted backend-calibration table (a calibrated winner that the live
  timings contradict is flagged for recalibration);
* with ``--folded``, flamegraph-style folded stacks (``a;b;c <usec>`` of
  self time per unique stack — feed to any FlameGraph renderer).

``--check`` validates the trace instead of decorating it: every line must
parse, every parent must exist and wall-contain its children (missing
parents/links are tolerated only when the export header records ring
drops, with a warning), trace ids must be consistent (a span whose stack
parent sits in another trace must link into its own), and every
``serve.request`` must decompose (queue_s + batch_s + kernel_s ==
latency_s == sim_t1 - sim_t0) within tolerance.  Exits non-zero on any
violation — the CI obs job runs it on a freshly traced scenario.

``--stitch KEY`` assembles the cross-host causal tree for one trace —
KEY is a trace id, ``rid:N``, or ``auto`` (the slowest serve.request) —
and prints its members plus every span linking into it, with hosts and
sim-clock bounds, then reconciles the stitched end-to-end latency
against the queue+batch+kernel decomposition.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from collections import defaultdict
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.obs import load_trace, percentile

TOL = 1e-6      # seconds of slack for float accumulation in checks


# ------------------------------------------------------------------ analysis
def self_times(spans: List[Dict]) -> Dict[int, float]:
    """Wall self time per span id: own duration minus direct children."""
    dur = {s["span"]: (s["t1"] or s["t0"]) - s["t0"] for s in spans}
    child_sum: Dict[int, float] = defaultdict(float)
    for s in spans:
        if s["parent"] is not None:
            child_sum[s["parent"]] += dur[s["span"]]
    return {sid: max(0.0, d - child_sum.get(sid, 0.0))
            for sid, d in dur.items()}

def aggregate(spans: List[Dict]) -> List[Dict]:
    """Per-name aggregate rows, sorted by total wall time descending."""
    self_t = self_times(spans)
    rows: Dict[str, Dict] = {}
    for s in spans:
        r = rows.setdefault(s["name"], {"name": s["name"], "count": 0,
                                        "total_s": 0.0, "self_s": 0.0,
                                        "durs": []})
        d = (s["t1"] or s["t0"]) - s["t0"]
        r["count"] += 1
        r["total_s"] += d
        r["self_s"] += self_t[s["span"]]
        r["durs"].append(d)
    out = []
    for r in rows.values():
        out.append({"name": r["name"], "count": r["count"],
                    "total_s": r["total_s"], "self_s": r["self_s"],
                    "p50_s": percentile(r["durs"], 50.0),
                    "p99_s": percentile(r["durs"], 99.0)})
    return sorted(out, key=lambda r: -r["total_s"])

def phase_breakdown(spans: List[Dict]) -> List[Tuple[str, float, int]]:
    """(namespace, self wall seconds, span count), biggest first."""
    self_t = self_times(spans)
    agg: Dict[str, List[float]] = defaultdict(lambda: [0.0, 0])
    for s in spans:
        ns = s["name"].split(".", 1)[0]
        agg[ns][0] += self_t[s["span"]]
        agg[ns][1] += 1
    return sorted(((ns, v[0], int(v[1])) for ns, v in agg.items()),
                  key=lambda r: -r[1])

def folded_stacks(spans: List[Dict]) -> Dict[str, int]:
    """Flamegraph folded stacks: 'root;child;leaf' -> self usec."""
    by_id = {s["span"]: s for s in spans}
    self_t = self_times(spans)

    def stack(s: Dict) -> str:
        names = [s["name"]]
        seen = {s["span"]}
        p = s["parent"]
        while p is not None and p in by_id and p not in seen:
            seen.add(p)
            names.append(by_id[p]["name"])
            p = by_id[p]["parent"]
        return ";".join(reversed(names))

    out: Dict[str, int] = defaultdict(int)
    for s in spans:
        usec = int(round(1e6 * self_t[s["span"]]))
        if usec > 0:
            out[stack(s)] += usec
    return dict(out)


# --------------------------------------------------------------- validation
def check_trace(spans: List[Dict],
                meta: Optional[Dict] = None) -> List[str]:
    """Structural violations in a trace (empty list = valid).

    ``meta`` is the export header (``load_trace``).  A bounded ring may
    legitimately have dropped the parent of a retained child — missing
    parents are only violations when the header proves nothing was dropped
    (or for legacy headerless traces, which predate drop accounting and
    were always checked strictly)."""
    errors: List[str] = []
    dropped = int(meta.get("dropped", 0)) if meta else 0
    tolerate_missing = meta is not None and dropped > 0
    by_id: Dict[int, Dict] = {}
    for s in spans:
        if s["span"] in by_id:
            errors.append(f"duplicate span id {s['span']}")
        by_id[s["span"]] = s
    for s in spans:
        if s["t1"] is None:
            errors.append(f"span {s['span']} ({s['name']}) never ended")
            continue
        if s["t1"] < s["t0"]:
            errors.append(f"span {s['span']} ({s['name']}) ends before "
                          f"it starts")
        p = by_id.get(s["parent"]) if s["parent"] is not None else None
        if s["parent"] is not None and p is None:
            if not tolerate_missing:
                errors.append(f"span {s['span']} ({s['name']}) references "
                              f"missing parent {s['parent']}")
        elif p is not None and p["t1"] is not None:
            if s["t0"] < p["t0"] - TOL or s["t1"] > p["t1"] + TOL:
                errors.append(
                    f"span {s['span']} ({s['name']}) escapes parent "
                    f"{p['span']} ({p['name']}) wall window")
        # trace-id consistency (schema 2 spans only): the stack parent may
        # belong to a different trace (a serve.batch wall-contains requests
        # of many traces) — but then the span must carry an explicit link
        # into its *own* trace, or its causal history is unreachable
        tid = s.get("trace")
        if tid:
            links = s.get("links", [])
            if (p is not None and p.get("trace")
                    and p["trace"] != tid
                    and not any(lt == tid for lt, _ in links)):
                errors.append(
                    f"span {s['span']} ({s['name']}) in trace {tid} has "
                    f"stack parent in trace {p['trace']} but no link "
                    f"into its own trace")
            for lt, lsid in links:
                target = by_id.get(lsid)
                if target is None:
                    if not tolerate_missing:
                        errors.append(
                            f"span {s['span']} ({s['name']}) links to "
                            f"missing span {lsid}")
                elif target.get("trace") and target["trace"] != lt:
                    errors.append(
                        f"span {s['span']} ({s['name']}) link claims span "
                        f"{lsid} is in trace {lt} but it is in "
                        f"{target['trace']}")
        if s["name"] == "serve.request":
            a = s["attrs"]
            parts = a.get("queue_s", 0) + a.get("batch_s", 0) + \
                a.get("kernel_s", 0)
            if abs(parts - a.get("latency_s", 0)) > TOL:
                errors.append(
                    f"serve.request {s['span']}: queue+batch+kernel = "
                    f"{parts:.6f}s != latency {a.get('latency_s'):.6f}s")
            if (s["sim_t0"] is not None and s["sim_t1"] is not None and
                    abs((s["sim_t1"] - s["sim_t0"])
                        - a.get("latency_s", 0)) > TOL):
                errors.append(
                    f"serve.request {s['span']}: sim interval != latency")
    return errors


# ----------------------------------------------------------------- stitching
def resolve_trace_key(spans: List[Dict], key: str) -> Optional[str]:
    """Resolve a ``--stitch`` key to a trace id.  Accepts a literal trace
    id, ``rid:N`` (the trace of request N), or ``auto`` (the trace of the
    slowest ``serve.request`` — the most interesting one to stitch)."""
    if key == "auto":
        reqs = [s for s in spans
                if s["name"] == "serve.request" and s.get("trace")]
        if not reqs:
            return next((s["trace"] for s in spans if s.get("trace")), None)
        return max(reqs, key=lambda s: s["attrs"].get("latency_s", 0.0)
                   )["trace"]
    if key.startswith("rid:"):
        rid = int(key[4:])
        for s in spans:
            if s.get("trace") and s["attrs"].get("rid") == rid:
                return s["trace"]
        return None
    return key


def stitch_trace(spans: List[Dict], trace_id: str) -> Dict:
    """Assemble the stitched causal tree for one trace: every span *in*
    the trace plus every span that links *into* it (a chain.mint /
    chain.aggregate on another node, a serve.batch host's completion).

    Returns ``{trace, members, hosts, sim_t0, sim_t1, e2e_s, parts_s}``:
    ``e2e_s`` is the trace's simulated-clock extent, ``parts_s`` the sum
    of the serve.request decomposition (queue + batch + kernel) — for a
    request trace the two agree within TOL (the acceptance check)."""
    members: List[Dict] = []
    for s in spans:
        if s.get("trace") == trace_id:
            members.append(dict(s, _edge="member"))
        elif any(lt == trace_id for lt, _ in s.get("links", [])):
            members.append(dict(s, _edge="linked"))
    members.sort(key=lambda s: (s["sim_t0"] if s["sim_t0"] is not None
                                else s["t0"], s["span"]))
    sims0 = [s["sim_t0"] for s in members
             if s["_edge"] == "member" and s["sim_t0"] is not None]
    sims1 = [s["sim_t1"] for s in members
             if s["_edge"] == "member" and s["sim_t1"] is not None]
    parts = sum(s["attrs"].get("queue_s", 0.0)
                + s["attrs"].get("batch_s", 0.0)
                + s["attrs"].get("kernel_s", 0.0)
                for s in members if s["name"] == "serve.request")
    t0 = min(sims0) if sims0 else None
    t1 = max(sims1) if sims1 else None
    return {
        "trace": trace_id,
        "members": members,
        "hosts": sorted({s.get("host", "") for s in members
                         if s.get("host")}),
        "sim_t0": t0, "sim_t1": t1,
        "e2e_s": (t1 - t0) if (t0 is not None and t1 is not None) else None,
        "parts_s": parts if parts > 0 else None,
    }


def print_stitch(st: Dict) -> None:
    print(f"\n-- stitched trace {st['trace']} "
          f"({len(st['members'])} spans, hosts: "
          f"{', '.join(st['hosts']) or '-'}) --")
    print(f"{'sim_t0':>10}{'sim_t1':>10}  {'host':<10}{'edge':<8}"
          f"{'name':<18}detail")
    for s in st["members"]:
        sim0 = f"{s['sim_t0']:.6f}" if s["sim_t0"] is not None else "-"
        sim1 = f"{s['sim_t1']:.6f}" if s["sim_t1"] is not None else "-"
        a = s["attrs"]
        detail = " ".join(f"{k}={a[k]}" for k in
                          ("rid", "tenant", "cid", "seq", "height",
                           "queue_s", "batch_s", "kernel_s", "latency_s")
                          if k in a)
        print(f"{sim0:>10}{sim1:>10}  {s.get('host', '') or '-':<10}"
              f"{s['_edge']:<8}{s['name']:<18}{detail}")
    if st["e2e_s"] is not None:
        line = f"stitched e2e: {st['e2e_s'] * 1e3:.3f} ms (sim extent)"
        if st["parts_s"] is not None:
            delta = abs(st["e2e_s"] - st["parts_s"])
            line += (f" · queue+batch+kernel = {st['parts_s'] * 1e3:.3f} ms"
                     f" · |delta| = {delta * 1e3:.6f} ms")
        print(line)


# ------------------------------------------------------------ kernel profile
_LABELED = re.compile(r"^kernel\.wall_s\{(.*)\}$")

def kernel_profile(metrics_snapshot: Dict,
                   calibration_path: Optional[str] = None
                   ) -> Tuple[List[Dict], List[str]]:
    """(profile rows, calibration warnings) from a registry snapshot.

    Rows come from ``kernel.wall_s{backend=...,bucket=...,kernel=...}``
    histograms.  When a calibration table exists, each (kernel, bucket)
    observed on 2+ backends is checked: if the calibrated winner's p50 is
    not the fastest observed, a recalibration warning is emitted."""
    rows: List[Dict] = []
    launches = metrics_snapshot.get("counters", {})
    for key, h in sorted(metrics_snapshot.get("histograms", {}).items()):
        m = _LABELED.match(key)
        if not m:
            continue
        labels = dict(kv.split("=", 1) for kv in m.group(1).split(","))
        n = launches.get(key.replace("kernel.wall_s", "kernel.launches"),
                         h.get("count", 0))
        rows.append({"kernel": labels.get("kernel", "?"),
                     "bucket": labels.get("bucket", "?"),
                     "backend": labels.get("backend", "?"),
                     "launches": int(n), "p50_s": h["p50"],
                     "p99_s": h["p99"]})
    warnings: List[str] = []
    table: Dict[Tuple[str, str], str] = {}
    if calibration_path and Path(calibration_path).exists():
        data = json.loads(Path(calibration_path).read_text())
        for e in data.get("table", []):
            blabel = "x".join(str(int(d)) for d in e["bucket"])
            table[(e["kernel"], blabel)] = e["backend"]
    if table:
        grouped: Dict[Tuple[str, str], Dict[str, float]] = defaultdict(dict)
        for r in rows:
            grouped[(r["kernel"], r["bucket"])][r["backend"]] = r["p50_s"]
        for (kern, bucket), by_backend in sorted(grouped.items()):
            winner = table.get((kern, bucket))
            if winner is None or winner not in by_backend \
                    or len(by_backend) < 2:
                continue
            best = min(by_backend, key=by_backend.get)
            if best != winner:
                warnings.append(
                    f"calibration stale: {kern}@{bucket} calibrated to "
                    f"'{winner}' (observed p50 {by_backend[winner]*1e3:.3f} "
                    f"ms) but '{best}' measured faster "
                    f"({by_backend[best]*1e3:.3f} ms) — recalibrate")
    return rows, warnings


# ----------------------------------------------------------------- printing
def _fmt_s(s: float) -> str:
    return f"{1e3 * s:10.3f}ms"

def print_report(spans: List[Dict], top: int,
                 metrics_snapshot: Optional[Dict],
                 calibration_path: Optional[str],
                 dropped: int = 0) -> None:
    total_self = sum(self_times(spans).values())
    drop_note = f" · {dropped} dropped by ring" if dropped else ""
    print(f"{len(spans)} spans · {total_self * 1e3:.1f} ms traced self "
          f"time{drop_note}")
    print(f"\n-- top {top} span names (by total wall time) --")
    print(f"{'name':<24}{'count':>7}{'total':>13}{'self':>13}"
          f"{'p50':>13}{'p99':>13}")
    for r in aggregate(spans)[:top]:
        print(f"{r['name']:<24}{r['count']:>7}{_fmt_s(r['total_s']):>13}"
              f"{_fmt_s(r['self_s']):>13}{_fmt_s(r['p50_s']):>13}"
              f"{_fmt_s(r['p99_s']):>13}")
    print("\n-- per-phase self time --")
    for ns, sec, n in phase_breakdown(spans):
        pct = 100.0 * sec / total_self if total_self else 0.0
        print(f"{ns:<12}{_fmt_s(sec):>13}  {pct:5.1f}%  ({n} spans)")
    if metrics_snapshot is not None:
        rows, warns = kernel_profile(metrics_snapshot, calibration_path)
        if rows:
            print("\n-- kernel profile --")
            print(f"{'kernel':<22}{'bucket':<16}{'backend':<11}"
                  f"{'launches':>9}{'p50':>13}{'p99':>13}")
            for r in rows:
                print(f"{r['kernel']:<22}{r['bucket']:<16}"
                      f"{r['backend']:<11}{r['launches']:>9}"
                      f"{_fmt_s(r['p50_s']):>13}{_fmt_s(r['p99_s']):>13}")
        for w in warns:
            print(f"WARNING: {w}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="per-phase time breakdown from an obs JSONL trace")
    ap.add_argument("trace", help="JSONL trace (Tracer.export_jsonl output)")
    ap.add_argument("--metrics", default=None,
                    help="registry snapshot JSON (MetricsRegistry.save)")
    ap.add_argument("--calibration",
                    default="artifacts/backend_calibration.json",
                    help="backend calibration table to sanity-check "
                         "against observed kernel timings")
    ap.add_argument("--top", type=int, default=15,
                    help="span names to show (default 15)")
    ap.add_argument("--folded", default=None, metavar="OUT",
                    help="write flamegraph folded stacks here")
    ap.add_argument("--check", action="store_true",
                    help="validate structure (parse, nesting, trace ids, "
                         "links, request decomposition); non-zero exit "
                         "on violation")
    ap.add_argument("--stitch", default=None, metavar="KEY",
                    help="print the stitched cross-host tree for one "
                         "trace: a trace id, 'rid:N', or 'auto' (slowest "
                         "serve.request)")
    args = ap.parse_args(argv)

    try:
        meta, spans = load_trace(args.trace)
    except (OSError, json.JSONDecodeError) as e:
        print(f"unreadable trace {args.trace!r}: {e}", file=sys.stderr)
        return 2
    if not spans:
        print(f"empty trace {args.trace!r}", file=sys.stderr)
        return 2
    dropped = int(meta.get("dropped", 0)) if meta else 0

    snapshot = None
    if args.metrics:
        snapshot = json.loads(Path(args.metrics).read_text())

    if args.check:
        errors = check_trace(spans, meta)
        if errors:
            for e in errors[:50]:
                print(f"CHECK FAILED: {e}", file=sys.stderr)
            print(f"{len(errors)} violation(s) in {len(spans)} spans",
                  file=sys.stderr)
            return 1
        if dropped:
            print(f"WARNING: ring dropped {dropped} span(s) — trace is "
                  f"incomplete; missing-parent/link checks relaxed")
        print(f"trace OK: {len(spans)} spans parse, nest, and decompose")

    print_report(spans, args.top, snapshot, args.calibration,
                 dropped=dropped)

    if args.stitch:
        tid = resolve_trace_key(spans, args.stitch)
        if tid is None:
            print(f"no trace matches stitch key {args.stitch!r}",
                  file=sys.stderr)
            return 2
        print_stitch(stitch_trace(spans, tid))

    if args.folded:
        stacks = folded_stacks(spans)
        with Path(args.folded).open("w") as f:
            for stack, usec in sorted(stacks.items()):
                f.write(f"{stack} {usec}\n")
        print(f"\nwrote {len(stacks)} folded stacks -> {args.folded}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
