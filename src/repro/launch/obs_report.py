"""Trace reporter: where did the time go, from an exported obs trace.

    PYTHONPATH=src python -m repro.launch.obs_report TRACE.jsonl
    PYTHONPATH=src python -m repro.launch.obs_report TRACE.jsonl \
        --metrics METRICS.json --top 20 --folded out.folded --check

Reads the JSON Lines trace written by ``Tracer.export_jsonl`` (schema in
``src/repro/obs/README.md``) and prints:

* **top spans** aggregated by name — count, total/self wall time, p50/p99
  span duration (self time excludes child spans, so a phase that merely
  *contains* the work doesn't dominate its own children);
* a **per-phase breakdown** by namespace prefix (``train.`` / ``serve.`` /
  ``kernel.`` / ``gossip.`` / ...) of self wall time;
* with ``--metrics``, the **kernel profile** table from the registry
  snapshot's ``kernel.wall_s{...}`` histograms, cross-checked against the
  persisted backend-calibration table (a calibrated winner that the live
  timings contradict is flagged for recalibration);
* with ``--folded``, flamegraph-style folded stacks (``a;b;c <usec>`` of
  self time per unique stack — feed to any FlameGraph renderer).

``--check`` validates the trace instead of decorating it: every line must
parse, every parent must exist and wall-contain its children, and every
``serve.request`` must decompose (queue_s + batch_s + kernel_s ==
latency_s == sim_t1 - sim_t0) within tolerance.  Exits non-zero on any
violation — the CI obs job runs it on a freshly traced scenario.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from collections import defaultdict
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.obs import load_jsonl, percentile

TOL = 1e-6      # seconds of slack for float accumulation in checks


# ------------------------------------------------------------------ analysis
def self_times(spans: List[Dict]) -> Dict[int, float]:
    """Wall self time per span id: own duration minus direct children."""
    dur = {s["span"]: (s["t1"] or s["t0"]) - s["t0"] for s in spans}
    child_sum: Dict[int, float] = defaultdict(float)
    for s in spans:
        if s["parent"] is not None:
            child_sum[s["parent"]] += dur[s["span"]]
    return {sid: max(0.0, d - child_sum.get(sid, 0.0))
            for sid, d in dur.items()}

def aggregate(spans: List[Dict]) -> List[Dict]:
    """Per-name aggregate rows, sorted by total wall time descending."""
    self_t = self_times(spans)
    rows: Dict[str, Dict] = {}
    for s in spans:
        r = rows.setdefault(s["name"], {"name": s["name"], "count": 0,
                                        "total_s": 0.0, "self_s": 0.0,
                                        "durs": []})
        d = (s["t1"] or s["t0"]) - s["t0"]
        r["count"] += 1
        r["total_s"] += d
        r["self_s"] += self_t[s["span"]]
        r["durs"].append(d)
    out = []
    for r in rows.values():
        out.append({"name": r["name"], "count": r["count"],
                    "total_s": r["total_s"], "self_s": r["self_s"],
                    "p50_s": percentile(r["durs"], 50.0),
                    "p99_s": percentile(r["durs"], 99.0)})
    return sorted(out, key=lambda r: -r["total_s"])

def phase_breakdown(spans: List[Dict]) -> List[Tuple[str, float, int]]:
    """(namespace, self wall seconds, span count), biggest first."""
    self_t = self_times(spans)
    agg: Dict[str, List[float]] = defaultdict(lambda: [0.0, 0])
    for s in spans:
        ns = s["name"].split(".", 1)[0]
        agg[ns][0] += self_t[s["span"]]
        agg[ns][1] += 1
    return sorted(((ns, v[0], int(v[1])) for ns, v in agg.items()),
                  key=lambda r: -r[1])

def folded_stacks(spans: List[Dict]) -> Dict[str, int]:
    """Flamegraph folded stacks: 'root;child;leaf' -> self usec."""
    by_id = {s["span"]: s for s in spans}
    self_t = self_times(spans)

    def stack(s: Dict) -> str:
        names = [s["name"]]
        seen = {s["span"]}
        p = s["parent"]
        while p is not None and p in by_id and p not in seen:
            seen.add(p)
            names.append(by_id[p]["name"])
            p = by_id[p]["parent"]
        return ";".join(reversed(names))

    out: Dict[str, int] = defaultdict(int)
    for s in spans:
        usec = int(round(1e6 * self_t[s["span"]]))
        if usec > 0:
            out[stack(s)] += usec
    return dict(out)


# --------------------------------------------------------------- validation
def check_trace(spans: List[Dict]) -> List[str]:
    """Structural violations in a trace (empty list = valid)."""
    errors: List[str] = []
    by_id: Dict[int, Dict] = {}
    for s in spans:
        if s["span"] in by_id:
            errors.append(f"duplicate span id {s['span']}")
        by_id[s["span"]] = s
    for s in spans:
        if s["t1"] is None:
            errors.append(f"span {s['span']} ({s['name']}) never ended")
            continue
        if s["t1"] < s["t0"]:
            errors.append(f"span {s['span']} ({s['name']}) ends before "
                          f"it starts")
        p = by_id.get(s["parent"]) if s["parent"] is not None else None
        if s["parent"] is not None and p is None:
            # a bounded ring may have dropped the parent of a retained
            # child; only flag when nothing was dropped upstream
            errors.append(f"span {s['span']} ({s['name']}) references "
                          f"missing parent {s['parent']}")
        elif p is not None and p["t1"] is not None:
            if s["t0"] < p["t0"] - TOL or s["t1"] > p["t1"] + TOL:
                errors.append(
                    f"span {s['span']} ({s['name']}) escapes parent "
                    f"{p['span']} ({p['name']}) wall window")
        if s["name"] == "serve.request":
            a = s["attrs"]
            parts = a.get("queue_s", 0) + a.get("batch_s", 0) + \
                a.get("kernel_s", 0)
            if abs(parts - a.get("latency_s", 0)) > TOL:
                errors.append(
                    f"serve.request {s['span']}: queue+batch+kernel = "
                    f"{parts:.6f}s != latency {a.get('latency_s'):.6f}s")
            if (s["sim_t0"] is not None and s["sim_t1"] is not None and
                    abs((s["sim_t1"] - s["sim_t0"])
                        - a.get("latency_s", 0)) > TOL):
                errors.append(
                    f"serve.request {s['span']}: sim interval != latency")
    return errors


# ------------------------------------------------------------ kernel profile
_LABELED = re.compile(r"^kernel\.wall_s\{(.*)\}$")

def kernel_profile(metrics_snapshot: Dict,
                   calibration_path: Optional[str] = None
                   ) -> Tuple[List[Dict], List[str]]:
    """(profile rows, calibration warnings) from a registry snapshot.

    Rows come from ``kernel.wall_s{backend=...,bucket=...,kernel=...}``
    histograms.  When a calibration table exists, each (kernel, bucket)
    observed on 2+ backends is checked: if the calibrated winner's p50 is
    not the fastest observed, a recalibration warning is emitted."""
    rows: List[Dict] = []
    launches = metrics_snapshot.get("counters", {})
    for key, h in sorted(metrics_snapshot.get("histograms", {}).items()):
        m = _LABELED.match(key)
        if not m:
            continue
        labels = dict(kv.split("=", 1) for kv in m.group(1).split(","))
        n = launches.get(key.replace("kernel.wall_s", "kernel.launches"),
                         h.get("count", 0))
        rows.append({"kernel": labels.get("kernel", "?"),
                     "bucket": labels.get("bucket", "?"),
                     "backend": labels.get("backend", "?"),
                     "launches": int(n), "p50_s": h["p50"],
                     "p99_s": h["p99"]})
    warnings: List[str] = []
    table: Dict[Tuple[str, str], str] = {}
    if calibration_path and Path(calibration_path).exists():
        data = json.loads(Path(calibration_path).read_text())
        for e in data.get("table", []):
            blabel = "x".join(str(int(d)) for d in e["bucket"])
            table[(e["kernel"], blabel)] = e["backend"]
    if table:
        grouped: Dict[Tuple[str, str], Dict[str, float]] = defaultdict(dict)
        for r in rows:
            grouped[(r["kernel"], r["bucket"])][r["backend"]] = r["p50_s"]
        for (kern, bucket), by_backend in sorted(grouped.items()):
            winner = table.get((kern, bucket))
            if winner is None or winner not in by_backend \
                    or len(by_backend) < 2:
                continue
            best = min(by_backend, key=by_backend.get)
            if best != winner:
                warnings.append(
                    f"calibration stale: {kern}@{bucket} calibrated to "
                    f"'{winner}' (observed p50 {by_backend[winner]*1e3:.3f} "
                    f"ms) but '{best}' measured faster "
                    f"({by_backend[best]*1e3:.3f} ms) — recalibrate")
    return rows, warnings


# ----------------------------------------------------------------- printing
def _fmt_s(s: float) -> str:
    return f"{1e3 * s:10.3f}ms"

def print_report(spans: List[Dict], top: int,
                 metrics_snapshot: Optional[Dict],
                 calibration_path: Optional[str]) -> None:
    total_self = sum(self_times(spans).values())
    print(f"{len(spans)} spans · {total_self * 1e3:.1f} ms traced self time")
    print(f"\n-- top {top} span names (by total wall time) --")
    print(f"{'name':<24}{'count':>7}{'total':>13}{'self':>13}"
          f"{'p50':>13}{'p99':>13}")
    for r in aggregate(spans)[:top]:
        print(f"{r['name']:<24}{r['count']:>7}{_fmt_s(r['total_s']):>13}"
              f"{_fmt_s(r['self_s']):>13}{_fmt_s(r['p50_s']):>13}"
              f"{_fmt_s(r['p99_s']):>13}")
    print("\n-- per-phase self time --")
    for ns, sec, n in phase_breakdown(spans):
        pct = 100.0 * sec / total_self if total_self else 0.0
        print(f"{ns:<12}{_fmt_s(sec):>13}  {pct:5.1f}%  ({n} spans)")
    if metrics_snapshot is not None:
        rows, warns = kernel_profile(metrics_snapshot, calibration_path)
        if rows:
            print("\n-- kernel profile --")
            print(f"{'kernel':<22}{'bucket':<16}{'backend':<11}"
                  f"{'launches':>9}{'p50':>13}{'p99':>13}")
            for r in rows:
                print(f"{r['kernel']:<22}{r['bucket']:<16}"
                      f"{r['backend']:<11}{r['launches']:>9}"
                      f"{_fmt_s(r['p50_s']):>13}{_fmt_s(r['p99_s']):>13}")
        for w in warns:
            print(f"WARNING: {w}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="per-phase time breakdown from an obs JSONL trace")
    ap.add_argument("trace", help="JSONL trace (Tracer.export_jsonl output)")
    ap.add_argument("--metrics", default=None,
                    help="registry snapshot JSON (MetricsRegistry.save)")
    ap.add_argument("--calibration",
                    default="artifacts/backend_calibration.json",
                    help="backend calibration table to sanity-check "
                         "against observed kernel timings")
    ap.add_argument("--top", type=int, default=15,
                    help="span names to show (default 15)")
    ap.add_argument("--folded", default=None, metavar="OUT",
                    help="write flamegraph folded stacks here")
    ap.add_argument("--check", action="store_true",
                    help="validate structure (parse, nesting, request "
                         "decomposition); non-zero exit on violation")
    args = ap.parse_args(argv)

    try:
        spans = load_jsonl(args.trace)
    except (OSError, json.JSONDecodeError) as e:
        print(f"unreadable trace {args.trace!r}: {e}", file=sys.stderr)
        return 2
    if not spans:
        print(f"empty trace {args.trace!r}", file=sys.stderr)
        return 2

    snapshot = None
    if args.metrics:
        snapshot = json.loads(Path(args.metrics).read_text())

    if args.check:
        errors = check_trace(spans)
        if errors:
            for e in errors[:50]:
                print(f"CHECK FAILED: {e}", file=sys.stderr)
            print(f"{len(errors)} violation(s) in {len(spans)} spans",
                  file=sys.stderr)
            return 1
        print(f"trace OK: {len(spans)} spans parse, nest, and decompose")

    print_report(spans, args.top, snapshot, args.calibration)

    if args.folded:
        stacks = folded_stacks(spans)
        with Path(args.folded).open("w") as f:
            for stack, usec in sorted(stacks.items()):
                f.write(f"{stack} {usec}\n")
        print(f"\nwrote {len(stacks)} folded stacks -> {args.folded}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
