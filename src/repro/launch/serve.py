"""Serving driver: batched prefill + decode loop against any assigned
architecture (reduced preset on CPU; full configs are exercised by the
dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_arch
from repro.models import Model


def generate(model: Model, params, prompts: jnp.ndarray, n_new: int,
             cache_len: int, frames=None, temperature: float = 0.0,
             seed: int = 0):
    """prompts: (B, T0) -> (B, T0 + n_new) greedy/temperature sampling."""
    cfg = model.cfg
    B, T0 = prompts.shape
    if cfg.is_encoder_decoder:
        logits, caches = jax.jit(
            lambda p, f, t: model.prefill(p, {"frames": f, "tokens": t},
                                          cache_seq=cache_len)
        )(params, frames, prompts)
    else:
        logits, caches = jax.jit(
            lambda p, t: model.prefill(p, {"tokens": t}, cache_seq=cache_len)
        )(params, prompts)

    decode = jax.jit(model.decode_step)
    key = jax.random.key(seed)
    out = [prompts]
    tok = None
    for i in range(n_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = jnp.minimum(tok, cfg.vocab_size - 1).astype(jnp.int32)[:, None]
        out.append(tok)
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(T0 + i, jnp.int32))
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    frames = (jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model))
              if cfg.is_encoder_decoder else None)
    t0 = time.time()
    seqs = generate(model, params, prompts, args.tokens,
                    cache_len=args.prompt_len + args.tokens, frames=frames,
                    temperature=args.temperature)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {args.batch}x{args.tokens} tokens "
          f"in {dt:.1f}s ({args.batch*args.tokens/dt:.1f} tok/s)")
    print("sample:", np.asarray(seqs[0])[:32].tolist())


if __name__ == "__main__":
    main()
