"""Ensemble serving driver: train federated boosted ensembles on paper
domains, publish snapshots into a sharded registry cluster mid-training,
gossip them across hosts, then serve a bursty closed-loop workload through
the adaptive micro-batcher with per-snapshot result caching.

    PYTHONPATH=src python -m repro.launch.serve_ensemble \
        --domains edge_vision iot --rounds 12 --rate 400 --duration 3 \
        --hosts 3 --cache 4096 --kill-owner

Prints per-tenant published versions and gossip convergence, then the
serving report: throughput, p50/p99 latency, batch-size mix, snapshot
staleness, per-host traffic, and cache hit rate.  ``--fixed-window N``
disables window adaptation for an A/B against a fixed window of N
milliseconds; ``--kill-owner`` marks the first tenant's owning host down
halfway through to exercise rendezvous failover onto a gossiped replica;
``--backend``/``--calibration`` pin or table-drive the kernel execution
backend (see README "Execution backends"); ``--autoscale MAX`` lets the
eq.-(1) fleet autoscaler grow/shrink the host count between ``--hosts``
and MAX on queue-depth/p99 pressure; ``--policy-table JSON`` loads
per-(tenant, host) batching/kernel policies (README "Fleet autoscaling").
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro import obs
from repro.configs.paper_fedboost import FedBoostConfig
from repro.sim.scenarios import DOMAINS
from repro.core import FederatedBoostEngine
from repro.data import make_domain_data
from repro.kernels.dispatch import KernelPolicy
from repro.serve import (AutoscaleConfig, BatchConfig, FleetAutoscaler,
                         GossipConfig, PolicyTable, ServeMetrics,
                         ShardCluster, ShardedEnsembleServer)


def train_tenants(cluster: ShardCluster, domains, rounds: int, seed: int,
                  policy=None):
    pools = {}
    for name in domains:
        dom = dataclasses.replace(DOMAINS[name],
                                  n_samples=min(DOMAINS[name].n_samples, 2000),
                                  n_clients=min(DOMAINS[name].n_clients, 8))
        data = make_domain_data(dom, seed=seed)
        cfg = FedBoostConfig(n_clients=dom.n_clients, n_rounds=rounds,
                             straggler_factor=dom.straggler_factor,
                             dropout_prob=dom.dropout_prob, seed=seed,
                             balanced_init=dom.label_imbalance < 0.4)
        eng = FederatedBoostEngine(cfg, data, "enhanced",
                                   kernel_policy=policy)
        eng.attach_registry(cluster, name)    # publishes route to the owner
        metrics = eng.run()
        pools[name] = np.asarray(data["test"][0], np.float32)
        snap = cluster.latest(name)
        print(f"trained {name:<12} val_err={metrics.final_val_error:.3f} "
              f"-> {cluster.version_count(name)} snapshots published "
              f"(latest v{snap.version}, {snap.n_learners} learners, "
              f"owner {cluster.owner(name)})")
    rounds_taken = cluster.run_until_quiescent(now=0.0)
    print(f"gossip converged in {rounds_taken} anti-entropy round(s): "
          f"{cluster.stats.pulled} snapshots pulled, "
          f"{cluster.stats.reconciled} conflicts reconciled")
    cluster.rebase_clock(0.0)
    return pools


def serve(cluster: ShardCluster, pools, rate: float, duration: float,
          seed: int, fixed_window_ms: float = 0.0, cache_capacity: int = 4096,
          kill_owner: bool = False, policy=None, policy_table=None,
          autoscale_max: int = 0, budget_per_host: float = None,
          budget_per_hour: float = None):
    # the flag-built config composes with a policy table: it becomes the
    # fleet default the table's host/tenant/pair overrides layer onto
    cfg = (BatchConfig(adaptive=False,
                       fixed_window_units=max(1, int(fixed_window_ms)),
                       cache_capacity=cache_capacity)
           if fixed_window_ms > 0
           else BatchConfig(cache_capacity=cache_capacity))
    server = ShardedEnsembleServer(
        cluster, cfg, service_model=lambda n: 1.2e-3 + 2.0e-4 * n,
        policy=policy, policy_table=policy_table)
    scaler = None
    if autoscale_max > 0:
        scaler = FleetAutoscaler(server, AutoscaleConfig(
            min_hosts=len(cluster.hosts),
            max_hosts=max(autoscale_max, len(cluster.hosts))),
            budget_per_host=budget_per_host,
            budget_per_hour=budget_per_hour)
    elif budget_per_host is not None or budget_per_hour is not None:
        print("  WARNING: --budget-per-host/--budget-per-hour only apply "
              "to an autoscaled fleet; pass --autoscale MAX to enable "
              "the cost cap (budget flags ignored)")
    tenants = sorted(pools)
    victim = cluster.owner(tenants[0]) if kill_owner else None
    rng = np.random.RandomState(seed)
    t, killed = 0.0, False
    while t < duration:
        # bursty arrivals: 3x rate on-phase, 0.1x off-phase, 0.5 s period
        lam = rate * (3.0 if (t % 0.5) < 0.25 else 0.1)
        t += rng.exponential(1.0 / max(lam, 1e-9))
        if t >= duration:
            break
        if victim is not None and not killed and t >= 0.5 * duration:
            cluster.mark_down(victim)
            killed = True
            print(f"  t={t:.2f}s marked {victim} down -> "
                  f"{tenants[0]} now served by "
                  f"{cluster.route(tenants[0]).host_id} (gossiped replica)")
        tenant = tenants[rng.randint(len(tenants))]
        pool = pools[tenant]
        server.submit(tenant, pool[rng.randint(pool.shape[0])], t)
        if scaler is not None:
            scaler.step(t)
    server.drain()
    if scaler is not None:
        st = scaler.stats
        print(f"  autoscaler: {st.scale_outs} scale-out(s), "
              f"{st.scale_ins} scale-in(s), {st.rerouted} request(s) "
              f"rerouted, peak pressure {st.pressure_peak:.2f}, "
              f"final fleet {len(server.servers)} host(s)")
        if st.budget_capped:
            print(f"  budget: {st.budget_capped} scale-out(s) refused at "
                  f"{scaler.projected_cost():.2f} $/h projected "
                  f"(cap {scaler.budget_per_hour:.2f} $/h, "
                  f"{scaler.cost_per_host_hour:.2f} $/h per host)")
        for when, action, hid, size in st.events:
            print(f"    t={when:.2f}s scale-{action:<3} {hid:<10} "
                  f"-> {size} hosts")
    return server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--domains", nargs="+",
                    default=["edge_vision", "iot"], choices=sorted(DOMAINS))
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--rate", type=float, default=400.0)
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hosts", type=int, default=3,
                    help="serving hosts in the sharded cluster")
    ap.add_argument("--cache", type=int, default=4096,
                    help="result-cache entries per host (0 disables)")
    ap.add_argument("--kill-owner", action="store_true",
                    help="mark the first tenant's owner down mid-serve "
                         "(failover demo)")
    ap.add_argument("--fixed-window", type=float, default=0.0,
                    help="fixed batch window in ms (0 = adaptive)")
    ap.add_argument("--autoscale", type=int, default=0, metavar="MAX",
                    help="autoscale the fleet between --hosts and MAX "
                         "hosts on queue-depth/p99 pressure (0 = fixed "
                         "fleet)")
    ap.add_argument("--budget-per-host", type=float, default=None,
                    metavar="$/H", help="projected cost of one serving "
                    "host in $/hour (cost-aware autoscaling)")
    ap.add_argument("--budget-per-hour", type=float, default=None,
                    metavar="$/H", help="fleet budget in $/hour: "
                    "scale-outs that would exceed it are refused")
    ap.add_argument("--policy-table", default=None, metavar="JSON",
                    help="per-(tenant, host) batching/kernel policy table "
                         "(see repro.serve.policy for the JSON shape); "
                         "the CLI batching flags form the fleet default "
                         "its host/tenant/pair overrides layer onto")
    ap.add_argument("--backend", default=None,
                    choices=["interpret", "mosaic", "xla"],
                    help="force one kernel backend fleet-wide (default: "
                         "per-call resolution — REPRO_KERNEL_BACKEND env "
                         "var > calibration > platform default)")
    ap.add_argument("--calibration", default=None, metavar="JSON",
                    help="backend-calibration table written by "
                         "benchmarks.backend_matrix; per-bucket winners "
                         "drive kernel dispatch")
    ap.add_argument("--trace", default=None, metavar="OUT.jsonl",
                    help="export the obs span timeline here (enables "
                         "tracing + kernel profiling for the whole run)")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="export the obs metrics-registry snapshot here")
    args = ap.parse_args()

    tracer = None
    if args.trace or args.metrics:
        tracer = obs.configure(trace=True)

    policy = None
    if args.backend:
        policy = KernelPolicy(backend=args.backend)
    elif args.calibration:
        policy = KernelPolicy.load(args.calibration)
        print(f"loaded calibration table ({len(policy.table)} buckets) "
              f"from {args.calibration}")

    policy_table = None
    if args.policy_table:
        policy_table = PolicyTable.load(args.policy_table)
        print(f"loaded policy table from {args.policy_table}")

    cluster = ShardCluster(args.hosts, GossipConfig(seed=args.seed))
    pools = train_tenants(cluster, args.domains, args.rounds, args.seed,
                          policy=policy)
    server = serve(cluster, pools, args.rate, args.duration, args.seed,
                   fixed_window_ms=args.fixed_window,
                   cache_capacity=args.cache, kill_owner=args.kill_owner,
                   policy=policy, policy_table=policy_table,
                   autoscale_max=args.autoscale,
                   budget_per_host=args.budget_per_host,
                   budget_per_hour=args.budget_per_hour)

    rep = server.report()
    mode = ("adaptive" if args.fixed_window <= 0
            else f"fixed {args.fixed_window:.0f}ms")
    mode += " window"
    if args.autoscale > 0:
        mode += f", autoscaled <= {args.autoscale} hosts"
    print(f"\nserving [{mode}, {args.hosts} hosts] nominal "
          f"{args.rate:.0f} rps, {args.duration:.1f}s bursty closed loop")
    print(f"  completed {rep['completed']}  rejected {rep['rejected']}  "
          f"throughput {rep['throughput_rps']:.0f} rps")
    print(f"  latency p50 {rep['p50_ms']:.2f} ms  p99 {rep['p99_ms']:.2f} ms  "
          f"mean batch {rep['mean_batch']:.1f}  "
          f"peak queue {rep['queue_depth_peak']}")
    cache = rep["cache"]
    print(f"  cache hit rate {cache['hit_rate']:.1%} "
          f"({cache['hits']} hits, {cache['fills']} fills, "
          f"{cache['invalidated']} invalidated)")
    for hid, h in rep["per_host"].items():
        print(f"  host {hid:<8} [{h['status']:>7}] served "
              f"{h['completed']:>6} p99 {h['p99_ms']:>6.2f} ms  "
              f"batches {h['n_batches']}")
    for name, t in rep["tenants"].items():
        print(f"  tenant {name:<12} served {t['completed']:>5} "
              f"p99 {t['p99_ms']:>6.2f} ms  snapshot v{t['snapshot_version']} "
              f"staleness {t['mean_staleness_s']:.2f}s")

    if tracer is not None:
        if args.trace:
            print(f"  trace: {len(tracer)} spans -> "
                  f"{tracer.export_jsonl(args.trace)}")
        if args.metrics:
            # fold the fleet's per-host serving counters into the global
            # registry snapshot so one file carries train + serve + kernel
            fleet_view = ServeMetrics(obs.get_registry())
            for _hid, _status, m in server._all_metrics():
                ShardedEnsembleServer._merge_into(fleet_view, m)
            ShardedEnsembleServer._merge_into(fleet_view, server.metrics)
            print(f"  metrics: -> "
                  f"{obs.get_registry().save(args.metrics)}")
        obs.disable()


if __name__ == "__main__":
    main()
