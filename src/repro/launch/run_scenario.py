"""Scenario launcher: run one registered deployment scenario end to end —
train baseline + enhanced through a behavior trace, check the paper band,
then replay the publish/request trace into the autoscaled serving fleet.

    PYTHONPATH=src python -m repro.launch.run_scenario --list
    PYTHONPATH=src python -m repro.launch.run_scenario mobile \
        --trace diurnal --rounds 16 --seed 0
    PYTHONPATH=src python -m repro.launch.run_scenario iot \
        --trace duty_cycle --hosts 3 --serve-duration 2.0
    PYTHONPATH=src python -m repro.launch.run_scenario healthcare \
        --trace legacy --no-serve

``--list`` prints the registry (domains, variants, traces, bands); a run
prints the train metrics vs the paper band and the serving-replay report.
"""
from __future__ import annotations

import argparse
import sys

from repro import obs
from repro.sim.harness import run_scenario, summarize
from repro.sim.scenarios import (SCENARIOS, base_scenarios, get_scenario,
                                 variant_scenarios)


def list_registry() -> None:
    print(f"{len(base_scenarios())} base scenario(s) + "
          f"{len(variant_scenarios())} variant(s):\n")
    for name, sc in SCENARIOS.items():
        kind = (f"variant of {sc.variant_of}" if sc.variant_of
                else "paper domain")
        b = sc.band
        print(f"{name:<18} [{kind}] {sc.domain.n_clients} clients, "
              f"{sc.domain.n_samples} samples, {sc.partitioner} partition")
        print(f"{'':<18} traces: legacy, {', '.join(sc.nontrivial_traces)}")
        print(f"{'':<18} band: time ~{b.time_down[0]:.0f}-"
              f"{b.time_down[1]:.0f}%  comm ~{b.comm_down[0]:.0f}-"
              f"{b.comm_down[1]:.0f}%  acc {b.acc_delta_pp[0]:+.1f}.."
              f"{b.acc_delta_pp[1]:+.1f}pp")
        if sc.notes:
            print(f"{'':<18} {sc.notes}")
        print()


def main() -> None:
    ap = argparse.ArgumentParser(
        description="train -> serve one registered scenario")
    ap.add_argument("scenario", nargs="?", default=None,
                    help="registered scenario name (see --list)")
    ap.add_argument("--list", action="store_true", dest="list_",
                    help="list the scenario registry and exit")
    ap.add_argument("--trace", default="legacy",
                    help="behavior trace name (default: legacy)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="boosting rounds (default: scenario's n_rounds)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hosts", type=int, default=2,
                    help="initial serving hosts")
    ap.add_argument("--serve-duration", type=float, default=1.5,
                    help="serving replay window (simulated seconds)")
    ap.add_argument("--no-serve", action="store_true",
                    help="train + band check only")
    ap.add_argument("--no-autoscale", action="store_true",
                    help="fixed fleet during the serve replay")
    ap.add_argument("--engine", choices=("events", "loop"),
                    default="events",
                    help="execution core: the event-queue virtual clock "
                         "(default) or the legacy client-at-a-time loop "
                         "kept as the bit-for-bit parity oracle")
    ap.add_argument("--fleet", action="store_true",
                    help="force the vectorized fleet profile (auto-"
                         "enabled at 4096+ clients; implies the event "
                         "core)")
    # --trace names the *behavior* trace (pre-dates the obs layer), so the
    # observability exports take the -out suffix here; serve_ensemble has
    # no such clash and uses the plain --trace/--metrics spelling
    ap.add_argument("--trace-out", default=None, metavar="OUT.jsonl",
                    help="export the obs span timeline here (enables "
                         "tracing + kernel profiling for the run)")
    ap.add_argument("--metrics-out", default=None, metavar="OUT.json",
                    help="export the obs metrics-registry snapshot here")
    args = ap.parse_args()

    if args.list_ or args.scenario is None:
        list_registry()
        return

    sc = get_scenario(args.scenario)
    if args.trace not in sc.traces:
        ap.error(f"scenario {sc.name!r} has no trace {args.trace!r}; "
                 f"choose from: legacy, {', '.join(sc.nontrivial_traces)}")
    if args.fleet:
        from dataclasses import replace
        sc = replace(sc, fleet=True)
    tracer = None
    if args.trace_out or args.metrics_out:
        tracer = obs.configure(trace=True)
    rep = run_scenario(sc, trace=args.trace, seed=args.seed,
                       n_rounds=args.rounds, serve=not args.no_serve,
                       serve_duration_s=args.serve_duration,
                       hosts=args.hosts, autoscale=not args.no_autoscale,
                       engine=args.engine)
    print(summarize(rep))
    if tracer is not None:
        if args.trace_out:
            print(f"trace: {len(tracer)} spans -> "
                  f"{tracer.export_jsonl(args.trace_out)}")
        if args.metrics_out:
            print(f"metrics: -> {obs.get_registry().save(args.metrics_out)}")
        obs.disable()
    sys.exit(0 if rep.within_band else 1)


if __name__ == "__main__":
    main()
