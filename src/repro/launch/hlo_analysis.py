"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` (XLA HloCostAnalysis) visits every while-loop
body ONCE, so a scan-over-layers model under-reports FLOPs/bytes/collective
traffic by roughly the layer count.  The compiled HLO text, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on every while op — so an
exact correction is possible by walking the call graph and multiplying each
computation's costs by the product of enclosing trip counts.

This module implements that walk plus a minimal per-op cost model:

* FLOPs: 2 * prod(result_dims) * prod(contracted_dims) per ``dot`` op
  (elementwise/reduce FLOPs are ignored — matmuls dominate every shape we
  analyze; the roofline compute term is MXU-bound anyway).
* collective bytes: result-buffer bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (incl. tuple-shaped and
  ``-start`` async forms).
* bytes accessed: sum of (operands + result) buffer bytes over ops in
  non-fused computations — the same convention HloCostAnalysis uses, with
  fusion internals attributed to the fusion call site.

Validated against a fully-unrolled compile of qwen1.5-0.5b/train_4k
(scan-corrected vs unrolled FLOPs agree; see tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s+(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RES = [
    re.compile(r"body=(%[\w.\-]+)"),
    re.compile(r"condition=(%[\w.\-]+)"),
    re.compile(r"calls=(%[\w.\-]+)"),
    re.compile(r"to_apply=(%[\w.\-]+)"),
    re.compile(r"branch_computations=\{([^}]*)\}"),
]

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota",
}


@dataclass
class Op:
    name: str
    opcode: str
    dtype: Optional[str]
    dims: Optional[Tuple[int, ...]]
    tuple_shapes: List[Tuple[str, Tuple[int, ...]]]
    rhs: str          # full right-hand side text


def _parse_shape_prefix(rhs: str):
    """Parse `f32[2,3]{...}` or `(f32[2], s32[])` result type prefix."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        close = rhs.find(")")
        inner = rhs[1:close]
        shapes = []
        for dt, dims in _SHAPE_RE.findall(inner):
            shapes.append((dt, tuple(int(d) for d in dims.split(",") if d)))
        return None, None, shapes, rhs[close + 1:]
    m = _SHAPE_RE.match(rhs)
    if not m:
        return None, None, [], rhs
    dt, dims = m.groups()
    return (dt, tuple(int(d) for d in dims.split(",") if d), [],
            rhs[m.end():])


def _opcode_of(rest: str) -> str:
    rest = rest.lstrip()
    # strip layout `{...}` annotations
    while rest.startswith("{"):
        rest = rest[rest.find("}") + 1:].lstrip()
    m = re.match(r"([\w\-]+)\(", rest)
    return m.group(1) if m else rest.split("(")[0].strip()


def parse_hlo(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(2)
            comps[cur] = []
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            if line.strip() == "}":
                cur = None
            continue
        name, rhs = md.groups()
        dt, dims, tshapes, rest = _parse_shape_prefix(rhs)
        opcode = _opcode_of(rest)
        # drop `-start`/`-done` suffixes for classification
        base = opcode
        for suf in ("-start", "-done", "-update"):
            if base.endswith(suf):
                base = base[: -len(suf)]
        comps[cur].append(Op(name, base, dt, dims, tshapes, rhs))
    return comps


def _bytes_of(dt, dims) -> int:
    if dt is None or dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * DTYPE_BYTES[dt]


def analyze(text: str) -> Dict:
    comps = parse_hlo(text)
    shape_map: Dict[str, Tuple[Optional[str], Optional[Tuple[int, ...]]]] = {}
    for ops in comps.values():
        for op in ops:
            shape_map[op.name] = (op.dtype, op.dims)

    # call graph with while-trip multipliers
    entry = None
    for name in comps:
        if "ENTRY" in name or entry is None:
            pass
    # the ENTRY computation is the one introduced by a line starting ENTRY
    # _COMP_RE keeps its name in group 2; detect by re-scanning text:
    m = re.search(r"^ENTRY\s+(%?[\w.\-]+)", text, re.M)
    entry = m.group(1) if m else next(iter(comps))

    mult: Dict[str, float] = defaultdict(float)
    fused_bodies = set()

    def visit(comp: str, m_in: float):
        if comp not in comps:
            return
        if mult[comp] >= m_in:   # already visited with >= multiplier
            return
        mult[comp] = m_in
        for op in comps[comp]:
            trip = 1
            tm = _TRIP_RE.search(op.rhs)
            if tm:
                trip = int(tm.group(1))
            for cre in _CALLEE_RES:
                for cm in cre.finditer(op.rhs):
                    targets = cm.group(1)
                    for t in re.findall(r"%[\w.\-]+", targets):
                        is_body = "body=" + t in op.rhs and op.opcode == "while"
                        if "calls=" + t in op.rhs:
                            fused_bodies.add(t)
                        visit(t, m_in * (trip if is_body else 1))

    visit(entry, 1.0)

    flops = 0.0
    flops_raw = 0.0
    coll = {c: {"count": 0, "bytes": 0.0, "bytes_raw": 0.0}
            for c in COLLECTIVES}
    bytes_accessed = 0.0

    for comp, ops in comps.items():
        m_ = mult.get(comp, 0.0)
        if m_ == 0.0:
            continue
        in_fused = comp in fused_bodies
        for op in ops:
            if op.opcode == "dot":
                # contracted size from the lhs operand's shape; the operand
                # may carry a type prefix (`dot(f32[8,16]{1,0} %lhs, ...)`,
                # older XLA text) or not (`dot(%lhs, ...)`)
                f = 0.0
                rm = re.search(
                    r"\(\s*(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?\s+)?"
                    r"(%[\w.\-]+)", op.rhs)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rhs)
                if rm and cm and op.dims is not None:
                    lhs_dt, lhs_dims = shape_map.get(rm.group(1), (None, None))
                    if lhs_dims is not None:
                        contracted = 1
                        for d in cm.group(1).split(","):
                            if d:
                                contracted *= lhs_dims[int(d)]
                        n = 1
                        for d in op.dims:
                            n *= d
                        f = 2.0 * n * contracted
                flops += f * m_
                flops_raw += f
            if op.opcode in COLLECTIVES:
                if op.dims is not None:
                    b = _bytes_of(op.dtype, op.dims)
                else:
                    b = sum(_bytes_of(dt, dims) for dt, dims in op.tuple_shapes)
                # `-done` variants were normalized away; `-start` ops carry
                # the payload (async pair counted once via -start, and the
                # sync form once via itself).  Skip the paired `-done`.
                if "-done" in op.rhs.split("(")[0]:
                    continue
                coll[op.opcode]["count"] += 1
                coll[op.opcode]["bytes"] += b * m_
                coll[op.opcode]["bytes_raw"] += b
            if (not in_fused and op.opcode not in _SKIP_BYTES_OPS
                    and op.opcode not in ("while", "conditional", "call")):
                b = (_bytes_of(op.dtype, op.dims) if op.dims is not None
                     else sum(_bytes_of(dt, dims)
                              for dt, dims in op.tuple_shapes))
                # operands: only refs inside the op's argument parens (the
                # text before the first close-paren) — attributes like
                # body=%x / metadata would otherwise pollute the count
                args = op.rhs.split("(", 1)[-1].split(")", 1)[0]
                for ref in re.findall(r"%[\w.\-]+", args):
                    dt, dims = shape_map.get(ref, (None, None))
                    if dims is not None:
                        b += _bytes_of(dt, dims)
                bytes_accessed += b * m_

    # async -start/-done double count: each async collective contributes its
    # payload twice (start + its alias at done). Halve pairs heuristically:
    # (the sync form dominates CPU HLO; keep simple and note the convention.)

    return {
        "flops_corrected": flops,
        "flops_loop_body_once": flops_raw,
        "collectives": {k: {"count": v["count"],
                            "bytes": v["bytes"],
                            "bytes_raw": v["bytes_raw"]}
                        for k, v in coll.items()},
        "collective_bytes_total": sum(v["bytes"] for v in coll.values()),
        "bytes_accessed_corrected": bytes_accessed,
        "n_computations": len(comps),
    }
